//! Batch-size impact demo (Fig 4c in miniature): end-to-end throughput of
//! the pipelined scan over the simulated S3 store as the inference batch
//! size sweeps 1 -> 64.
//!
//! Expected shape (paper §4.3.2): flat at BS 1-2 (transmission-dominated),
//! steep rise 4-16 (compute amortizes), plateau past 16 (compute capacity).
//!
//! Run: `cargo run --release --example batch_size_sweep`

use std::sync::Arc;
use std::time::{Duration, Instant};

use alaas::cache::DataCache;
use alaas::config::StoreConfig;
use alaas::data::{generate_into_store, DatasetSpec};
use alaas::pipeline::{run_pipeline, BatchPolicy, DataflowMode, PipelineParams};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, HostBackend, PjrtBackend, PjrtPool};
use alaas::store::{ObjectStore, StoreRouter};
use alaas::trainer::LinearHead;

fn backend() -> Arc<dyn ComputeBackend> {
    match alaas::runtime::find_artifacts_dir(None) {
        Some(dir) => {
            let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
            let pool = Arc::new(PjrtPool::new(index, 2, 64));
            Arc::new(PjrtBackend::new(pool))
        }
        None => Arc::new(HostBackend::new()),
    }
}

fn main() -> anyhow::Result<()> {
    let n = 1500usize;
    // S3-like latency: this is what creates the Fig 4c shape
    let store_cfg =
        StoreConfig { get_latency_us: 400, bandwidth_mib_s: 200.0, jitter: 0.05 };
    let store = StoreRouter::new("/tmp", &store_cfg);
    let spec = DatasetSpec::cifarsim(4).with_sizes(0, n, 0);
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "bs");
    for key in scratch.list("")? {
        store.s3sim_backing().put(&key, &scratch.get(&key)?)?;
    }
    let backend = backend();
    let head = LinearHead::zeros(64, 10);

    println!("== batch-size sweep, {n} images over s3sim (Fig 4c protocol) ==");
    println!("{:>6} {:>14} {:>12}", "batch", "throughput", "elapsed");
    for bs in [1usize, 2, 4, 8, 16, 32, 64] {
        let cache = DataCache::new(0, 1, false); // cold every time
        let params = PipelineParams {
            mode: DataflowMode::Pipelined,
            batch: BatchPolicy { max_batch: bs, max_wait: Duration::from_millis(10) },
            fetch_threads: 8,
            preprocess_threads: 4,
            infer_threads: 2,
            ..Default::default()
        };
        let t0 = Instant::now();
        let out = run_pipeline(&manifest.pool, &store, &cache, &backend, &head, &params, None)?;
        let dt = t0.elapsed();
        assert_eq!(out.processed, n);
        println!(
            "{bs:>6} {:>10.1} im/s {:>10.2}s",
            n as f64 / dt.as_secs_f64(),
            dt.as_secs_f64()
        );
    }
    println!("\nbatch_size_sweep OK");
    Ok(())
}

//! The AL agent demo: "non-experts only need to input target accuracy and
//! budget, then sit and wait for the final results" (paper §3.1).
//!
//! Runs PSHEA (Algorithm 1) with all 7 zoo candidates on a synthetic
//! dataset, printing the per-round accuracy / forecast / elimination trace
//! (Fig 5b in miniature) and the final recommendation.
//!
//! Run: `cargo run --release --example auto_select_pshea`

use std::sync::Arc;

use alaas::agent::{run_pshea, PsheaConfig};
use alaas::data::{generate, DatasetSpec};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, HostBackend, PjrtBackend, PjrtPool};
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;

fn backend() -> Arc<dyn ComputeBackend> {
    match alaas::runtime::find_artifacts_dir(None) {
        Some(dir) => {
            let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
            let pool = Arc::new(PjrtPool::new(index, 2, 64));
            Arc::new(PjrtBackend::new(pool))
        }
        None => Arc::new(HostBackend::new()),
    }
}

fn main() -> anyhow::Result<()> {
    // The non-expert's two inputs:
    let target_accuracy = 0.88;
    let max_budget = 6_000;

    let spec = DatasetSpec::cifarsim(77).with_sizes(300, 2500, 600);
    println!("== PSHEA auto-selection (target {target_accuracy}, budget {max_budget}) ==");
    let gen = generate(&spec);
    let backend = backend();
    println!("embedding {} samples via {}...", gen.images.len(), backend.name());
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec.num_classes,
        TrainConfig { epochs: 25, ..Default::default() },
        77,
    )?;
    let (_, base) = exp.baseline()?;
    println!("baseline (init-only) top-1: {:.3}\n", base.top1);

    let candidates: Vec<String> =
        alaas::strategies::candidate_names().into_iter().map(str::to_string).collect();
    let cfg = PsheaConfig {
        target_accuracy,
        max_budget,
        round_budget: 150,
        max_rounds: 8, // the paper simulates an 8-round procedure
        initial_accuracy: Some(base.top1), // Algorithm 1: a_max = a_0
        ..Default::default()
    };
    let trace = run_pshea(&mut exp, &candidates, &cfg)?;

    for r in 0..trace.rounds {
        println!("round {r}:");
        for rec in trace.round(r) {
            println!(
                "  {:18} acc {:.4}  pred-next {}  {}",
                rec.strategy,
                rec.accuracy,
                rec.predicted_next
                    .map(|p| format!("{p:.4}"))
                    .unwrap_or_else(|| "   -  ".into()),
                if rec.eliminated { "<- ELIMINATED" } else { "" }
            );
        }
    }
    println!(
        "\nstopped: {:?} after {} rounds; {} labels consumed; best accuracy {:.4}",
        trace.stop, trace.rounds, trace.total_budget, trace.best_accuracy
    );
    println!(
        "recommended strategy for this dataset/budget: {}",
        trace.recommendation().unwrap_or("(none)")
    );

    // cost saving vs brute force: running all candidates every round
    let brute = candidates.len() * trace.rounds * cfg.round_budget;
    println!(
        "label cost: {} vs {} brute-force ({}% saved by early stopping)",
        trace.total_budget,
        brute,
        100 * (brute - trace.total_budget) / brute.max(1)
    );
    println!("\nauto_select_pshea OK");
    Ok(())
}

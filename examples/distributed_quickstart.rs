//! Distributed quickstart — one AL round through the cluster topology
//! (DESIGN.md §Cluster):
//!
//!   1. Start 3 worker servers (in-process, real TCP).
//!   2. Start a coordinator wired to them.
//!   3. Push an unlabeled dataset through the *unchanged* client API:
//!      the coordinator shards the pool so each worker pipelines its own
//!      slice concurrently, then merges the selections.
//!
//! Run: `cargo run --release --example distributed_quickstart`

use std::sync::Arc;

use alaas::cache::DataCache;
use alaas::cluster::{Coordinator, CoordinatorDeps};
use alaas::config::AlaasConfig;
use alaas::data::{generate_into_store, DatasetSpec, Oracle};
use alaas::json::Value;
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::{AlClient, AlServer, ServerDeps, SessionOpts};
use alaas::store::{ObjectStore, StoreRouter};

const WORKERS: usize = 3;

fn main() -> anyhow::Result<()> {
    let mut cfg = AlaasConfig::default();
    cfg.al_worker.port = 0; // ephemeral everywhere

    // The dataset lives in the (simulated) object store all servers share
    // — like a bucket every replica can reach.
    let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));
    let spec = DatasetSpec::cifarsim(42).with_sizes(200, 1500, 0);
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "dist-quickstart");
    for key in scratch.list("")? {
        store.s3sim_backing().put(&key, &scratch.get(&key)?)?;
    }
    let oracle = Oracle::load(&scratch, "dist-quickstart")?;
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);
    println!(
        "dataset: {} (init {}, pool {})",
        manifest.name,
        manifest.init.len(),
        manifest.pool.len()
    );

    // 1. Workers: each is a plain AlServer that also speaks the
    // worker-facing cluster methods.
    let workers: Vec<AlServer> = (0..WORKERS)
        .map(|_| {
            AlServer::start(
                cfg.clone(),
                ServerDeps {
                    store: store.clone(),
                    cache: Arc::new(DataCache::from_config(&cfg.cache)),
                    backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
                    metrics: Registry::new(),
                },
            )
        })
        .collect::<std::io::Result<_>>()?;
    for (i, w) in workers.iter().enumerate() {
        println!("worker {i}: listening on {}", w.addr());
    }

    // 2. Coordinator: the AlClient-compatible front for the pool.
    let mut coord_cfg = cfg.clone();
    coord_cfg.cluster.workers = workers.iter().map(|w| w.addr().to_string()).collect();
    let metrics = Registry::new();
    let coordinator = Coordinator::start(
        coord_cfg,
        CoordinatorDeps {
            backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
            metrics: metrics.clone(),
        },
    )?;
    println!("coordinator: listening on {}", coordinator.addr());

    // 3. The Figure 2 workflow, now against the cluster through a session
    // handle — the coordinator admits each scatter under the tenancy gate.
    let mut client = AlClient::connect(&coordinator.addr().to_string())?;
    client.ping()?;
    let mut session = client.create_session("dist", SessionOpts::default())?;
    session.push(&manifest, Some(&init_labels))?;
    println!("client: pushed {} pool samples across {WORKERS} workers", manifest.pool.len());

    let t0 = std::time::Instant::now();
    let (selected, strategy, select_ms) = session.query(10, None)?;
    println!(
        "client: query(budget=10) -> {} samples via {strategy} in {:.1}ms (merge {select_ms:.2}ms)",
        selected.len(),
        t0.elapsed().as_secs_f64() * 1e3,
    );
    for s in &selected {
        println!("  -> id={:5} {}", s.id, s.uri);
    }
    // a diversity strategy exercises the candidate-then-refine protocol
    let (div, strategy, _) = session.query(10, Some("k_center_greedy"))?;
    println!("client: {strategy} refine pass -> {} samples", div.len());
    session.close()?;

    // Per-shard scan timings + straggler spread from the coordinator's
    // metrics registry (also served over the `metrics` RPC).
    let snap = metrics.snapshot();
    let hists = snap.get("histograms").expect("histograms");
    println!("per-shard scan timings:");
    for i in 0..WORKERS {
        let name = format!("cluster.shard{i}.scan");
        if let Some(h) = hists.get(&name) {
            let mean_us = h.get("mean_us").and_then(Value::as_f64).unwrap_or(0.0);
            let max_us = h.get("max_us").and_then(Value::as_f64).unwrap_or(0.0);
            println!("  shard {i}: mean {:.1}ms, max {:.1}ms", mean_us / 1e3, max_us / 1e3);
        }
    }
    let straggler = snap
        .path("counters")
        .and_then(|c| c.get("cluster.scan.straggler_ms"))
        .and_then(Value::as_i64)
        .unwrap_or(0);
    println!("straggler spread (max - min shard scan): {straggler}ms");

    coordinator.shutdown();
    for w in workers {
        w.shutdown();
    }
    println!("distributed quickstart OK");
    Ok(())
}

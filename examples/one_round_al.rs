//! End-to-end driver (the Table 2 protocol on a real small workload).
//!
//! Full system exercise proving all layers compose:
//!   * dataset synthesized into the simulated S3 store (storage tier)
//!   * AL server + client over TCP (L3 coordinator)
//!   * pipelined scan: fetch -> cache -> preprocess -> dynamic batch ->
//!     AOT JAX/Pallas artifacts through PJRT (runtime + L2 + L1)
//!   * least-confidence selection (the Table 2 strategy)
//!   * oracle labels the selection; last layer fine-tuned via the AOT
//!     train_step; accuracy evaluated before/after
//!
//! Reports one-round latency, end-to-end throughput, and top-1/top-5 —
//! the Table 2 columns. Recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example one_round_al` (needs artifacts)

use std::sync::Arc;
use std::time::Instant;

use alaas::cache::DataCache;
use alaas::config::AlaasConfig;
use alaas::data::{generate, generate_into_store, DatasetSpec, Oracle};
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, HostBackend, PjrtBackend, PjrtPool};
use alaas::server::{AlClient, AlServer, ServerDeps};
use alaas::sim::AlExperiment;
use alaas::store::{ObjectStore, StoreRouter};
use alaas::trainer::TrainConfig;

fn backend(replicas: usize) -> Arc<dyn ComputeBackend> {
    match alaas::runtime::find_artifacts_dir(None) {
        Some(dir) => {
            let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
            let pool = Arc::new(PjrtPool::new(index, replicas, 64));
            let be = PjrtBackend::new(pool);
            be.pool()
                .warmup(&["forward_b16".into(), "forward_b128".into()])
                .expect("warmup");
            println!("backend: pjrt");
            Arc::new(be)
        }
        None => {
            println!("backend: host (run `make artifacts` for the PJRT path)");
            Arc::new(HostBackend::new())
        }
    }
}

fn main() -> anyhow::Result<()> {
    // Scaled-down Table 2 workload: paper scans 40k and selects 10k;
    // we scan 4k and select 1k (same 4:1 ratio) on the simulated S3.
    let (n_init, n_pool, n_test, budget) = (500usize, 4000usize, 1000usize, 1000usize);
    let spec = DatasetSpec::cifarsim(2022).with_sizes(n_init, n_pool, n_test);

    let mut cfg = AlaasConfig::default();
    cfg.al_worker.port = 0;
    cfg.active_learning.model.batch_size = 16;
    let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));

    println!("== one-round AL end-to-end (Table 2 protocol, scaled 1/10) ==");
    println!("dataset: cifarsim init={n_init} pool={n_pool} test={n_test}, budget={budget}");

    // provision the bucket
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "t2");
    for key in scratch.list("")? {
        store.s3sim_backing().put(&key, &scratch.get(&key)?)?;
    }
    let oracle = Oracle::load(&scratch, "t2")?;
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);

    // server + client
    let backend = backend(cfg.al_worker.replicas);
    let metrics = Registry::new();
    let deps = ServerDeps {
        store,
        cache: Arc::new(DataCache::from_config(&cfg.cache)),
        backend: backend.clone(),
        metrics: metrics.clone(),
    };
    let server = AlServer::start(cfg, deps)?;
    let mut client = AlClient::connect(&server.addr().to_string())?;

    // one-round AL: push (starts the pipelined scan) + query
    let t0 = Instant::now();
    client.push_data("t2", &manifest, Some(&init_labels))?;
    let (selected, strategy, select_ms) =
        client.query("t2", budget, Some("least_confidence"))?;
    let latency = t0.elapsed();
    let throughput = n_pool as f64 / latency.as_secs_f64();
    println!("\none-round AL latency : {:.2}s (strategy {strategy})", latency.as_secs_f64());
    println!("end-to-end throughput: {throughput:.1} images/sec");
    println!("select phase         : {select_ms:.1}ms");
    assert_eq!(selected.len(), budget);

    // label the selection and fine-tune the last layer (the "human
    // oracle -> model update" half of Figure 1), via the science engine
    // on the same backend/artifacts.
    let gen = generate(&spec);
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec.num_classes,
        TrainConfig::default(),
        7,
    )?;
    let (_, before) = exp.baseline()?;
    let after = exp.one_round("least_confidence", budget)?;
    println!("\naccuracy (test {n_test} samples):");
    println!("  init-only baseline : top-1 {:.2}%  top-5 {:.2}%", before.top1 * 100.0, before.top5 * 100.0);
    println!("  after one-round AL : top-1 {:.2}%  top-5 {:.2}%", after.top1 * 100.0, after.top5 * 100.0);
    let ub = exp.upper_bound()?;
    println!("  full-pool upper bnd: top-1 {:.2}%  top-5 {:.2}%", ub.top1 * 100.0, ub.top5 * 100.0);

    // stage breakdown from the server metrics
    let snap = metrics.snapshot();
    for stage in ["stage.fetch", "stage.preprocess", "stage.infer", "al.select"] {
        if let Some(h) = snap.get("histograms").and_then(|h| h.get(stage)) {
            println!(
                "  {stage:18} p50 {:>9.1}us  p95 {:>9.1}us  n={}",
                h.get("p50_us").unwrap().as_f64().unwrap(),
                h.get("p95_us").unwrap().as_f64().unwrap(),
                h.get("count").unwrap().as_i64().unwrap()
            );
        }
    }
    assert!(after.top1 >= before.top1 - 0.02, "AL round should not hurt accuracy");
    server.shutdown();
    println!("\none_round_al OK");
    Ok(())
}

//! Quickstart — the paper's Figure 2 workflow in one binary:
//!
//!   1. Configure an AL server from `example.yml`.
//!   2. Start the server (in-process, real TCP).
//!   3. Start a client, push an unlabeled dataset, `query(budget)`.
//!
//! Run: `cargo run --release --example quickstart`
//! Uses the PJRT backend when `make artifacts` has been run, otherwise
//! falls back to the host backend.

use std::sync::Arc;

use alaas::cache::DataCache;
use alaas::config::AlaasConfig;
use alaas::data::{generate_into_store, DatasetSpec, Oracle};
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, HostBackend, PjrtBackend, PjrtPool};
use alaas::server::{AlClient, AlServer, ServerDeps, SessionOpts};
use alaas::store::{ObjectStore, StoreRouter};

fn backend() -> Arc<dyn ComputeBackend> {
    match alaas::runtime::find_artifacts_dir(None) {
        Some(dir) => {
            let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
            let pool = Arc::new(PjrtPool::new(index, 2, 64));
            println!("backend: pjrt ({} artifacts)", dir.display());
            Arc::new(PjrtBackend::new(pool))
        }
        None => {
            println!("backend: host (run `make artifacts` for the PJRT path)");
            Arc::new(HostBackend::new())
        }
    }
}

fn main() -> anyhow::Result<()> {
    // 1. Configure AL server at example.yml (Fig 2, step 1)
    let config_path = std::path::Path::new("examples/example.yml");
    let mut cfg = if config_path.exists() {
        AlaasConfig::from_yaml_file(config_path.to_str().unwrap())?
    } else {
        AlaasConfig::default()
    };
    cfg.al_worker.port = 0; // ephemeral for the example
    println!("config: service '{}' v{}, strategy {:?}", cfg.name, cfg.version, cfg.active_learning.strategy);

    // The dataset lives in the (simulated) object store before the client
    // pushes its URIs — like a bucket the data scientist already owns.
    let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));
    let spec = DatasetSpec::cifarsim(42).with_sizes(200, 1000, 0);
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "quickstart");
    for key in scratch.list("")? {
        store.s3sim_backing().put(&key, &scratch.get(&key)?)?;
    }
    let oracle = Oracle::load(&scratch, "quickstart")?;
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);
    println!(
        "dataset: {} (init {}, pool {})",
        manifest.name,
        manifest.init.len(),
        manifest.pool.len()
    );

    // 2. Start Server (Fig 2, step 2)
    let deps = ServerDeps {
        store,
        cache: Arc::new(DataCache::from_config(&cfg.cache)),
        backend: backend(),
        metrics: Registry::new(),
    };
    let server = AlServer::start(cfg, deps)?;
    println!("server: listening on {}", server.addr());

    // 3. Start Client (Fig 2, step 3). `create_session` mints a session
    // handle; push/query hang off it and `close()` releases the quota slot.
    let mut client = AlClient::connect(&server.addr().to_string())?;
    client.ping()?;
    let mut session = client.create_session("quickstart", SessionOpts::default())?;
    session.push(&manifest, Some(&init_labels))?;
    println!("client: pushed {} pool samples", manifest.pool.len());

    let t0 = std::time::Instant::now();
    let (selected, strategy, select_ms) = session.query(10, None)?;
    println!(
        "client: query(budget=10) -> {} samples via {strategy} in {:.1}ms (select {select_ms:.2}ms)",
        selected.len(),
        t0.elapsed().as_secs_f64() * 1e3,
    );
    for s in &selected {
        println!("  -> id={:5} {}", s.id, s.uri);
    }

    // these are what a human oracle would label next
    session.close()?;
    let stats = client.cache_stats()?;
    println!(
        "cache: {} hits / {} misses",
        stats.get("hits").unwrap().as_i64().unwrap(),
        stats.get("misses").unwrap().as_i64().unwrap()
    );
    server.shutdown();
    println!("quickstart OK");
    Ok(())
}

//! Agent-as-a-service quickstart (DESIGN.md §Agent): run the PSHEA
//! auto-selection loop *on the cluster* instead of in the client process:
//!
//!   1. Start 2 workers + a coordinator (in-process, real TCP).
//!   2. Push a dataset (init + pool + test) through the unchanged client
//!      API — the pool shards across the workers, init/test replicate.
//!   3. `agent_start` a background PSHEA job: every candidate strategy is
//!      an arm whose per-round selection scatters over the worker shards
//!      through the same `select_shard` wire a plain query uses.
//!   4. Poll `agent_status` for the live round log, then print the final
//!      trace from `agent_result`.
//!
//! Run: `cargo run --release --example agent_service`

use std::sync::Arc;
use std::time::Duration;

use alaas::agent::PsheaConfig;
use alaas::cache::DataCache;
use alaas::cluster::{Coordinator, CoordinatorDeps};
use alaas::config::AlaasConfig;
use alaas::data::{generate_into_store, DatasetSpec, Oracle};
use alaas::json::Value;
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::{AlClient, AlServer, ServerDeps, SessionOpts};
use alaas::store::{ObjectStore, StoreRouter};

const WORKERS: usize = 2;

fn main() -> anyhow::Result<()> {
    let mut cfg = AlaasConfig::default();
    cfg.al_worker.port = 0; // ephemeral everywhere

    let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));
    let spec = DatasetSpec::cifarsim(42).with_sizes(150, 900, 300);
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "agent-quickstart");
    for key in scratch.list("")? {
        store.s3sim_backing().put(&key, &scratch.get(&key)?)?;
    }
    let oracle = Oracle::load(&scratch, "agent-quickstart")?;
    let ids = |refs: &[alaas::store::SampleRef]| -> Vec<u32> {
        refs.iter().map(|s| s.id).collect()
    };
    // init labels are pushed with the data; pool/test labels ride the
    // agent_start RPC as the oracle the served loop charges per round
    let init_labels = oracle.label(&ids(&manifest.init));
    let pool_labels = oracle.eval_labels(&ids(&manifest.pool));
    let test_labels = oracle.eval_labels(&ids(&manifest.test));
    println!(
        "dataset: {} (init {}, pool {}, test {})",
        manifest.name,
        manifest.init.len(),
        manifest.pool.len(),
        manifest.test.len()
    );

    let workers: Vec<AlServer> = (0..WORKERS)
        .map(|_| {
            AlServer::start(
                cfg.clone(),
                ServerDeps {
                    store: store.clone(),
                    cache: Arc::new(DataCache::from_config(&cfg.cache)),
                    backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
                    metrics: Registry::new(),
                },
            )
        })
        .collect::<std::io::Result<_>>()?;
    let mut coord_cfg = cfg.clone();
    coord_cfg.cluster.workers = workers.iter().map(|w| w.addr().to_string()).collect();
    let metrics = Registry::new();
    let coordinator = Coordinator::start(
        coord_cfg,
        CoordinatorDeps {
            backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
            metrics: metrics.clone(),
        },
    )?;
    println!("coordinator: {} ({WORKERS} workers)", coordinator.addr());

    let mut client = AlClient::connect(&coordinator.addr().to_string())?;
    let mut session = client.create_session("agent", SessionOpts::default())?;
    session.push(&manifest, Some(&init_labels))?;

    // 3 candidate arms under a tight budget; the server eliminates the
    // weakest forecast each round (Algorithm 1)
    let strategies: Vec<String> =
        ["least_confidence", "entropy", "k_center_greedy"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let pshea = PsheaConfig {
        target_accuracy: 0.95,
        max_budget: 2_000,
        round_budget: 50,
        max_rounds: 6,
        min_history: 2,
        ..Default::default()
    };
    let job = session.agent_start(&strategies, &pshea, &pool_labels, &test_labels, 42)?;
    println!("agent job {job}: {} arms fan out across the shards", strategies.len());
    // detach: the poll loop needs the client back, and dropping the handle
    // would close the session out from under the running job
    let (_, token) = session.detach();

    let mut last_round = 0usize;
    loop {
        std::thread::sleep(Duration::from_millis(200));
        let st = client.agent_status(&job)?;
        let status = st.get("status").and_then(Value::as_str).unwrap_or("?").to_string();
        let rounds = st.get("rounds").and_then(Value::as_usize).unwrap_or(0);
        if rounds > last_round {
            let live = st.get("live").and_then(Value::as_array).map(|a| a.len()).unwrap_or(0);
            let spent = st.get("budget_spent").and_then(Value::as_usize).unwrap_or(0);
            let best = st.get("best_accuracy").and_then(Value::as_f64).unwrap_or(0.0);
            println!("  round {rounds}: {live} live, {spent} labels, best {best:.4}");
            last_round = rounds;
        }
        if status != "running" {
            break;
        }
    }

    let trace = client.agent_result(&job, Duration::from_secs(600))?;
    client.close_session(&token)?;
    for rec in trace.records.iter().filter(|r| r.eliminated) {
        println!("eliminated in round {}: {}", rec.round, rec.strategy);
    }
    println!(
        "stop {:?} after {} rounds, {} labels; recommended: {}",
        trace.stop,
        trace.rounds,
        trace.total_budget,
        trace.recommendation().unwrap_or("(none)")
    );

    coordinator.shutdown();
    for w in workers {
        w.shutdown();
    }
    println!("agent service quickstart OK");
    Ok(())
}

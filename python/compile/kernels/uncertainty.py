"""L1 Pallas kernel: fused softmax + 4 uncertainty scores.

The AL serving hot path needs, for every unlabeled sample, the four
uncertainty statistics the strategy zoo consumes (least-confidence, margin,
ratio, entropy — see ref.SCORE_NAMES). A naive implementation (what the
Python AL tools in Table 1 do) materializes the softmax, then runs four
separate reductions over HBM-resident probabilities. This kernel fuses the
whole thing: one `[Bb, C]` logits tile is read into VMEM once and all four
scores come out of the same pass, so the probabilities never round-trip to
HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the row-wise max/sum/top-2
reductions are VPU lane reductions over a VMEM-resident tile; the grid walks
the batch dimension in `block_b` chunks. On a GPU this would be a
thread-per-row fused kernel; the BlockSpec grid expresses the same schedule
as an HBM→VMEM pipeline.

Pallas is run with interpret=True (CPU plugin cannot execute Mosaic
custom-calls); correctness vs. ref.uncertainty_scores_ref is enforced by
python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NUM_SCORES = 4


def _scores_kernel(logits_ref, out_ref):
    """One grid step: score a [Bb, C] tile of logits into a [Bb, 4] tile."""
    logits = logits_ref[...].astype(jnp.float32)  # [Bb, C]

    # Numerically stable softmax over the class axis, entirely in VMEM.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z  # [Bb, C]

    # Top-2 via masked second max — C is small (class count), so two
    # reductions beat a sort on both VPU and scalar cores.
    p1 = jnp.max(p, axis=-1, keepdims=True)  # [Bb, 1]
    is_top = p == p1
    # Knock out exactly one argmax occurrence per row (ties: the first).
    first_top = jnp.cumsum(is_top.astype(jnp.int32), axis=-1) == 1
    knock = is_top & first_top
    p_wo_top = jnp.where(knock, -jnp.inf, p)
    p2 = jnp.max(p_wo_top, axis=-1, keepdims=True)  # [Bb, 1]

    lc = 1.0 - p1[:, 0]
    margin = p1[:, 0] - p2[:, 0]
    ratio = p2[:, 0] / p1[:, 0]
    plogp = jnp.where(p > 0, p * jnp.log(p), 0.0)
    entropy = -jnp.sum(plogp, axis=-1)

    out_ref[...] = jnp.stack([lc, margin, ratio, entropy], axis=-1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def uncertainty_scores(logits: jnp.ndarray, *, block_b: int = 128) -> jnp.ndarray:
    """Fused uncertainty scores for a batch of logits.

    Args:
        logits: [B, C] float array.
        block_b: batch-tile size; B is padded up to a multiple of it.

    Returns:
        [B, 4] float32 scores (columns per ref.SCORE_NAMES).
    """
    b, c = logits.shape
    bb = min(block_b, _next_pow2(b))
    b_pad = pl.cdiv(b, bb) * bb
    if b_pad != b:
        # Padding rows are scored too (garbage in, garbage out) and sliced
        # away below; they never influence real rows.
        logits = jnp.pad(logits, ((0, b_pad - b), (0, 0)))

    out = pl.pallas_call(
        _scores_kernel,
        grid=(b_pad // bb,),
        in_specs=[pl.BlockSpec((bb, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, NUM_SCORES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, NUM_SCORES), jnp.float32),
        interpret=True,
    )(logits)
    return out[:b]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p

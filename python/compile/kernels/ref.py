"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance (see python/tests/test_kernels.py,
which sweeps shapes/dtypes with hypothesis). They are also the "roofline
reference" used by the §Perf analysis in EXPERIMENTS.md.
"""

from __future__ import annotations

import jax.numpy as jnp

# Order of the per-sample uncertainty scores emitted by the fused kernel.
# Strategies on the Rust side index into this (keep in sync with
# rust/src/strategies/mod.rs::ScoreColumn).
SCORE_NAMES = ("least_confidence", "margin", "ratio", "entropy")


def uncertainty_scores_ref(logits: jnp.ndarray) -> jnp.ndarray:
    """Fused softmax + 4 AL uncertainty scores.

    Args:
        logits: [B, C] float array of raw classifier outputs.

    Returns:
        [B, 4] float32 scores, columns per SCORE_NAMES:
          * least_confidence: 1 - max_c p_c          (higher = more uncertain)
          * margin:           p_(1) - p_(2)          (lower  = more uncertain)
          * ratio:            p_(2) / p_(1)          (higher = more uncertain)
          * entropy:          -sum_c p_c log p_c     (higher = more uncertain)
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    p = e / z

    top2 = jnp.sort(p, axis=-1)[:, -2:]  # [B, 2]: (second, first)
    p2, p1 = top2[:, 0], top2[:, 1]

    lc = 1.0 - p1
    margin = p1 - p2
    ratio = p2 / p1
    # p log p with the 0*log(0) = 0 convention.
    plogp = jnp.where(p > 0, p * jnp.log(p), 0.0)
    entropy = -jnp.sum(plogp, axis=-1)

    return jnp.stack([lc, margin, ratio, entropy], axis=-1).astype(jnp.float32)


def pairwise_sqdist_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared Euclidean distances.

    Args:
        x: [M, D] float array.
        y: [N, D] float array.

    Returns:
        [M, N] float32, out[i, j] = ||x_i - y_j||^2, clamped at 0.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)  # [M]
    yy = jnp.sum(y * y, axis=-1)  # [N]
    cross = x @ y.T  # [M, N]
    d = xx[:, None] + yy[None, :] - 2.0 * cross
    return jnp.maximum(d, 0.0)

"""L1 Pallas kernel: tiled pairwise squared Euclidean distance.

The diversity-based strategies (K-Center Greedy, Core-Set, DBAL's k-means)
are dominated by pairwise distances between embedding sets. The paper calls
Core-Set's "heavy design" the throughput floor of Fig 4b — this kernel is
that hot spot.

Formulation: ||x_i - y_j||^2 = ||x_i||^2 + ||y_j||^2 - 2 x_i·y_j. The cross
term is a matmul, which is the whole point of the TPU adaptation
(DESIGN.md §Hardware-Adaptation): a CUDA implementation tiles x/y into
shared memory per threadblock; here the BlockSpec grid tiles the [M, N]
output into [Tm, Tn] VMEM blocks, and the [Tm, D] x [D, Tn] cross term is
an MXU systolic-array matmul with f32 accumulation. The row/col norms are
computed in-tile (D is small: one VMEM-resident strip), so nothing but x/y
tiles and the output tile ever occupy VMEM.

interpret=True as everywhere (see uncertainty.py); numerics vs.
ref.pairwise_sqdist_ref enforced by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(x_ref, y_ref, out_ref):
    """One grid step: distances between a [Tm, D] and a [Tn, D] tile."""
    x = x_ref[...].astype(jnp.float32)  # [Tm, D]
    y = y_ref[...].astype(jnp.float32)  # [Tn, D]

    xx = jnp.sum(x * x, axis=-1)  # [Tm]
    yy = jnp.sum(y * y, axis=-1)  # [Tn]
    # MXU: [Tm, D] @ [D, Tn], f32 accumulate.
    cross = jax.lax.dot_general(
        x,
        y,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Tm, Tn]
    d = xx[:, None] + yy[None, :] - 2.0 * cross
    out_ref[...] = jnp.maximum(d, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def pairwise_sqdist(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
) -> jnp.ndarray:
    """Tiled pairwise squared distances.

    Args:
        x: [M, D] float array.
        y: [N, D] float array (same D).
        block_m / block_n: output tile shape; M and N are padded up.

    Returns:
        [M, N] float32, out[i, j] = ||x_i - y_j||^2, clamped at 0.
    """
    m, d = x.shape
    n, d2 = y.shape
    if d != d2:
        raise ValueError(f"feature dims differ: {d} vs {d2}")

    tm = min(block_m, _next_pow2(m))
    tn = min(block_n, _next_pow2(n))
    m_pad = pl.cdiv(m, tm) * tm
    n_pad = pl.cdiv(n, tn) * tn
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    if n_pad != n:
        y = jnp.pad(y, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(m_pad // tm, n_pad // tn),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        interpret=True,
    )(x, y)
    return out[:m, :n]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p

"""L2 JAX model: feature extractor, serving forward pass, last-layer training.

This is the ALaaS compute graph. The paper's setup is a *pretrained*
ResNet-18 trunk whose last layer is fine-tuned on AL-selected samples; only
the trunk's embeddings matter to the system (Triton extracts them, the
strategies consume them). Our stand-in trunk (DESIGN.md §Substitutions) is a
fixed-seed patch-embedding MLP: deterministic "pretrained" weights are baked
into the lowered HLO as constants, so the artifact is self-contained and the
Rust side never ships weights for the trunk.

Entry points lowered by aot.py (all shapes static; one artifact per batch
variant):

  * embed(images)                        -> embeddings            (trunk only)
  * forward(images, w, b)                -> (embeddings, scores)  (serving hot
        path: trunk + linear head + the fused Pallas uncertainty kernel)
  * scores(logits)                       -> scores                (kernel only)
  * sqdist(x, y)                         -> distances             (kernel only)
  * train_step(w, b, x, y_onehot, lr)    -> (w', b', loss)        (fine-tune)
  * eval_logits(x, w, b)                 -> logits                 (evaluation)

Python never runs at serving time: these are lowered once by `make
artifacts` and executed from Rust via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.distance import pairwise_sqdist
from .kernels.uncertainty import uncertainty_scores

# Canonical model geometry (keep in sync with rust/src/runtime/artifact.rs).
IMG_SIDE = 32
IMG_CHANNELS = 3
IMG_DIM = IMG_SIDE * IMG_SIDE * IMG_CHANNELS  # 3072, flattened u8->f32 image
PATCH = 4
N_PATCHES = (IMG_SIDE // PATCH) * (IMG_SIDE // PATCH)  # 64
PATCH_DIM = PATCH * PATCH * IMG_CHANNELS  # 48
EMBED_DIM = 64  # trunk output / last-layer input
HIDDEN_DIM = 128
NUM_CLASSES = 10
TRUNK_SEED = 20220718  # fixed: the "pretrained" checkpoint identity


def trunk_params(seed: int = TRUNK_SEED) -> dict[str, jnp.ndarray]:
    """Deterministic 'pretrained' trunk weights.

    Scaled-Gaussian init with a fixed seed stands in for the torchvision
    checkpoint: what matters for the reproduction is that the trunk is a
    *fixed* nonlinear map shared by every experiment, not its training
    provenance.
    """
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)

    def dense(key, fan_in, fan_out):
        scale = (2.0 / fan_in) ** 0.5
        return scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)

    return {
        "patch_w": dense(k1, PATCH_DIM, EMBED_DIM),
        "patch_b": jnp.zeros((EMBED_DIM,), jnp.float32),
        "mlp_w1": dense(k2, EMBED_DIM, HIDDEN_DIM),
        "mlp_b1": jnp.zeros((HIDDEN_DIM,), jnp.float32),
        "mlp_w2": dense(k3, HIDDEN_DIM, EMBED_DIM),
        "mlp_b2": jnp.zeros((EMBED_DIM,), jnp.float32),
        # A touch of positional information so the patch pooling is not
        # permutation-blind (keeps the synthetic datasets' spatial structure
        # visible to the embeddings).
        "pos": 0.02 * jax.random.normal(k4, (N_PATCHES, EMBED_DIM), jnp.float32),
    }


def _patches(images: jnp.ndarray) -> jnp.ndarray:
    """[B, 3072] flat HWC images -> [B, N_PATCHES, PATCH_DIM] patch rows."""
    b = images.shape[0]
    x = images.reshape(b, IMG_SIDE, IMG_SIDE, IMG_CHANNELS)
    g = IMG_SIDE // PATCH
    x = x.reshape(b, g, PATCH, g, PATCH, IMG_CHANNELS)
    x = x.transpose(0, 1, 3, 2, 4, 5)  # [B, g, g, PATCH, PATCH, C]
    return x.reshape(b, N_PATCHES, PATCH_DIM)


def _layernorm(x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def embed(images: jnp.ndarray, params: dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    """Trunk forward: [B, 3072] f32 images -> [B, EMBED_DIM] embeddings."""
    p = trunk_params() if params is None else params
    x = _patches(images)  # [B, 64, 48]
    x = jax.nn.gelu(x @ p["patch_w"] + p["patch_b"]) + p["pos"]  # [B, 64, 64]
    x = jnp.mean(x, axis=1)  # [B, 64] mean-pool over patches
    h = jax.nn.gelu(x @ p["mlp_w1"] + p["mlp_b1"])
    x = x + h @ p["mlp_w2"] + p["mlp_b2"]  # residual
    return _layernorm(x)


def logits_head(emb: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Fine-tuned last layer: [B, D] x [D, C] + [C] -> [B, C]."""
    return emb @ w + b


def forward(images: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """Serving hot path: images -> (embeddings, uncertainty scores).

    One fused graph per batch variant so the Rust pipeline makes a single
    PJRT call per batch: trunk -> linear head -> Pallas score kernel.
    """
    e = embed(images)
    lg = logits_head(e, w, b)
    s = uncertainty_scores(lg)
    return e, s


def scores(logits: jnp.ndarray) -> jnp.ndarray:
    """Standalone fused score kernel entry point (logits -> [B, 4])."""
    return uncertainty_scores(logits)


def sqdist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Standalone pairwise-sqdist entry point ([M, D], [N, D] -> [M, N])."""
    return pairwise_sqdist(x, y)


def _xent(w, b, x, y_onehot):
    lg = logits_head(x, w, b)
    logp = jax.nn.log_softmax(lg, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def train_step(w, b, x, y_onehot, lr):
    """One SGD fine-tuning step on the last layer.

    Args:
        w: [D, C] weights.   b: [C] bias.
        x: [Bt, D] embedding minibatch.
        y_onehot: [Bt, C] labels; all-zero rows are padding and contribute
            no gradient (their xent term is 0) — the Rust trainer pads the
            tail minibatch with zero rows instead of compiling more shapes.
        lr: [] learning rate scalar.

    Returns:
        (w', b', loss).
    """
    # Padding rows have sum(y)=0; normalize by the number of real rows.
    n_real = jnp.maximum(jnp.sum(y_onehot), 1.0)

    def loss_fn(params):
        wi, bi = params
        lg = logits_head(x, wi, bi)
        logp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.sum(y_onehot * logp) / n_real

    loss, grads = jax.value_and_grad(loss_fn)((w, b))
    gw, gb = grads
    return w - lr * gw, b - lr * gb, loss


def eval_logits(x, w, b):
    """Evaluation forward on precomputed embeddings: [Be, D] -> [Be, C]."""
    return logits_head(x, w, b)

"""AOT compile path: lower every model entry point to HLO text artifacts.

This is the only place Python touches the system. `make artifacts` runs it
once; the Rust coordinator then loads `artifacts/*.hlo.txt` through the
`xla` crate's PJRT CPU client and never imports Python again.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md). We lower via
StableHLO -> XlaComputation with return_tuple=True; the Rust side unwraps
the tuple.

HLO is shape-specialized, so batched entry points are emitted once per
batch-size variant; the Rust dynamic batcher pads each batch up to the
nearest compiled variant. `artifacts/manifest.json` indexes every artifact
with its input/output specs for the Rust ArtifactRegistry.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Batch-size variants for the serving-path entry points. Must cover the
# batcher's max batch; keep in sync with rust/src/runtime/artifact.rs.
BATCH_VARIANTS = (1, 2, 4, 8, 16, 32, 64, 128)
# Tile size for the standalone distance executable (pool x centers tiling
# is done on the Rust side).
DIST_TILE = 256
# Fine-tune minibatch and eval batch (fixed; Rust pads the tail).
TRAIN_BATCH = 64
EVAL_BATCH = 256

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_points():
    """Yield (name, fn, arg_specs, input_names, output_names)."""
    d, c = model.EMBED_DIM, model.NUM_CLASSES

    for bs in BATCH_VARIANTS:
        yield (
            f"embed_b{bs}",
            model.embed,
            (_spec(bs, model.IMG_DIM),),
            ["images"],
            ["embeddings"],
        )
        yield (
            f"forward_b{bs}",
            model.forward,
            (_spec(bs, model.IMG_DIM), _spec(d, c), _spec(c)),
            ["images", "w", "b"],
            ["embeddings", "scores"],
        )
        yield (
            f"scores_b{bs}",
            model.scores,
            (_spec(bs, c),),
            ["logits"],
            ["scores"],
        )

    yield (
        f"sqdist_t{DIST_TILE}",
        model.sqdist,
        (_spec(DIST_TILE, d), _spec(DIST_TILE, d)),
        ["x", "y"],
        ["sqdist"],
    )
    yield (
        "train_step",
        model.train_step,
        (_spec(d, c), _spec(c), _spec(TRAIN_BATCH, d), _spec(TRAIN_BATCH, c), _spec()),
        ["w", "b", "x", "y_onehot", "lr"],
        ["w_out", "b_out", "loss"],
    )
    yield (
        f"eval_logits_b{EVAL_BATCH}",
        model.eval_logits,
        (_spec(EVAL_BATCH, d), _spec(d, c), _spec(c)),
        ["x", "w", "b"],
        ["logits"],
    )


def lower_all(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {
        "format": "hlo-text/return-tuple",
        "model": {
            "img_dim": model.IMG_DIM,
            "embed_dim": model.EMBED_DIM,
            "num_classes": model.NUM_CLASSES,
            "trunk_seed": model.TRUNK_SEED,
            "batch_variants": list(BATCH_VARIANTS),
            "dist_tile": DIST_TILE,
            "train_batch": TRAIN_BATCH,
            "eval_batch": EVAL_BATCH,
        },
        "artifacts": {},
    }
    for name, fn, specs, in_names, out_names in entry_points():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": "f32"}
                for n, s in zip(in_names, specs)
            ],
            "outputs": out_names,
        }
        print(f"  {name:24s} -> {fname} ({len(text)} chars)")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact output dir")
    args = ap.parse_args()
    manifest = lower_all(args.outdir)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json to {args.outdir}")


if __name__ == "__main__":
    main()

"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal for the compute layer: hypothesis
sweeps shapes, dtypes, block sizes and value scales, and every case must
match the ref.py oracle to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.distance import pairwise_sqdist
from compile.kernels.ref import (
    SCORE_NAMES,
    pairwise_sqdist_ref,
    uncertainty_scores_ref,
)
from compile.kernels.uncertainty import NUM_SCORES, uncertainty_scores

# interpret-mode pallas is slow; keep hypothesis examples small but varied.
SETTINGS = settings(max_examples=25, deadline=None)


def _logits(seed: int, b: int, c: int, scale: float, dtype) -> jnp.ndarray:
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, c), jnp.float32) * scale
    return x.astype(dtype)


class TestUncertaintyScores:
    @SETTINGS
    @given(
        seed=st.integers(0, 2**31 - 1),
        b=st.integers(1, 200),
        c=st.integers(2, 40),
        scale=st.sampled_from([0.1, 1.0, 5.0, 20.0]),
    )
    def test_matches_ref(self, seed, b, c, scale):
        lg = _logits(seed, b, c, scale, jnp.float32)
        got = uncertainty_scores(lg)
        want = uncertainty_scores_ref(lg)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)

    @SETTINGS
    @given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 64))
    def test_bfloat16_inputs(self, seed, b):
        lg = _logits(seed, b, 10, 3.0, jnp.bfloat16)
        got = uncertainty_scores(lg)
        want = uncertainty_scores_ref(lg)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @SETTINGS
    @given(
        seed=st.integers(0, 2**31 - 1),
        block_b=st.sampled_from([1, 2, 8, 32, 128, 256]),
    )
    def test_block_size_invariant(self, seed, block_b):
        """Tiling must not change the numbers."""
        lg = _logits(seed, 77, 10, 4.0, jnp.float32)
        base = uncertainty_scores(lg, block_b=128)
        got = uncertainty_scores(lg, block_b=block_b)
        np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-7)

    def test_output_shape_and_columns(self):
        lg = _logits(0, 9, 10, 1.0, jnp.float32)
        out = uncertainty_scores(lg)
        assert out.shape == (9, NUM_SCORES)
        assert out.dtype == jnp.float32
        assert len(SCORE_NAMES) == NUM_SCORES

    def test_uniform_logits_extremes(self):
        """Uniform distribution: max uncertainty on every score."""
        c = 10
        lg = jnp.zeros((3, c), jnp.float32)
        out = np.asarray(uncertainty_scores(lg))
        np.testing.assert_allclose(out[:, 0], 1 - 1 / c, atol=1e-6)  # LC
        np.testing.assert_allclose(out[:, 1], 0.0, atol=1e-6)  # margin
        np.testing.assert_allclose(out[:, 2], 1.0, atol=1e-6)  # ratio
        np.testing.assert_allclose(out[:, 3], np.log(c), atol=1e-5)  # entropy

    def test_peaked_logits_extremes(self):
        """Near-one-hot: min uncertainty on every score."""
        lg = jnp.array([[50.0] + [0.0] * 9], jnp.float32)
        out = np.asarray(uncertainty_scores(lg))
        assert out[0, 0] < 1e-6  # LC ~ 0
        assert out[0, 1] > 1 - 1e-6  # margin ~ 1
        assert out[0, 2] < 1e-6  # ratio ~ 0
        assert out[0, 3] < 1e-5  # entropy ~ 0

    def test_tie_in_top_probs(self):
        """Exact two-way tie: margin 0, ratio 1 (argmax knockout is stable)."""
        lg = jnp.array([[3.0, 3.0, 0.0, 0.0]], jnp.float32)
        out = np.asarray(uncertainty_scores(lg))
        want = np.asarray(uncertainty_scores_ref(lg))
        np.testing.assert_allclose(out, want, rtol=1e-6)
        np.testing.assert_allclose(out[0, 1], 0.0, atol=1e-6)
        np.testing.assert_allclose(out[0, 2], 1.0, atol=1e-6)


class TestPairwiseSqdist:
    @SETTINGS
    @given(
        seed=st.integers(0, 2**31 - 1),
        m=st.integers(1, 200),
        n=st.integers(1, 200),
        d=st.sampled_from([1, 3, 16, 64]),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
    )
    def test_matches_ref(self, seed, m, n, d, scale):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, d), jnp.float32) * scale
        y = jax.random.normal(ky, (n, d), jnp.float32) * scale
        got = pairwise_sqdist(x, y)
        want = pairwise_sqdist_ref(x, y)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)

    @SETTINGS
    @given(
        seed=st.integers(0, 2**31 - 1),
        bm=st.sampled_from([1, 16, 64, 128]),
        bn=st.sampled_from([1, 16, 64, 128]),
    )
    def test_tile_invariant(self, seed, bm, bn):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (90, 32), jnp.float32)
        y = jax.random.normal(ky, (70, 32), jnp.float32)
        base = pairwise_sqdist(x, y, block_m=128, block_n=128)
        got = pairwise_sqdist(x, y, block_m=bm, block_n=bn)
        np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-5)

    def test_self_distance_zero_diagonal(self):
        x = jax.random.normal(jax.random.PRNGKey(7), (50, 64), jnp.float32)
        d = np.asarray(pairwise_sqdist(x, x))
        np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)
        assert (d >= 0).all()

    def test_symmetry(self):
        kx, ky = jax.random.split(jax.random.PRNGKey(8))
        x = jax.random.normal(kx, (33, 16), jnp.float32)
        y = jax.random.normal(ky, (21, 16), jnp.float32)
        dxy = np.asarray(pairwise_sqdist(x, y))
        dyx = np.asarray(pairwise_sqdist(y, x))
        np.testing.assert_allclose(dxy, dyx.T, rtol=1e-5, atol=1e-5)

    def test_hand_computed(self):
        x = jnp.array([[0.0, 0.0], [1.0, 1.0]])
        y = jnp.array([[0.0, 1.0], [2.0, 0.0], [1.0, 1.0]])
        want = np.array([[1.0, 4.0, 2.0], [1.0, 2.0, 0.0]])
        np.testing.assert_allclose(pairwise_sqdist(x, y), want, atol=1e-6)

    def test_mismatched_dims_raise(self):
        x = jnp.zeros((4, 8))
        y = jnp.zeros((4, 9))
        with pytest.raises(ValueError):
            pairwise_sqdist(x, y)

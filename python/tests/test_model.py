"""L2 correctness: model shapes, trunk determinism, train-step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

SETTINGS = settings(max_examples=10, deadline=None)


def _images(seed: int, b: int) -> jnp.ndarray:
    # Matches the Rust preprocessing: u8/255 - 0.5 in [-0.5, 0.5].
    u = jax.random.randint(jax.random.PRNGKey(seed), (b, model.IMG_DIM), 0, 256)
    return u.astype(jnp.float32) / 255.0 - 0.5


class TestTrunk:
    def test_embed_shape_and_norm(self):
        e = model.embed(_images(0, 16))
        assert e.shape == (16, model.EMBED_DIM)
        # Layernormed output: per-row mean ~ 0, var ~ 1.
        np.testing.assert_allclose(np.asarray(e).mean(axis=1), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(e).var(axis=1), 1.0, atol=1e-2)

    def test_deterministic_pretrained_weights(self):
        """Same seed -> identical trunk: the 'checkpoint' is reproducible."""
        e1 = model.embed(_images(1, 4))
        e2 = model.embed(_images(1, 4), params=model.trunk_params())
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))

    def test_different_seed_changes_trunk(self):
        e1 = model.embed(_images(1, 4))
        e2 = model.embed(_images(1, 4), params=model.trunk_params(seed=1))
        assert np.abs(np.asarray(e1) - np.asarray(e2)).max() > 1e-3

    @SETTINGS
    @given(b=st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128]))
    def test_batch_invariance(self, b):
        """Row i of a batch must equal the single-sample forward of row i:
        the batcher's padding must never leak across samples."""
        imgs = _images(2, b)
        full = model.embed(imgs)
        one = model.embed(imgs[:1])
        np.testing.assert_allclose(np.asarray(full[0]), np.asarray(one[0]), rtol=1e-5, atol=1e-5)


class TestForward:
    def test_shapes(self):
        w = jnp.zeros((model.EMBED_DIM, model.NUM_CLASSES))
        b = jnp.zeros((model.NUM_CLASSES,))
        e, s = model.forward(_images(3, 8), w, b)
        assert e.shape == (8, model.EMBED_DIM)
        assert s.shape == (8, 4)

    def test_zero_head_gives_uniform_scores(self):
        w = jnp.zeros((model.EMBED_DIM, model.NUM_CLASSES))
        b = jnp.zeros((model.NUM_CLASSES,))
        _, s = model.forward(_images(4, 5), w, b)
        s = np.asarray(s)
        c = model.NUM_CLASSES
        np.testing.assert_allclose(s[:, 0], 1 - 1 / c, atol=1e-6)
        np.testing.assert_allclose(s[:, 3], np.log(c), atol=1e-5)


class TestTrainStep:
    def _setup(self, seed=0, n=64):
        d, c = model.EMBED_DIM, model.NUM_CLASSES
        x = model.embed(_images(seed, n))
        y = jax.nn.one_hot(jnp.arange(n) % c, c)
        w = jnp.zeros((d, c))
        b = jnp.zeros((c,))
        return w, b, x, y

    def test_first_step_loss_is_log_c(self):
        w, b, x, y = self._setup()
        _, _, loss = model.train_step(w, b, x, y, jnp.float32(0.1))
        np.testing.assert_allclose(float(loss), np.log(model.NUM_CLASSES), atol=1e-5)

    def test_loss_decreases_over_steps(self):
        w, b, x, y = self._setup()
        losses = []
        for _ in range(50):
            w, b, loss = model.train_step(w, b, x, y, jnp.float32(0.5))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, losses[::10]

    def test_padding_rows_are_inert(self):
        """Zero one-hot rows (batch padding) must not change the update."""
        w, b, x, y = self._setup(n=32)
        pad_x = jnp.concatenate([x, jnp.ones((32, model.EMBED_DIM))])
        pad_y = jnp.concatenate([y, jnp.zeros((32, model.NUM_CLASSES))])
        w1, b1, l1 = model.train_step(w, b, x[:32], y[:32], jnp.float32(0.3))
        # train_step is shape-specialized at 64 in AOT, but the python fn is
        # polymorphic; compare a 32-real-row call vs 32 real + 32 pad.
        w2, b2, l2 = model.train_step(w, b, pad_x, pad_y, jnp.float32(0.3))
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)

    def test_learnable_problem_reaches_high_train_accuracy(self):
        """End-to-end sanity: last-layer fine-tuning on trunk embeddings of
        class-structured inputs must fit the training set."""
        d, c = model.EMBED_DIM, model.NUM_CLASSES
        n = 256
        # Class-conditional images: class k biases a block of the image.
        key = jax.random.PRNGKey(9)
        labels = jnp.arange(n) % c
        base = jax.random.uniform(key, (n, model.IMG_DIM)) - 0.5
        onehot_block = jax.nn.one_hot(labels, c)  # [n, c]
        rep = -(-model.IMG_DIM // c)  # ceil-div, then trim to IMG_DIM
        bias = jnp.repeat(onehot_block, rep, axis=1)[:, : model.IMG_DIM] * 0.6
        x = model.embed(base + bias)
        y = jax.nn.one_hot(labels, c)
        w = jnp.zeros((d, c))
        b = jnp.zeros((c,))
        for _ in range(500):
            w, b, _ = model.train_step(w, b, x, y, jnp.float32(1.0))
        acc = float(jnp.mean(jnp.argmax(model.eval_logits(x, w, b), -1) == labels))
        assert acc > 0.8, acc


class TestEval:
    def test_eval_logits_matches_head(self):
        d, c = model.EMBED_DIM, model.NUM_CLASSES
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
        x = jax.random.normal(k1, (17, d))
        w = jax.random.normal(k2, (d, c))
        b = jax.random.normal(k3, (c,))
        np.testing.assert_allclose(
            np.asarray(model.eval_logits(x, w, b)), np.asarray(x @ w + b), rtol=1e-5, atol=1e-5
        )

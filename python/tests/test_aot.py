"""AOT path: lowering produces loadable HLO text + a complete manifest."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(outdir))
    return str(outdir), manifest


class TestManifest:
    def test_every_artifact_listed_and_present(self, built):
        outdir, manifest = built
        assert manifest["artifacts"], "no artifacts lowered"
        for name, meta in manifest["artifacts"].items():
            path = os.path.join(outdir, meta["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 0, name

    def test_manifest_json_round_trips(self, built):
        outdir, manifest = built
        with open(os.path.join(outdir, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest

    def test_batch_variants_cover_serving_entry_points(self, built):
        _, manifest = built
        for bs in aot.BATCH_VARIANTS:
            for ep in ("embed", "forward", "scores"):
                assert f"{ep}_b{bs}" in manifest["artifacts"]

    def test_special_entry_points_present(self, built):
        _, manifest = built
        arts = manifest["artifacts"]
        assert f"sqdist_t{aot.DIST_TILE}" in arts
        assert "train_step" in arts
        assert f"eval_logits_b{aot.EVAL_BATCH}" in arts

    def test_input_specs_match_model_geometry(self, built):
        _, manifest = built
        fwd = manifest["artifacts"]["forward_b16"]
        shapes = {i["name"]: i["shape"] for i in fwd["inputs"]}
        assert shapes["images"] == [16, model.IMG_DIM]
        assert shapes["w"] == [model.EMBED_DIM, model.NUM_CLASSES]
        assert shapes["b"] == [model.NUM_CLASSES]
        assert fwd["outputs"] == ["embeddings", "scores"]


class TestHloText:
    def test_hlo_text_has_entry_computation(self, built):
        outdir, manifest = built
        for name, meta in manifest["artifacts"].items():
            with open(os.path.join(outdir, meta["file"])) as f:
                text = f.read()
            assert "ENTRY" in text, name
            assert "HloModule" in text, name

    def test_no_mosaic_custom_calls(self, built):
        """interpret=True pallas must lower to plain HLO the CPU PJRT
        client can run — a mosaic custom-call would only load on TPU."""
        outdir, manifest = built
        for name, meta in manifest["artifacts"].items():
            with open(os.path.join(outdir, meta["file"])) as f:
                text = f.read()
            assert "tpu_custom_call" not in text, name
            assert "mosaic" not in text.lower(), name

    def test_lowering_is_deterministic(self, built, tmp_path):
        """Same model + seed -> byte-identical HLO (sha in manifest)."""
        outdir, manifest = built
        again = aot.lower_all(str(tmp_path))
        for name, meta in manifest["artifacts"].items():
            assert again["artifacts"][name]["sha256"] == meta["sha256"], name

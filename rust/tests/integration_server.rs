//! Server-client integration over real TCP: the Figure 1 workflow
//! (push unlabeled data -> server processes -> query(budget) -> selected
//! samples) end to end on the host backend with an in-process store.

use std::sync::Arc;

use alaas::cache::DataCache;
use alaas::config::AlaasConfig;
use alaas::data::{generate_into_store, DatasetSpec, Oracle};
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::{AlClient, AlServer, ServerDeps, WireMode};
use alaas::store::{Manifest, ObjectStore, StoreRouter};

struct Harness {
    server: AlServer,
    manifest: Manifest,
    init_labels: Vec<u8>,
    store: Arc<StoreRouter>,
}

/// Start a server on an ephemeral port with a generated dataset living in
/// its s3sim store (default binary data plane).
fn harness(pool: usize) -> Harness {
    harness_wire(pool, WireMode::Binary)
}

fn harness_wire(pool: usize, wire: WireMode) -> Harness {
    let mut cfg = AlaasConfig::default();
    cfg.al_worker.host = "127.0.0.1".into();
    cfg.al_worker.port = 0; // ephemeral
    cfg.server.wire = wire;
    cfg.store.get_latency_us = 0;
    cfg.store.bandwidth_mib_s = 0.0;
    cfg.store.jitter = 0.0;

    let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));
    let spec = DatasetSpec::cifarsim(7).with_sizes(60, pool, 0);
    // write via the backing store (no latency), serve via s3sim URIs
    let backing: Arc<dyn ObjectStore> =
        Arc::new(NoopWrap(store.clone())) as Arc<dyn ObjectStore>;
    let manifest = generate_into_store(&spec, &backing, "s3sim", "it-ds");
    let oracle = Oracle::load(&backing, "it-ds").unwrap();
    let init_ids: Vec<u32> = manifest.init.iter().map(|s| s.id).collect();
    let init_labels = oracle.label(&init_ids);

    let deps = ServerDeps {
        store: store.clone(),
        cache: Arc::new(DataCache::new(256 << 20, 8, true)),
        backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
        metrics: Registry::new(),
    };
    let server = AlServer::start(cfg, deps).expect("server starts");
    Harness { server, manifest, init_labels, store }
}

/// Adapter: write dataset blobs through the router's s3sim *backing*
/// store (fast path) while the server reads them through s3sim.
struct NoopWrap(Arc<StoreRouter>);

impl ObjectStore for NoopWrap {
    fn get(&self, key: &str) -> alaas::store::StoreResult<Vec<u8>> {
        self.0.s3sim_backing().get(key)
    }
    fn put(&self, key: &str, data: &[u8]) -> alaas::store::StoreResult<()> {
        self.0.s3sim_backing().put(key, data)
    }
    fn exists(&self, key: &str) -> bool {
        self.0.s3sim_backing().exists(key)
    }
    fn list(&self, prefix: &str) -> alaas::store::StoreResult<Vec<String>> {
        self.0.s3sim_backing().list(prefix)
    }
    fn kind(&self) -> &'static str {
        "wrap"
    }
}

#[test]
fn full_push_query_workflow() {
    let h = harness(300);
    let addr = h.server.addr().to_string();
    let mut client = AlClient::connect(&addr).unwrap();
    client.ping().unwrap();

    client.push_data("s1", &h.manifest, Some(&h.init_labels)).unwrap();
    let (selected, strategy, _ms) = client.query("s1", 50, Some("least_confidence")).unwrap();
    assert_eq!(strategy, "least_confidence");
    assert_eq!(selected.len(), 50);
    // selections are distinct pool members
    let pool_ids: std::collections::HashSet<u32> =
        h.manifest.pool.iter().map(|s| s.id).collect();
    let mut seen = std::collections::HashSet::new();
    for s in &selected {
        assert!(pool_ids.contains(&s.id), "id {} not in pool", s.id);
        assert!(seen.insert(s.id), "duplicate id {}", s.id);
    }
    assert_eq!(client.status("s1").unwrap(), "ready");
}

#[test]
fn different_strategies_give_different_selections() {
    let h = harness(400);
    let mut client = AlClient::connect(&h.server.addr().to_string()).unwrap();
    client.push_data("s1", &h.manifest, Some(&h.init_labels)).unwrap();
    let (lc, _, _) = client.query("s1", 40, Some("least_confidence")).unwrap();
    let (rand, _, _) = client.query("s1", 40, Some("random")).unwrap();
    let (kcg, _, _) = client.query("s1", 40, Some("k_center_greedy")).unwrap();
    let ids = |v: &[alaas::store::SampleRef]| {
        let mut x: Vec<u32> = v.iter().map(|s| s.id).collect();
        x.sort_unstable();
        x
    };
    assert_ne!(ids(&lc), ids(&rand), "LC vs random should differ");
    assert_ne!(ids(&lc), ids(&kcg), "LC vs KCG should differ");
}

#[test]
fn query_is_deterministic_for_same_session() {
    let h = harness(200);
    let mut client = AlClient::connect(&h.server.addr().to_string()).unwrap();
    client.push_data("s1", &h.manifest, Some(&h.init_labels)).unwrap();
    let (a, _, _) = client.query("s1", 30, Some("entropy")).unwrap();
    let (b, _, _) = client.query("s1", 30, Some("entropy")).unwrap();
    assert_eq!(
        a.iter().map(|s| s.id).collect::<Vec<_>>(),
        b.iter().map(|s| s.id).collect::<Vec<_>>()
    );
}

#[test]
fn concurrent_clients_and_sessions() {
    let h = harness(200);
    let addr = h.server.addr().to_string();
    let manifest = h.manifest.clone();
    let labels = h.init_labels.clone();
    std::thread::scope(|s| {
        for t in 0..4 {
            let addr = addr.clone();
            let manifest = manifest.clone();
            let labels = labels.clone();
            s.spawn(move || {
                let mut c = AlClient::connect(&addr).unwrap();
                let session = format!("sess-{t}");
                c.push_data(&session, &manifest, Some(&labels)).unwrap();
                let (sel, _, _) = c.query(&session, 20, Some("margin_confidence")).unwrap();
                assert_eq!(sel.len(), 20);
            });
        }
    });
}

#[test]
fn error_paths_are_clean_rpc_errors() {
    let h = harness(50);
    let mut client = AlClient::connect(&h.server.addr().to_string()).unwrap();
    // unknown session
    let err = client.query("nope", 5, None).unwrap_err();
    assert!(format!("{err}").contains("unknown session"), "{err}");
    // unknown strategy
    client.push_data("s1", &h.manifest, Some(&h.init_labels)).unwrap();
    let err = client.query("s1", 5, Some("not_a_strategy")).unwrap_err();
    assert!(format!("{err}").contains("unknown strategy"), "{err}");
    // auto requires agent workflow
    let err = client.query("s1", 5, Some("auto")).unwrap_err();
    assert!(format!("{err}").contains("agent"), "{err}");
    // budget bigger than pool degrades to the whole pool
    let (sel, _, _) = client.query("s1", 10_000, Some("random")).unwrap();
    assert_eq!(sel.len(), 50);
    // connection still usable after errors
    client.ping().unwrap();
}

#[test]
fn bad_init_labels_rejected() {
    let h = harness(50);
    let mut client = AlClient::connect(&h.server.addr().to_string()).unwrap();
    let err = client.push_data("s1", &h.manifest, Some(&[1, 2, 3])).unwrap_err();
    assert!(format!("{err}").contains("init_labels"), "{err}");
}

#[test]
fn faulty_store_objects_are_skipped_not_fatal() {
    let h = harness(120);
    h.store.s3sim().inject_fault(Some("img_000070".into()));
    let mut client = AlClient::connect(&h.server.addr().to_string()).unwrap();
    client.push_data("s1", &h.manifest, Some(&h.init_labels)).unwrap();
    let (sel, _, _) = client.query("s1", 119, Some("random")).unwrap();
    // one pool sample poisoned -> selectable set is 119
    assert_eq!(sel.len(), 119);
    assert!(sel.iter().all(|s| !s.uri.contains("img_000070")));
}

#[test]
fn metrics_and_cache_stats_flow() {
    let h = harness(100);
    let mut client = AlClient::connect(&h.server.addr().to_string()).unwrap();
    client.push_data("s1", &h.manifest, Some(&h.init_labels)).unwrap();
    client.query("s1", 10, Some("random")).unwrap();
    let m = client.metrics().unwrap();
    assert!(m.get("histograms").is_some());
    assert!(m.path("meters.pipeline\u{2e}samples").is_none()); // dotted key is literal
    let meters = m.get("meters").unwrap();
    assert!(meters.get("pipeline.samples").is_some());
    let cs = client.cache_stats().unwrap();
    assert!(cs.get("misses").unwrap().as_i64().unwrap() > 0);
    let zoo = client.strategies().unwrap();
    assert!(zoo.contains(&"core_set".to_string()));
}

#[test]
fn wire_negotiation_and_selection_parity_across_modes() {
    let h = harness(150);
    let addr = h.server.addr().to_string();
    // default client negotiates the binary data plane via `hello`
    let mut bin = AlClient::connect(&addr).unwrap();
    assert_eq!(bin.wire_mode(), WireMode::Binary);
    // a forced-JSON client keeps speaking v1 frames
    let mut json = AlClient::connect_with_wire(&addr, WireMode::Json).unwrap();
    assert_eq!(json.wire_mode(), WireMode::Json);

    bin.push_data("b", &h.manifest, Some(&h.init_labels)).unwrap();
    json.push_data("j", &h.manifest, Some(&h.init_labels)).unwrap();
    let ids = |v: &[alaas::store::SampleRef]| -> Vec<u32> {
        v.iter().map(|s| s.id).collect()
    };
    let (a, _, _) = bin.query("b", 25, Some("entropy")).unwrap();
    let (b, _, _) = json.query("j", 25, Some("entropy")).unwrap();
    assert_eq!(ids(&a), ids(&b), "selection must not depend on the wire encoding");

    // binary frames actually flowed, and the wire metrics landed
    let m = bin.metrics().unwrap();
    let counters = m.get("counters").unwrap();
    let counter = |name: &str| -> i64 {
        counters.get(name).and_then(|v| v.as_i64()).unwrap_or(0)
    };
    assert!(counter("wire.frames.binary") > 0, "no v2 frames seen");
    assert!(counter("wire.frames.json") > 0, "no v1 frames seen");
    assert!(counter("wire.rx_bytes") > 0 && counter("wire.tx_bytes") > 0);
    assert!(m.get("histograms").unwrap().get("wire.decode").is_some());
    assert!(m.get("histograms").unwrap().get("wire.encode").is_some());
}

#[test]
fn json_forced_server_downgrades_binary_clients() {
    let h = harness_wire(80, WireMode::Json);
    let addr = h.server.addr().to_string();
    // the hello probe learns the server refuses binary; the session then
    // runs entirely on v1 frames
    let mut c = AlClient::connect(&addr).unwrap();
    assert_eq!(c.wire_mode(), WireMode::Json);
    c.push_data("s", &h.manifest, Some(&h.init_labels)).unwrap();
    let (sel, _, _) = c.query("s", 10, Some("least_confidence")).unwrap();
    assert_eq!(sel.len(), 10);
    let m = c.metrics().unwrap();
    let bin_frames = m
        .get("counters")
        .unwrap()
        .get("wire.frames.binary")
        .and_then(|v| v.as_i64())
        .unwrap_or(0);
    assert_eq!(bin_frames, 0, "a JSON-forced server should never see v2 frames");
}

#[test]
fn server_shutdown_is_clean() {
    let h = harness(30);
    let addr = h.server.addr();
    h.server.shutdown();
    // new connections should fail (or at least not serve)
    std::thread::sleep(std::time::Duration::from_millis(50));
    let c = std::net::TcpStream::connect_timeout(&addr, std::time::Duration::from_millis(200));
    if let Ok(stream) = c {
        // accept loop is gone; a request should not get a response
        let mut stream = stream;
        let _ = alaas::server::rpc::send_request(
            &mut stream,
            1,
            "ping",
            alaas::json::Value::Null,
        );
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(300)))
            .unwrap();
        let r = alaas::server::rpc::recv_response(&mut stream, 1);
        assert!(r.is_err(), "server answered after shutdown");
    }
}

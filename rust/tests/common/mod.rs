//! Shared helpers for the integration test crates. Each `tests/*.rs`
//! crate compiles this module independently (`mod common;`), so items
//! unused by one crate are expected — dead-code lints are allowed at
//! the module level in `cluster_harness`.

pub mod cluster_harness;

#![allow(dead_code)]
//! Shared cluster test harness (ISSUE 5 satellite): one dataset in a
//! shared simulated store, a coordinator + N workers on ephemeral
//! ports, an optional reference single server — plus scripted fault
//! injection: kill / gracefully retire / restart a worker, wedge one
//! (heartbeats stop, data-path sockets stay open), advance the
//! coordinator's membership clock (virtual-time lease expiry), and bind
//! any of those to a named point around the push/query flow. Every
//! fault is appended to a per-harness log under
//! `target/harness-logs/` (override with `ALAAS_HARNESS_LOG_DIR`), which
//! CI uploads on failure.
//!
//! Used by `integration_cluster.rs`, `integration_agent.rs`, and
//! `integration_membership.rs` in place of their previously copy-pasted
//! spawn/kill boilerplate.

use std::io::Write as _;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use alaas::cache::DataCache;
use alaas::cluster::{worker::register_with, Coordinator, CoordinatorDeps};
use alaas::config::AlaasConfig;
use alaas::data::{generate_into_store, DatasetSpec, Oracle};
use alaas::json::Value;
use alaas::metrics::Registry;
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::{AlClient, AlServer, ServerDeps, WireMode};
use alaas::store::{Manifest, ObjectStore, SampleRef, StoreRouter};

/// Write dataset blobs through the router's s3sim *backing* store (fast
/// path) while servers read them through s3sim URIs.
pub struct NoopWrap(pub Arc<StoreRouter>);

impl ObjectStore for NoopWrap {
    fn get(&self, key: &str) -> alaas::store::StoreResult<Vec<u8>> {
        self.0.s3sim_backing().get(key)
    }
    fn put(&self, key: &str, data: &[u8]) -> alaas::store::StoreResult<()> {
        self.0.s3sim_backing().put(key, data)
    }
    fn exists(&self, key: &str) -> bool {
        self.0.s3sim_backing().exists(key)
    }
    fn list(&self, prefix: &str) -> alaas::store::StoreResult<Vec<String>> {
        self.0.s3sim_backing().list(prefix)
    }
    fn kind(&self) -> &'static str {
        "wrap"
    }
}

/// Oracle labels for every split: init rides with pushes; pool/test are
/// the agent job's oracle arrays.
pub struct Labels {
    pub init: Vec<u8>,
    pub pool: Vec<u8>,
    pub test: Vec<u8>,
}

pub fn base_config() -> AlaasConfig {
    let mut cfg = AlaasConfig::default();
    cfg.al_worker.host = "127.0.0.1".into();
    cfg.al_worker.port = 0; // ephemeral
    cfg.store.get_latency_us = 0;
    cfg.store.bandwidth_mib_s = 0.0;
    cfg.store.jitter = 0.0;
    cfg
}

pub fn server_deps(store: Arc<StoreRouter>) -> ServerDeps {
    ServerDeps {
        store,
        cache: Arc::new(DataCache::new(256 << 20, 8, true)),
        backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
        metrics: Registry::new(),
    }
}

/// Generate a dataset into the shared store and collect every split's
/// oracle labels.
pub fn dataset(store: &Arc<StoreRouter>, spec: &DatasetSpec, bucket: &str) -> (Manifest, Labels) {
    let backing: Arc<dyn ObjectStore> =
        Arc::new(NoopWrap(store.clone())) as Arc<dyn ObjectStore>;
    let manifest = generate_into_store(spec, &backing, "s3sim", bucket);
    let oracle = Oracle::load(&backing, bucket).unwrap();
    let ids =
        |refs: &[SampleRef]| -> Vec<u32> { refs.iter().map(|s| s.id).collect() };
    let labels = Labels {
        init: oracle.label(&ids(&manifest.init)),
        pool: oracle.eval_labels(&ids(&manifest.pool)),
        test: oracle.eval_labels(&ids(&manifest.test)),
    };
    (manifest, labels)
}

/// Named points in the push/query flow where scripted faults fire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    BeforePush,
    AfterPush,
    BeforeQuery,
    AfterQuery,
}

/// Scripted fault actions (worker indices are harness slots).
#[derive(Debug, Clone)]
pub enum FaultAction {
    /// Crash: no deregister, heartbeats stop, sockets die.
    Kill(usize),
    /// Graceful retirement: deregister, then shut down.
    Leave(usize),
    /// Start a fresh server process on the worker's old port.
    Restart(usize),
    /// Wedge: heartbeats stop but the server keeps serving.
    Hang(usize),
    /// Un-wedge a hung worker (it re-joins the view).
    Resume(usize),
    /// Advance the coordinator's membership clock (virtual time).
    AdvanceMs(u64),
    /// Force one membership sweep (lease expiry + keepalive probes).
    Tick,
    /// Hard-kill the coordinator (no flush, WAL sealed mid-write — the
    /// `kill -9` simulation) and restart it on the same port over the
    /// same data dir, exercising WAL + snapshot recovery.
    CrashRestart,
}

struct WorkerHandle {
    server: Option<AlServer>,
    advertised: String,
    port: u16,
}

static HARNESS_SEQ: AtomicU64 = AtomicU64::new(0);

/// Builder for [`ClusterHarness`]; defaults match the historical
/// `integration_cluster` fixture (seed 7, 60-init/240-pool, 3 workers,
/// binary wire, membership off).
pub struct HarnessBuilder {
    data_seed: u64,
    sizes: (usize, usize, usize),
    bucket: String,
    n_workers: usize,
    coord_wire: WireMode,
    worker_wire: WireMode,
    membership: bool,
    heartbeat_ms: u64,
    lease_ms: u64,
    with_single: bool,
    durable: bool,
    coord_tweak: Option<Box<dyn Fn(&mut AlaasConfig)>>,
    cfg_tweak: Option<Box<dyn Fn(&mut AlaasConfig)>>,
}

impl HarnessBuilder {
    pub fn data_seed(mut self, s: u64) -> Self {
        self.data_seed = s;
        self
    }
    pub fn sizes(mut self, init: usize, pool: usize, test: usize) -> Self {
        self.sizes = (init, pool, test);
        self
    }
    pub fn bucket(mut self, b: &str) -> Self {
        self.bucket = b.to_string();
        self
    }
    pub fn workers(mut self, n: usize) -> Self {
        self.n_workers = n;
        self
    }
    pub fn wires(mut self, coord: WireMode, worker: WireMode) -> Self {
        self.coord_wire = coord;
        self.worker_wire = worker;
        self
    }
    /// Enable heartbeat/lease membership. The lease is deliberately long
    /// (60 s): in tests, expiry comes from virtual time
    /// (`advance_time_ms` + `tick`) or keepalive probes, never from a
    /// wall-clock race.
    pub fn membership(mut self, on: bool) -> Self {
        self.membership = on;
        self
    }
    pub fn lease(mut self, heartbeat_ms: u64, lease_ms: u64) -> Self {
        self.heartbeat_ms = heartbeat_ms;
        self.lease_ms = lease_ms;
        self
    }
    pub fn with_single(mut self, on: bool) -> Self {
        self.with_single = on;
        self
    }
    /// Give the coordinator a fresh durable data dir (WAL + snapshots)
    /// under `target/harness-data/` (override with
    /// `ALAAS_HARNESS_DATA_DIR`) — the prerequisite for
    /// [`FaultAction::CrashRestart`] /
    /// [`ClusterHarness::crash_restart_coordinator`].
    pub fn durable(mut self, on: bool) -> Self {
        self.durable = on;
        self
    }
    /// Mutate the coordinator's config before start (e.g. disable the
    /// connection pool).
    pub fn coord_tweak(mut self, f: impl Fn(&mut AlaasConfig) + 'static) -> Self {
        self.coord_tweak = Some(Box::new(f));
        self
    }
    /// Mutate the *base* config — workers, single server, and
    /// coordinator alike (e.g. flip `[observability] trace` cluster-wide).
    pub fn cfg_tweak(mut self, f: impl Fn(&mut AlaasConfig) + 'static) -> Self {
        self.cfg_tweak = Some(Box::new(f));
        self
    }

    pub fn build(self) -> ClusterHarness {
        let mut cfg = base_config();
        cfg.server.wire = self.worker_wire;
        if self.membership {
            cfg.cluster.membership.enabled = true;
            cfg.cluster.membership.heartbeat_ms = self.heartbeat_ms;
            cfg.cluster.membership.lease_ms = self.lease_ms;
        }
        if let Some(tweak) = &self.cfg_tweak {
            tweak(&mut cfg);
        }
        let store = Arc::new(StoreRouter::new("/tmp", &cfg.store));
        let spec = DatasetSpec::cifarsim(self.data_seed).with_sizes(
            self.sizes.0,
            self.sizes.1,
            self.sizes.2,
        );
        let (manifest, labels) = dataset(&store, &spec, &self.bucket);
        let log = HarnessLog::open(&self.bucket);

        let single = self
            .with_single
            .then(|| AlServer::start(cfg.clone(), server_deps(store.clone())).unwrap());

        let mut workers: Vec<WorkerHandle> = Vec::new();
        let mut coord_cfg = cfg.clone();
        coord_cfg.server.wire = self.coord_wire;
        if let Some(tweak) = &self.coord_tweak {
            tweak(&mut coord_cfg);
        }
        let data_dir = self.durable.then(|| {
            let base = std::env::var("ALAAS_HARNESS_DATA_DIR")
                .unwrap_or_else(|_| "target/harness-data".to_string());
            let seq = HARNESS_SEQ.fetch_add(1, Ordering::Relaxed);
            let path = PathBuf::from(base)
                .join(format!("{}-{}-{seq}", self.bucket, std::process::id()));
            std::fs::create_dir_all(&path).unwrap();
            path.display().to_string()
        });
        if let Some(dir) = &data_dir {
            coord_cfg.durability.enabled = true;
            coord_cfg.durability.data_dir = dir.clone();
        }
        let coordinator;
        let coord_metrics = Registry::new();
        if self.membership {
            // discovery order: coordinator first, workers join via
            // heartbeats
            coordinator = Coordinator::start(
                coord_cfg.clone(),
                CoordinatorDeps {
                    backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
                    metrics: coord_metrics.clone(),
                },
            )
            .unwrap();
            let coord_addr = coordinator.addr().to_string();
            for _ in 0..self.n_workers {
                let server =
                    AlServer::start(cfg.clone(), server_deps(store.clone())).unwrap();
                let advertised = server.addr().to_string();
                let port = server.addr().port();
                server.discover(&coord_addr, Some(&advertised));
                workers.push(WorkerHandle { server: Some(server), advertised, port });
            }
        } else {
            for _ in 0..self.n_workers {
                let server =
                    AlServer::start(cfg.clone(), server_deps(store.clone())).unwrap();
                let advertised = server.addr().to_string();
                let port = server.addr().port();
                workers.push(WorkerHandle { server: Some(server), advertised, port });
            }
            coord_cfg.cluster.workers =
                workers.iter().map(|w| w.advertised.clone()).collect();
            coordinator = Coordinator::start(
                coord_cfg.clone(),
                CoordinatorDeps {
                    backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
                    metrics: coord_metrics.clone(),
                },
            )
            .unwrap();
        }
        let coord_addr = coordinator.addr();
        let h = ClusterHarness {
            coordinator: Some(coordinator),
            coord_metrics,
            coord_addr,
            coord_cfg,
            cfg,
            data_dir,
            workers,
            single,
            manifest,
            labels,
            store,
            membership: self.membership,
            faults: Vec::new(),
            tracked_jobs: Mutex::new(Vec::new()),
            log,
        };
        if self.membership {
            h.wait_members(self.n_workers);
        }
        h.log(&format!(
            "harness up: coordinator {} + {} workers (membership={})",
            h.coord_addr,
            h.workers.len(),
            self.membership
        ));
        h
    }
}

/// Coordinator + N workers + shared dataset + scripted fault injection.
pub struct ClusterHarness {
    coordinator: Option<Coordinator>,
    pub coord_metrics: Arc<Registry>,
    pub coord_addr: SocketAddr,
    coord_cfg: AlaasConfig,
    cfg: AlaasConfig,
    /// Coordinator WAL + snapshot dir when built with `.durable(true)`.
    pub data_dir: Option<String>,
    workers: Vec<WorkerHandle>,
    single: Option<AlServer>,
    pub manifest: Manifest,
    pub labels: Labels,
    pub store: Arc<StoreRouter>,
    membership: bool,
    faults: Vec<(FaultPoint, FaultAction)>,
    /// Agent job ids registered via [`ClusterHarness::track_job`]:
    /// failure diagnostics dump each one's push-event buffer.
    tracked_jobs: Mutex<Vec<String>>,
    log: HarnessLog,
}

impl ClusterHarness {
    pub fn builder() -> HarnessBuilder {
        HarnessBuilder {
            data_seed: 7,
            sizes: (60, 240, 0),
            bucket: "cl-ds".into(),
            n_workers: 3,
            coord_wire: WireMode::Binary,
            worker_wire: WireMode::Binary,
            membership: false,
            heartbeat_ms: 50,
            lease_ms: 60_000,
            with_single: false,
            durable: false,
            coord_tweak: None,
            cfg_tweak: None,
        }
    }

    pub fn coordinator(&self) -> &Coordinator {
        self.coordinator.as_ref().expect("coordinator running")
    }

    pub fn client(&self) -> AlClient {
        AlClient::connect(&self.coord_addr.to_string()).unwrap()
    }

    pub fn single_addr(&self) -> String {
        self.single.as_ref().expect("harness built without a single server").addr().to_string()
    }

    pub fn single_client(&self) -> AlClient {
        AlClient::connect(&self.single_addr()).unwrap()
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn worker_addr(&self, i: usize) -> String {
        self.workers[i].advertised.clone()
    }

    pub fn worker_alive(&self, i: usize) -> bool {
        self.workers[i].server.is_some()
    }

    /// Reference to a live worker's server (its metrics, address, ...).
    pub fn worker(&self, i: usize) -> &AlServer {
        self.workers[i].server.as_ref().expect("worker is down")
    }

    pub fn log(&self, msg: &str) {
        self.log.line(msg);
    }

    // -- fault injection ---------------------------------------------------

    /// Crash worker `i`: heartbeats stop without a deregister and its
    /// sockets die — the coordinator must find out via redispatch
    /// failures, keepalive probes, or lease expiry.
    pub fn kill_worker(&mut self, i: usize) {
        self.log(&format!("KILL worker {i} ({})", self.workers[i].advertised));
        let server = self.workers[i].server.take().expect("worker already down");
        if let Some(hb) = server.take_heartbeater() {
            hb.stop_quiet();
        }
        server.shutdown();
    }

    /// Gracefully retire worker `i`: deregisters (membership) then shuts
    /// down, so rows rebalance without any lease wait.
    pub fn leave_worker(&mut self, i: usize) {
        self.log(&format!("LEAVE worker {i} ({})", self.workers[i].advertised));
        let server = self.workers[i].server.take().expect("worker already down");
        server.shutdown();
    }

    /// Restart a killed worker as a fresh process on its old port (it
    /// re-joins via discovery under membership).
    pub fn restart_worker(&mut self, i: usize) {
        assert!(self.workers[i].server.is_none(), "worker {i} is still up");
        self.log(&format!("RESTART worker {i} on port {}", self.workers[i].port));
        let mut cfg = self.cfg.clone();
        cfg.al_worker.port = self.workers[i].port;
        let server = AlServer::start(cfg, server_deps(self.store.clone())).unwrap();
        if self.membership {
            server.discover(&self.coord_addr.to_string(), Some(&self.workers[i].advertised));
        }
        self.workers[i].server = Some(server);
    }

    /// Wedge worker `i`: its heartbeats stop but the server keeps
    /// serving — keepalive probes still pass, so only *lease expiry*
    /// (virtual time) can evict it. The realistic stuck-process failure.
    pub fn hang_worker(&mut self, i: usize) {
        self.log(&format!("HANG worker {i} ({})", self.workers[i].advertised));
        if let Some(hb) = self.worker(i).take_heartbeater() {
            hb.stop_quiet();
        }
    }

    /// Un-wedge a hung worker: heartbeats resume and it re-joins the
    /// view as a fresh member.
    pub fn resume_worker(&mut self, i: usize) {
        self.log(&format!("RESUME worker {i} ({})", self.workers[i].advertised));
        let coord = self.coord_addr.to_string();
        let advertised = self.workers[i].advertised.clone();
        self.worker(i).discover(&coord, Some(&advertised));
    }

    /// Start an additional worker (not yet known to the coordinator).
    pub fn add_worker_unregistered(&mut self) -> usize {
        let server = AlServer::start(self.cfg.clone(), server_deps(self.store.clone())).unwrap();
        let advertised = server.addr().to_string();
        let port = server.addr().port();
        self.log(&format!("ADD worker {} ({advertised}, unregistered)", self.workers.len()));
        self.workers.push(WorkerHandle { server: Some(server), advertised, port });
        self.workers.len() - 1
    }

    /// Start an additional worker and join it to the cluster (heartbeat
    /// discovery under membership, one-shot register otherwise).
    pub fn spawn_worker(&mut self) -> usize {
        let i = self.add_worker_unregistered();
        let coord = self.coord_addr.to_string();
        let advertised = self.workers[i].advertised.clone();
        if self.membership {
            self.worker(i).discover(&coord, Some(&advertised));
        } else {
            register_with(&advertised, &coord).unwrap();
        }
        self.log(&format!("JOIN worker {i} ({advertised})"));
        i
    }

    /// Advance the coordinator's membership clock (virtual-time lease
    /// expiry — no wall-clock sleeps).
    pub fn advance_time_ms(&self, ms: u64) {
        self.log(&format!("ADVANCE clock +{ms}ms"));
        self.coordinator().advance_time(ms);
    }

    /// Force one membership sweep now (lease expiry + keepalive probes).
    pub fn tick(&self) {
        self.coordinator().membership_tick();
    }

    /// Restart the coordinator on its old port with the same metrics
    /// registry; sessions are lost (re-push), workers' heartbeat loops
    /// re-register on their own.
    pub fn restart_coordinator(&mut self) {
        let old = self.coordinator.take().expect("coordinator running");
        let port = self.coord_addr.port();
        self.log(&format!("RESTART coordinator on port {port}"));
        old.shutdown();
        let mut cfg = self.coord_cfg.clone();
        cfg.al_worker.port = port;
        cfg.cluster.workers = vec![]; // rediscovery, not static config
        let coordinator = start_with_bind_retry(cfg, self.coord_metrics.clone());
        self.coord_addr = coordinator.addr();
        self.coordinator = Some(coordinator);
    }

    /// Hard-kill the coordinator — nothing is flushed, completed, or
    /// deregistered; the WAL seals at this instant exactly as a `kill
    /// -9` would leave it — then restart it on the same port over the
    /// same data dir. With `.durable(true)` the restarted coordinator
    /// replays its snapshot + WAL: sessions come back without a re-push
    /// and in-flight agent jobs resume or report `interrupted`.
    pub fn crash_restart_coordinator(&mut self) {
        let old = self.coordinator.take().expect("coordinator running");
        let port = self.coord_addr.port();
        self.log(&format!(
            "CRASH-RESTART coordinator on port {port} (data dir {:?})",
            self.data_dir
        ));
        old.hard_kill();
        let mut cfg = self.coord_cfg.clone();
        cfg.al_worker.port = port;
        if self.membership {
            // rediscovery via worker heartbeat loops, not static config
            cfg.cluster.workers = vec![];
        }
        let coordinator = start_with_bind_retry(cfg, self.coord_metrics.clone());
        self.coord_addr = coordinator.addr();
        self.coordinator = Some(coordinator);
    }

    // -- membership observation --------------------------------------------

    /// Block until the membership view holds exactly `n` live members.
    pub fn wait_members(&self, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let (_, live) = self.coordinator().membership_snapshot();
            if live == n {
                return;
            }
            assert!(
                Instant::now() < deadline,
                "membership never settled at {n} members (currently {live})"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// Block until `addr` has left the view (ticking each poll so lease
    /// sweeps run even between background ticks).
    pub fn wait_member_gone(&self, addr: &str) {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            self.tick();
            let (_, members) = self.members_view();
            if !members.iter().any(|m| m == addr) {
                return;
            }
            assert!(
                Instant::now() < deadline,
                "member {addr} never left the view ({members:?})"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// `(generation, member addresses)` via the `members` RPC.
    pub fn members_view(&self) -> (u64, Vec<String>) {
        let mut c = self.client();
        let v = c.members().unwrap();
        let generation =
            v.get("generation").and_then(Value::as_usize).unwrap_or(0) as u64;
        let members = v
            .get("members")
            .and_then(Value::as_array)
            .map(|a| {
                a.iter()
                    .filter_map(|e| {
                        e.get("addr").and_then(Value::as_str).map(str::to_string)
                    })
                    .collect()
            })
            .unwrap_or_default();
        (generation, members)
    }

    /// Per-worker-address pool row counts of a session's current shard
    /// layout (`cluster_status`).
    pub fn shard_rows_by_worker(&self, session: &str) -> Vec<(String, usize)> {
        let mut c = self.client();
        let v = c.call("cluster_status", Value::Null).unwrap();
        let workers: Vec<String> = v
            .get("workers")
            .and_then(Value::as_array)
            .map(|a| {
                a.iter()
                    .filter_map(|w| {
                        w.get("addr").and_then(Value::as_str).map(str::to_string)
                    })
                    .collect()
            })
            .unwrap_or_default();
        let mut out = Vec::new();
        for s in v.get("sessions").and_then(Value::as_array).unwrap_or(&[]) {
            if s.get("session").and_then(Value::as_str) != Some(session) {
                continue;
            }
            for sh in s.get("shards").and_then(Value::as_array).unwrap_or(&[]) {
                let slot = sh.get("worker").and_then(Value::as_usize).unwrap_or(0);
                let rows = sh.get("pool_samples").and_then(Value::as_usize).unwrap_or(0);
                let addr = workers.get(slot).cloned().unwrap_or_default();
                out.push((addr, rows));
            }
        }
        out
    }

    /// A named counter from the coordinator's metrics registry.
    pub fn coord_counter(&self, name: &str) -> u64 {
        self.coord_metrics
            .counter(name)
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    // -- scripted flow -----------------------------------------------------

    /// Bind a fault action to a named point; it fires (once) when the
    /// flow helpers below pass that point.
    pub fn script(&mut self, point: FaultPoint, action: FaultAction) {
        self.faults.push((point, action));
    }

    /// Fire every scripted action bound to `point`.
    pub fn fire(&mut self, point: FaultPoint) {
        let mut due = Vec::new();
        self.faults.retain(|(p, a)| {
            if *p == point {
                due.push(a.clone());
                false
            } else {
                true
            }
        });
        for a in due {
            self.log(&format!("fault at {point:?}: {a:?}"));
            self.apply(a);
        }
    }

    fn apply(&mut self, a: FaultAction) {
        match a {
            FaultAction::Kill(i) => self.kill_worker(i),
            FaultAction::Leave(i) => self.leave_worker(i),
            FaultAction::Restart(i) => self.restart_worker(i),
            FaultAction::Hang(i) => self.hang_worker(i),
            FaultAction::Resume(i) => self.resume_worker(i),
            FaultAction::AdvanceMs(ms) => self.advance_time_ms(ms),
            FaultAction::Tick => self.tick(),
            FaultAction::CrashRestart => self.crash_restart_coordinator(),
        }
    }

    /// Push the harness dataset under `session`, firing the
    /// `BeforePush`/`AfterPush` fault points.
    pub fn push(&mut self, client: &mut AlClient, session: &str) {
        self.fire(FaultPoint::BeforePush);
        client.push_data(session, &self.manifest, Some(&self.labels.init)).unwrap();
        self.fire(FaultPoint::AfterPush);
    }

    /// Query selected ids, firing the `BeforeQuery`/`AfterQuery` points.
    pub fn query_ids(
        &mut self,
        client: &mut AlClient,
        session: &str,
        budget: usize,
        strategy: &str,
    ) -> Vec<u32> {
        self.fire(FaultPoint::BeforeQuery);
        let (sel, _, _) = client.query(session, budget, Some(strategy)).unwrap();
        self.fire(FaultPoint::AfterQuery);
        sel.iter().map(|s| s.id).collect()
    }

    // -- failure diagnostics -----------------------------------------------

    /// Capture the coordinator's recent traces + slow-query log and a
    /// Prometheus-style metrics snapshot into the harness log. Runs
    /// automatically when a test panics (the log dir is what CI uploads
    /// on failure), so a red integration run ships the span trees that
    /// explain *where* the request went sideways. Never panics: a dead
    /// coordinator degrades to an error line, not a double panic.
    /// Register an agent job id so [`ClusterHarness::dump_diagnostics`]
    /// includes its push-event buffer (`job_events`: retained sequence
    /// window + every buffered event) when a test fails.
    pub fn track_job(&self, id: &str) {
        self.tracked_jobs.lock().unwrap().push(id.to_string());
    }

    pub fn dump_diagnostics(&self, why: &str) {
        self.log(&format!("DIAGNOSTICS ({why}): trace_recent + metrics follow"));
        match AlClient::connect(&self.coord_addr.to_string()) {
            Ok(mut c) => {
                match c.trace_recent(50) {
                    Ok(v) => self
                        .log(&format!("coord trace_recent: {}", alaas::json::to_string(&v))),
                    Err(e) => self.log(&format!("coord trace_recent failed: {e}")),
                }
                for job in self.tracked_jobs.lock().unwrap().iter() {
                    let p = alaas::json::obj([("job", Value::from(job.clone()))]);
                    match c.call("job_events", p) {
                        Ok(v) => self.log(&format!(
                            "job {job} event buffer: {}",
                            alaas::json::to_string(&v)
                        )),
                        Err(e) => {
                            self.log(&format!("job {job} event buffer failed: {e}"))
                        }
                    }
                }
                match c.metrics_text() {
                    Ok(text) => {
                        for line in text.lines() {
                            self.log(&format!("coord metric {line}"));
                        }
                    }
                    Err(e) => self.log(&format!("coord metrics_text failed: {e}")),
                }
            }
            Err(e) => self.log(&format!("coordinator unreachable for diagnostics: {e}")),
        }
        for (i, w) in self.workers.iter().enumerate() {
            if w.server.is_none() {
                continue;
            }
            match AlClient::connect(&w.advertised) {
                Ok(mut c) => match c.trace_recent(20) {
                    Ok(v) => self.log(&format!(
                        "worker {i} trace_recent: {}",
                        alaas::json::to_string(&v)
                    )),
                    Err(e) => self.log(&format!("worker {i} trace_recent failed: {e}")),
                },
                Err(e) => self.log(&format!("worker {i} unreachable for diagnostics: {e}")),
            }
        }
    }
}

impl Drop for ClusterHarness {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.dump_diagnostics("test panicked");
        }
        self.log.line("harness down");
    }
}

/// Start a coordinator, retrying while the crashed predecessor's port
/// drains — a hard kill can leave the listener in TIME_WAIT briefly.
fn start_with_bind_retry(cfg: AlaasConfig, metrics: Arc<Registry>) -> Coordinator {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match Coordinator::start(
            cfg.clone(),
            CoordinatorDeps {
                backend: Arc::new(HostBackend::new()) as Arc<dyn ComputeBackend>,
                metrics: metrics.clone(),
            },
        ) {
            Ok(c) => return c,
            Err(e) if Instant::now() < deadline => {
                eprintln!("[harness] coordinator bind retry: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => panic!("coordinator restart never bound: {e}"),
        }
    }
}

/// Append-only per-harness log file (uploaded by CI on failure).
struct HarnessLog {
    path: PathBuf,
    file: Option<Mutex<std::fs::File>>,
    t0: Instant,
}

impl HarnessLog {
    fn open(tag: &str) -> HarnessLog {
        let dir = std::env::var("ALAAS_HARNESS_LOG_DIR")
            .unwrap_or_else(|_| "target/harness-logs".to_string());
        let seq = HARNESS_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = PathBuf::from(dir)
            .join(format!("{tag}-{}-{seq}.log", std::process::id()));
        let file = std::fs::create_dir_all(path.parent().unwrap())
            .ok()
            .and_then(|_| {
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&path)
                    .ok()
            })
            .map(Mutex::new);
        HarnessLog { path, file, t0: Instant::now() }
    }

    fn line(&self, msg: &str) {
        let stamped =
            format!("[{:9.3}s] {msg}", self.t0.elapsed().as_secs_f64());
        eprintln!("[harness] {stamped}");
        if let Some(f) = &self.file {
            let mut f = f.lock().unwrap();
            let _ = writeln!(f, "{stamped}");
        }
    }
}

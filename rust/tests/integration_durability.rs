//! Coordinator crash safety (DESIGN.md §Durability): a coordinator
//! started with a durable data dir must survive a hard kill — nothing
//! flushed, WAL sealed mid-stream — and come back with its sessions
//! re-homed and its in-flight PSHEA jobs either resumed or terminal.
//!
//! The headline pin: a coordinator hard-killed mid-agent-job, restarted
//! over the same data dir, resumes the job from its last completed
//! round and finishes with a trace **bit-identical** to an
//! uninterrupted in-process run — same elimination order, survivor,
//! and budget spend. Plus: deterministic re-selection on recovered
//! sessions (static re-home and membership rebalance paths), finished
//! jobs' results surviving a restart, and a torn WAL tail being
//! discarded without a panic.

mod common;

use std::sync::Arc;
use std::time::Duration;

use alaas::agent::{run_pshea, PsheaConfig, PsheaTrace};
use alaas::data::{generate, DatasetSpec};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;

use common::cluster_harness::ClusterHarness;

/// Same fixture as `integration_agent.rs`, so the in-process comparator
/// and the crash-resumed job see byte-identical data.
const DATA_SEED: u64 = 7;
const AGENT_SEED: u64 = 4242;
const N_INIT: usize = 60;
const N_POOL: usize = 240;
const N_TEST: usize = 120;

fn spec() -> DatasetSpec {
    DatasetSpec::cifarsim(DATA_SEED).with_sizes(N_INIT, N_POOL, N_TEST)
}

/// Unreachable target so the loop runs to its round limit; min_history
/// 2 so eliminations start at round 1 — the trace has real structure to
/// compare.
fn agent_cfg() -> PsheaConfig {
    PsheaConfig {
        target_accuracy: 2.0,
        max_budget: 1_000_000,
        round_budget: 20,
        converge_rounds: 0,
        converge_eps: 0.0,
        max_rounds: 4,
        min_history: 2,
        initial_accuracy: None,
    }
}

fn arm_names() -> Vec<String> {
    ["least_confidence", "margin_confidence", "entropy"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// Ground truth: Algorithm 1 run in-process, uninterrupted.
fn in_process_trace() -> PsheaTrace {
    let gen = generate(&spec());
    let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec().num_classes,
        TrainConfig::default(),
        AGENT_SEED,
    )
    .unwrap();
    run_pshea(&mut exp, &arm_names(), &agent_cfg()).unwrap()
}

fn elimination_order(t: &PsheaTrace) -> Vec<(usize, String)> {
    t.records
        .iter()
        .filter(|r| r.eliminated)
        .map(|r| (r.round, r.strategy.clone()))
        .collect()
}

fn assert_trace_parity(got: &PsheaTrace, want: &PsheaTrace, tag: &str) {
    assert_eq!(got.stop, want.stop, "{tag}: stop reason");
    assert_eq!(got.rounds, want.rounds, "{tag}: rounds-to-stop");
    assert_eq!(got.survivors, want.survivors, "{tag}: surviving strategy");
    assert_eq!(
        elimination_order(got),
        elimination_order(want),
        "{tag}: elimination order"
    );
    assert_eq!(got.total_budget, want.total_budget, "{tag}: budget spent");
    assert_eq!(got.records.len(), want.records.len(), "{tag}: record count");
    for (a, b) in got.records.iter().zip(&want.records) {
        assert_eq!((a.round, &a.strategy), (b.round, &b.strategy), "{tag}: record order");
        assert!(
            (a.accuracy - b.accuracy).abs() < 1e-9,
            "{tag}: round {} {} accuracy {} vs {}",
            a.round,
            a.strategy,
            a.accuracy,
            b.accuracy
        );
    }
    assert!((got.best_accuracy - want.best_accuracy).abs() < 1e-9, "{tag}: best accuracy");
}

fn durable_cluster(bucket: &str, n_workers: usize) -> ClusterHarness {
    ClusterHarness::builder()
        .bucket(bucket)
        .data_seed(DATA_SEED)
        .sizes(N_INIT, N_POOL, N_TEST)
        .workers(n_workers)
        .durable(true)
        .build()
}

/// The acceptance pin: hard-kill the coordinator while an agent job has
/// completed at least one round but not finished, restart it over the
/// same data dir, and the job resumes from its last completed round —
/// final trace bit-identical to the uninterrupted in-process run.
#[test]
fn coordinator_crash_mid_job_resumes_with_identical_trace() {
    let want = in_process_trace();
    // 3 arms, 2 eliminations, 1 survivor: the parity must have teeth
    assert_eq!(elimination_order(&want).len(), 2);
    assert_eq!(want.survivors.len(), 1);

    let mut h = durable_cluster("dur-resume", 2);
    let mut client = h.client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let job = client
        .agent_start("s", &arm_names(), &agent_cfg(), &h.labels.pool, &h.labels.test, AGENT_SEED)
        .unwrap();

    // wait for one *completed* round (so the resume point is mid-job,
    // not from scratch), then pull the plug while rounds remain
    let mut rounds = 0;
    for _ in 0..1_500 {
        let st = client.agent_status(&job).unwrap();
        rounds = st.get("rounds").unwrap().as_usize().unwrap();
        if rounds >= 1 || st.get("status").unwrap().as_str() != Some("running") {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(rounds >= 1, "job never completed a round");
    drop(client);
    h.crash_restart_coordinator();

    let mut client = h.client();
    let got = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    assert_trace_parity(&got, &want, "crash-resumed");
    let st = client.agent_status(&job).unwrap();
    assert_eq!(st.get("status").unwrap().as_str(), Some("done"));

    assert!(
        h.coord_counter("recovery.replayed_records") > 0,
        "restart did not replay the WAL"
    );
    assert_eq!(
        h.coord_counter("recovery.resumed_jobs"),
        1,
        "the in-flight job was not resumed from the WAL"
    );
}

/// Static worker table: a recovered session has no shard layout until
/// first use; the next scatter re-homes it and selection is identical
/// to the pre-crash layout (exact merges are layout-independent).
#[test]
fn crash_restart_recovers_sessions_without_repush() {
    let mut h = durable_cluster("dur-static", 2);
    let mut client = h.client();
    h.push(&mut client, "s");
    let before = h.query_ids(&mut client, "s", 25, "entropy");
    drop(client);

    h.crash_restart_coordinator();
    let mut client = h.client();
    // no re-push: the session must come back from the WAL
    let after = h.query_ids(&mut client, "s", 25, "entropy");
    assert_eq!(before, after, "recovered session selects differently");

    assert!(h.coord_counter("recovery.replayed_records") >= 2);
    assert!(
        h.coord_counter("recovery.rehomed_sessions") >= 1,
        "static re-home never ran"
    );
}

/// Live membership: workers' heartbeat loops re-register with the
/// restarted coordinator, the restored generation floor marks every
/// recovered layout stale, and the first query rebalances onto the
/// fresh view.
#[test]
fn crash_restart_under_membership_rehomes_via_rebalance() {
    let mut h = ClusterHarness::builder()
        .bucket("dur-mem")
        .data_seed(DATA_SEED)
        .sizes(N_INIT, N_POOL, N_TEST)
        .workers(3)
        .membership(true)
        .durable(true)
        .build();
    let mut client = h.client();
    h.push(&mut client, "s");
    let before = h.query_ids(&mut client, "s", 25, "entropy");
    drop(client);

    h.crash_restart_coordinator();
    h.wait_members(3);
    let mut client = h.client();
    let after = h.query_ids(&mut client, "s", 25, "entropy");
    assert_eq!(before, after, "recovered session selects differently");
    assert!(h.coord_counter("membership.rebalances") >= 1);
}

/// A job that finished *before* the crash replays as terminal: its
/// status and full trace come back from the WAL's `job_done` record —
/// no re-drive, no lost result.
#[test]
fn finished_job_result_survives_crash_restart() {
    let mut h = durable_cluster("dur-done", 2);
    let mut client = h.client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let cfg = PsheaConfig { max_rounds: 2, ..agent_cfg() };
    let strategies = vec!["entropy".to_string()];
    let job = client
        .agent_start("s", &strategies, &cfg, &h.labels.pool, &h.labels.test, AGENT_SEED)
        .unwrap();
    let want = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    drop(client);

    h.crash_restart_coordinator();
    let mut client = h.client();
    let st = client.agent_status(&job).unwrap();
    assert_eq!(st.get("status").unwrap().as_str(), Some("done"));
    let got = client.agent_result(&job, Duration::from_secs(60)).unwrap();
    assert_trace_parity(&got, &want, "replayed-done");
    assert_eq!(h.coord_counter("recovery.resumed_jobs"), 0);
}

/// Push-stream crash pin (ISSUE 10): a `job_subscribe` follower that
/// loses its connection to the hard-killed coordinator resubscribes
/// from its cursor against the restarted process and receives the rest
/// of the stream — 1-based contiguous seqs end to end, no gaps, no
/// duplicates, the `job_resume` marker included — and the full streamed
/// sequence equals the WAL's job-scoped records verbatim (the restart
/// re-seeds the event buffer from the same records it replays).
#[test]
fn subscriber_reconnects_across_coordinator_crash_without_gaps() {
    use alaas::durable::{DurabilityConfig, DurableLog};
    use alaas::json::Value;
    use alaas::server::JobEvent;

    let event_type = |ev: &Value| ev.get("t").and_then(Value::as_str).unwrap_or("");

    let mut h = ClusterHarness::builder()
        .bucket("dur-stream")
        .data_seed(DATA_SEED)
        .sizes(N_INIT, N_POOL, N_TEST)
        .workers(2)
        .durable(true)
        // keep every record in the WAL so the stream-vs-WAL comparison
        // sees the full physical sequence across both incarnations
        .coord_tweak(|c| c.durability.snapshot_every = 1_000_000)
        .build();
    let mut client = h.client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let job = client
        .agent_start("s", &arm_names(), &agent_cfg(), &h.labels.pool, &h.labels.test, AGENT_SEED)
        .unwrap();
    h.track_job(&job);

    let mut stream = client.subscribe_job(&job, 0).unwrap();
    let mut events: Vec<JobEvent> = Vec::new();
    // consume a few live events, then pull the plug mid-stream
    while events.len() < 3 {
        match stream.next() {
            Some(Ok(ev)) => events.push(ev),
            Some(Err(e)) => panic!("stream died before the crash: {e}"),
            None => panic!("job finished before the crash point"),
        }
    }
    let mut cursor = stream.cursor();
    drop(stream);
    drop(client);
    h.crash_restart_coordinator();

    // resubscribe from the cursor; the restarted coordinator re-seeds
    // the event buffer from its WAL, so the numbering continues exactly
    let mut client = h.client();
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    'outer: loop {
        assert!(std::time::Instant::now() < deadline, "stream never finished");
        let mut stream = match client.subscribe_job(&job, cursor) {
            Ok(s) => s,
            Err(_) => {
                // recovery may still be resuming the job
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        for item in stream.by_ref() {
            match item {
                Ok(ev) => {
                    cursor = ev.seq;
                    events.push(ev);
                }
                Err(_) => continue 'outer,
            }
        }
        assert_eq!(stream.end_reason(), Some("all events delivered"));
        break;
    }

    for (i, ev) in events.iter().enumerate() {
        assert_eq!(ev.seq, (i + 1) as u64, "event {i} has seq {} (gap or duplicate)", ev.seq);
    }
    assert!(
        events.iter().any(|e| event_type(&e.value) == "job_resume"),
        "the resumed job never streamed its job_resume marker"
    );
    assert_eq!(event_type(&events.last().unwrap().value), "job_done");

    // the stream must be the WAL, across both process incarnations
    let dir = h.data_dir.clone().expect("durable harness has a data dir");
    drop(client);
    drop(h);
    let cfg = DurabilityConfig {
        enabled: true,
        data_dir: dir,
        ..DurabilityConfig::default()
    };
    let (_log, replay) = DurableLog::open(&cfg, None).unwrap();
    assert!(replay.snapshot.is_none(), "test fixture must not compact");
    let wal: Vec<Value> = replay
        .records
        .into_iter()
        .filter(|r| {
            r.get("job").and_then(Value::as_str) == Some(job.as_str())
                && r.get("t").and_then(Value::as_str) != Some("job_start")
        })
        .collect();
    assert_eq!(events.len(), wal.len(), "stream and WAL record counts diverge");
    for (ev, rec) in events.iter().zip(&wal) {
        assert_eq!(&ev.value, rec, "event seq {} is not the WAL record", ev.seq);
    }
}

/// A torn tail — the half-written frame a real `kill -9` leaves mid
/// `write(2)` — is detected by CRC, truncated, and everything before it
/// replays normally. No panic, no lost session.
#[test]
fn torn_wal_tail_is_discarded_and_session_still_recovers() {
    use std::io::Write as _;

    let mut h = durable_cluster("dur-torn", 2);
    let mut client = h.client();
    h.push(&mut client, "s");
    drop(client);

    // scribble garbage onto the live log's tail
    let dir = h.data_dir.clone().expect("durable harness has a data dir");
    let newest_wal = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("wal.") && n.ends_with(".log"))
        })
        .max()
        .expect("no WAL file in the data dir");
    let mut f = std::fs::OpenOptions::new().append(true).open(&newest_wal).unwrap();
    f.write_all(&[0x37, 0x13, 0xde, 0xad, 0xbe, 0xef, 0x00, 0x42, 0x99]).unwrap();
    f.sync_data().unwrap();
    drop(f);

    h.crash_restart_coordinator();
    let mut client = h.client();
    let ids = h.query_ids(&mut client, "s", 10, "entropy");
    assert_eq!(ids.len(), 10, "session did not survive the torn tail");
}

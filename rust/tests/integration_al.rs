//! AL end-to-end integration on the PJRT backend: the science loop
//! (embed -> select -> label -> fine-tune -> evaluate) on real synthetic
//! datasets, and the PSHEA agent on top of it.
//!
//! Requires `make artifacts`; no-ops with a notice otherwise. Kept small
//! (hundreds of samples) so `cargo test` stays fast — the paper-scale
//! numbers come from `cargo bench`.

use std::sync::Arc;

use alaas::agent::{run_pshea, PsheaConfig, StopReason};
use alaas::data::{generate, DatasetSpec};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, PjrtBackend, PjrtPool};
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;

fn pjrt() -> Option<Arc<dyn ComputeBackend>> {
    let dir = alaas::runtime::find_artifacts_dir(None)?;
    let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
    let pool = Arc::new(PjrtPool::new(index, 2, 64));
    Some(Arc::new(PjrtBackend::new(pool)))
}

fn experiment(backend: Arc<dyn ComputeBackend>, seed: u64) -> AlExperiment {
    let spec = DatasetSpec::cifarsim(seed).with_sizes(150, 700, 300);
    let gen = generate(&spec);
    AlExperiment::from_generated(
        backend,
        &gen,
        spec.num_classes,
        TrainConfig { epochs: 20, ..Default::default() },
        seed,
    )
    .expect("experiment builds")
}

#[test]
fn al_learns_on_pjrt_trunk_embeddings() {
    let Some(backend) = pjrt() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut exp = experiment(backend, 21);
    let (_, base) = exp.baseline().unwrap();
    let ub = exp.upper_bound().unwrap();
    assert!(
        ub.top1 > base.top1 + 0.02,
        "dataset must be learnable: baseline {:.3} vs upper bound {:.3}",
        base.top1,
        ub.top1
    );
    // a few LC rounds land between baseline and upper bound, above baseline
    let mut acc = base.top1;
    for _ in 0..3 {
        acc = exp.round("least_confidence", 100).unwrap().unwrap().top1;
    }
    assert!(
        acc > base.top1,
        "AL after 300 labels ({acc:.3}) should beat baseline ({:.3})",
        base.top1
    );
}

#[test]
fn informed_strategies_beat_random_on_average() {
    // Fig 4a's qualitative claim, miniaturized: mean over seeds of
    // one-round accuracy, informed (LC + core_set best-of) vs random.
    let Some(backend) = pjrt() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut informed_sum = 0.0;
    let mut random_sum = 0.0;
    let seeds = [31u64, 32, 33];
    for &seed in &seeds {
        let mut exp = experiment(backend.clone(), seed);
        let lc = exp.one_round("least_confidence", 150).unwrap().top1;
        let cs = exp.one_round("core_set", 150).unwrap().top1;
        informed_sum += lc.max(cs);
        random_sum += exp.one_round("random", 150).unwrap().top1;
    }
    let informed = informed_sum / seeds.len() as f64;
    let random = random_sum / seeds.len() as f64;
    assert!(
        informed + 0.01 >= random,
        "informed {informed:.3} should not lose to random {random:.3}"
    );
}

#[test]
fn pshea_agent_end_to_end_on_pjrt() {
    let Some(backend) = pjrt() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut exp = experiment(backend, 41);
    let strategies: Vec<String> = vec![
        "least_confidence".into(),
        "margin_confidence".into(),
        "k_center_greedy".into(),
        "random".into(),
    ];
    let cfg = PsheaConfig {
        target_accuracy: 1.1, // run to the round limit
        max_budget: 100_000,
        round_budget: 60,
        converge_rounds: 0,
        converge_eps: 0.0,
        max_rounds: 5,
        min_history: 3,
        initial_accuracy: None,
    };
    let trace = run_pshea(&mut exp, &strategies, &cfg).unwrap();
    assert_eq!(trace.stop, StopReason::RoundLimit);
    // all 4 arms ran rounds 0-2; eliminations after
    assert_eq!(trace.round(0).count(), 4);
    assert_eq!(trace.round(2).count(), 4);
    assert_eq!(trace.round(3).count(), 3);
    assert_eq!(trace.round(4).count(), 2);
    // one elimination at the end of each of rounds 2, 3, 4
    assert_eq!(trace.survivors.len(), 1);
    // budget: 3 rounds * 4 arms + 1 round * 3 + 1 round * 2, each 60
    assert_eq!(trace.total_budget, (12 + 3 + 2) * 60);
    // accuracy history is sane
    assert!(trace.best_accuracy > 0.2, "learned something: {}", trace.best_accuracy);
}

#[test]
fn budget_accounting_matches_oracle_charges() {
    let Some(backend) = pjrt() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let mut exp = experiment(backend, 51);
    let init_charge = exp.oracle().budget_spent(); // init split labels
    exp.round("entropy", 80).unwrap().unwrap();
    exp.round("entropy", 80).unwrap().unwrap();
    exp.round("dbal", 50).unwrap().unwrap();
    assert_eq!(exp.oracle().budget_spent() - init_charge, 80 + 80 + 50);
    assert_eq!(exp.labeled_count("entropy"), 160);
    assert_eq!(exp.labeled_count("dbal"), 50);
}

//! Pipeline integration on the PJRT backend: the full serving path
//! (s3sim store -> cache -> preprocess -> dynamic batch -> AOT artifacts
//! through PJRT) with all three Figure 3 dataflows.
//!
//! Requires `make artifacts`; no-ops with a notice otherwise.

use std::sync::Arc;
use std::time::Duration;

use alaas::cache::DataCache;
use alaas::config::StoreConfig;
use alaas::data::{generate_into_store, DatasetSpec};
use alaas::pipeline::{run_pipeline, BatchPolicy, DataflowMode, PipelineParams};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::{ArtifactIndex, PjrtBackend, PjrtPool};
use alaas::store::{Manifest, ObjectStore, SampleRef, StoreRouter};
use alaas::trainer::LinearHead;

fn pjrt(replicas: usize) -> Option<Arc<dyn ComputeBackend>> {
    let dir = alaas::runtime::find_artifacts_dir(None)?;
    let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
    let pool = Arc::new(PjrtPool::new(index, replicas, 64));
    Some(Arc::new(PjrtBackend::new(pool)))
}

/// Generate a dataset into a scratch MemStore, then copy the blobs into
/// the router's s3sim backing store (bypassing the latency model for the
/// writes, like a pre-provisioned bucket).
fn dataset(store: &StoreRouter, pool: usize) -> Manifest {
    let spec = DatasetSpec::cifarsim(11).with_sizes(0, pool, 0);
    let scratch: Arc<dyn ObjectStore> = Arc::new(alaas::store::MemStore::new());
    let manifest = generate_into_store(&spec, &scratch, "s3sim", "pl-ds");
    for key in scratch.list("").unwrap() {
        store.s3sim_backing().put(&key, &scratch.get(&key).unwrap()).unwrap();
    }
    manifest
}

fn fast_store() -> StoreRouter {
    StoreRouter::new(
        "/tmp",
        &StoreConfig { get_latency_us: 0, bandwidth_mib_s: 0.0, jitter: 0.0 },
    )
}

#[test]
fn all_dataflows_agree_on_pjrt() {
    let Some(backend) = pjrt(2) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = fast_store();
    let manifest = dataset(&store, 90);
    let head = LinearHead::zeros(64, 10);
    let mut outs = Vec::new();
    for mode in [
        DataflowMode::Pipelined,
        DataflowMode::SerialOneShot,
        DataflowMode::SerialPerRound(3),
    ] {
        let cache = DataCache::new(0, 1, false);
        let params = PipelineParams {
            mode,
            batch: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) },
            ..Default::default()
        };
        let out = run_pipeline(&manifest.pool, &store, &cache, &backend, &head, &params, None)
            .unwrap();
        assert!(out.errors.is_empty(), "{mode:?}: {:?}", out.errors);
        outs.push(out);
    }
    for o in &outs[1..] {
        for i in 0..90 {
            for (a, b) in outs[0].embeddings.row(i).iter().zip(o.embeddings.row(i)) {
                assert!((a - b).abs() < 1e-4, "row {i} differs across modes");
            }
        }
    }
}

#[test]
fn batch_variant_padding_is_invisible() {
    // 90 samples with max_batch 16 -> chunks of 16 plus a ragged tail;
    // results must match a one-shot scan with batch 64.
    let Some(backend) = pjrt(1) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = fast_store();
    let manifest = dataset(&store, 50);
    let head = LinearHead::zeros(64, 10);
    let run = |max_batch: usize| {
        let cache = DataCache::new(0, 1, false);
        let params = PipelineParams {
            mode: DataflowMode::SerialOneShot,
            batch: BatchPolicy { max_batch, max_wait: Duration::from_millis(5) },
            ..Default::default()
        };
        run_pipeline(&manifest.pool, &store, &cache, &backend, &head, &params, None).unwrap()
    };
    let a = run(16);
    let b = run(64);
    for i in 0..50 {
        for (x, y) in a.scores.row(i).iter().zip(b.scores.row(i)) {
            assert!((x - y).abs() < 1e-4, "scores row {i}: {x} vs {y}");
        }
    }
}

#[test]
fn cache_accelerates_rescan_on_slow_store() {
    let Some(backend) = pjrt(2) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = StoreRouter::new(
        "/tmp",
        &StoreConfig { get_latency_us: 1_500, bandwidth_mib_s: 0.0, jitter: 0.0 },
    );
    let manifest = dataset(&store, 80);
    let head = LinearHead::zeros(64, 10);
    let cache = DataCache::new(256 << 20, 8, true);
    let params = PipelineParams::default();
    let t0 = std::time::Instant::now();
    run_pipeline(&manifest.pool, &store, &cache, &backend, &head, &params, None).unwrap();
    let cold = t0.elapsed();
    let t0 = std::time::Instant::now();
    run_pipeline(&manifest.pool, &store, &cache, &backend, &head, &params, None).unwrap();
    let warm = t0.elapsed();
    assert_eq!(cache.misses(), 80);
    assert!(cache.hits() >= 80);
    assert!(
        warm < cold,
        "warm scan {warm:?} should beat cold {cold:?} (cache bypasses the store)"
    );
}

#[test]
fn selection_over_pipeline_output_matches_direct_path() {
    // End-to-end consistency: strategy selection over pipeline outputs ==
    // selection over directly-computed embeddings/scores.
    let Some(backend) = pjrt(1) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let store = fast_store();
    let manifest = dataset(&store, 60);
    let head = LinearHead::zeros(64, 10);
    let cache = DataCache::new(0, 1, false);
    let out = run_pipeline(
        &manifest.pool,
        &store,
        &cache,
        &backend,
        &head,
        &PipelineParams::default(),
        None,
    )
    .unwrap();

    // direct path: decode+embed+score without the pipeline machinery
    let mut flat = Vec::new();
    for s in &manifest.pool {
        let uri = alaas::uri::Uri::parse(&s.uri).unwrap();
        let raw = store.get(&uri).unwrap();
        flat.extend(alaas::data::decode_image(&raw).unwrap());
    }
    let imgs = alaas::util::mat::Mat::from_vec(flat, 60, alaas::data::IMG_DIM);
    let (emb, scores) = backend.forward(&imgs, &head.w, &head.b).unwrap();

    let labeled = alaas::util::mat::Mat::zeros(0, 64);
    let pick = |e: &alaas::util::mat::Mat, sc: &alaas::util::mat::Mat| {
        let ctx = alaas::strategies::SelectCtx {
            scores: sc,
            embeddings: e,
            labeled: &labeled,
            backend: backend.as_ref(),
            seed: 3,
        };
        alaas::strategies::by_name("k_center_greedy").unwrap().select(&ctx, 12).unwrap()
    };
    assert_eq!(pick(&out.embeddings, &out.scores), pick(&emb, &scores));
    let _ = SampleRef { id: 0, uri: String::new() }; // keep import used
}

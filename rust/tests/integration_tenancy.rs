//! Multi-tenant coordinator integration (DESIGN.md §Tenancy): weighted
//! fairness under saturation, admission-queue load shedding with typed
//! `Overloaded` + retry hints, session-quota enforcement through the
//! `SessionHandle` API, close-frees-worker-memory, and bit-identical
//! single-session selections with tenancy on vs off.
//!
//! Acceptance pins (ISSUE 9):
//! * two sessions with weights 1 and 3 under saturation ⇒ ~1:3 completed
//!   scatter throughput (±25%);
//! * overflowing the admission queue ⇒ typed `Overloaded` with
//!   `retry_after_ms > 0` instead of a timeout, and a retry succeeds
//!   once the burst drains;
//! * `session_close` releases the quota slot and drops every worker
//!   shard session (observable via aggregated `cache_stats`);
//! * a single session sees bit-identical selections whether the tenancy
//!   layer is enabled or not.

mod common;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use alaas::json::Value;
use alaas::server::rpc::RpcError;
use alaas::server::{AlClient, SessionOpts};

use common::cluster_harness::ClusterHarness;

/// Two sessions with DRR weights 1 and 3, a single-scatter admission
/// gate, and four backlog threads per session keeping both queues
/// saturated: completed queries must split ~1:3 (±25%).
#[test]
fn weighted_fairness_one_to_three_under_saturation() {
    let mut h = ClusterHarness::builder()
        .bucket("ten-fair")
        .workers(2)
        .coord_tweak(|c| {
            c.coordinator.tenancy.enabled = true;
            c.coordinator.tenancy.max_concurrent = 1;
            c.coordinator.tenancy.admit_queue_len = 64;
        })
        .build();
    let mut client = h.client();
    client
        .create_session("fair-a", SessionOpts { weight: 1, max_workers: 0 })
        .unwrap()
        .detach();
    client
        .create_session("fair-b", SessionOpts { weight: 3, max_workers: 0 })
        .unwrap()
        .detach();
    h.push(&mut client, "fair-a");
    h.push(&mut client, "fair-b");
    // warm both sessions so the measured window is select-only scatters
    h.query_ids(&mut client, "fair-a", 5, "least_confidence");
    h.query_ids(&mut client, "fair-b", 5, "least_confidence");

    let addr = h.coord_addr.to_string();
    let counts = [Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(0))];
    let stop = Arc::new(AtomicBool::new(false));
    let start = Arc::new(Barrier::new(9)); // 8 workers + the timer below
    let mut threads = Vec::new();
    for t in 0..8 {
        let (sess, idx) = if t % 2 == 0 { ("fair-a", 0) } else { ("fair-b", 1) };
        let addr = addr.clone();
        let count = counts[idx].clone();
        let stop = stop.clone();
        let start = start.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = AlClient::connect(&addr).unwrap();
            start.wait();
            while !stop.load(Ordering::Relaxed) {
                c.query(sess, 5, Some("least_confidence")).unwrap();
                count.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    start.wait();
    std::thread::sleep(Duration::from_millis(2_500));
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().unwrap();
    }
    let a = counts[0].load(Ordering::Relaxed);
    let b = counts[1].load(Ordering::Relaxed);
    h.log(&format!("fairness window: fair-a {a} vs fair-b {b}"));
    assert!(a >= 8, "need a meaningful sample for the weight-1 session, got {a}");
    let ratio = b as f64 / a as f64;
    assert!(
        (2.25..=3.75).contains(&ratio),
        "weights 1:3 should yield ~1:3 throughput (±25%): {a} vs {b} (ratio {ratio:.2})"
    );
}

/// Six simultaneous scatters into a gate with one slot and a one-deep
/// queue: some complete, the rest come back as typed `Overloaded` with a
/// positive retry hint — and a retry after the burst drains succeeds.
#[test]
fn admission_overflow_sheds_with_retry_hint() {
    let mut h = ClusterHarness::builder()
        .bucket("ten-shed")
        .workers(2)
        .sizes(60, 1200, 0) // a heavier pool keeps each scatter long enough to pile up behind
        .coord_tweak(|c| {
            c.coordinator.tenancy.enabled = true;
            c.coordinator.tenancy.max_concurrent = 1;
            c.coordinator.tenancy.admit_queue_len = 1;
        })
        .build();
    let mut client = h.client();
    h.push(&mut client, "shed-sess");

    let addr = h.coord_addr.to_string();
    let start = Arc::new(Barrier::new(6));
    let mut threads = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        let start = start.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = AlClient::connect(&addr).unwrap();
            start.wait();
            c.query("shed-sess", 5, Some("k_center_greedy")).map(|_| ())
        }));
    }
    let mut ok = 0usize;
    let mut shed = Vec::new();
    for t in threads {
        match t.join().unwrap() {
            Ok(()) => ok += 1,
            Err(e) => shed.push(e),
        }
    }
    h.log(&format!("shed burst: {ok} completed, {} shed", shed.len()));
    assert!(ok >= 1, "the running + queued scatters must still complete");
    assert!(!shed.is_empty(), "6 concurrent scatters into a 1-deep queue must shed");
    for e in &shed {
        match e {
            RpcError::Overloaded { retry_after_ms, .. } => {
                assert!(*retry_after_ms > 0, "shed reply must carry a positive retry hint");
            }
            other => panic!("expected typed Overloaded, got {other:?}"),
        }
    }
    // the burst has drained: a retry is admitted normally
    let ids = h.query_ids(&mut client, "shed-sess", 5, "least_confidence");
    assert_eq!(ids.len(), 5);
}

/// `max_sessions = 2`: the third create fails with a clean typed
/// `QuotaExceeded` (no session leaks), closing one releases the slot,
/// and `service_stats` reflects the registry.
#[test]
fn session_quota_enforced_and_released_on_close() {
    let h = ClusterHarness::builder()
        .bucket("ten-quota")
        .workers(2)
        .coord_tweak(|c| {
            c.coordinator.tenancy.enabled = true;
            c.coordinator.tenancy.max_sessions = 2;
        })
        .build();
    let mut client = h.client();
    let (_, tok_a) =
        client.create_session("quota-a", SessionOpts::default()).unwrap().detach();
    client.create_session("quota-b", SessionOpts::default()).unwrap().detach();
    match client.create_session("quota-c", SessionOpts::default()).map(|s| s.detach()) {
        Err(RpcError::QuotaExceeded(msg)) => {
            assert!(msg.contains('2'), "quota message should cite the limit: {msg}")
        }
        Ok((name, _)) => panic!("third create under max_sessions=2 minted '{name}'"),
        Err(other) => panic!("expected typed QuotaExceeded, got {other:?}"),
    }
    // closing by token frees the slot for a new tenant
    assert!(client.close_session(&tok_a).unwrap());
    let (name, _) =
        client.create_session("quota-c", SessionOpts::default()).unwrap().detach();
    assert_eq!(name, "quota-c");
    let stats = client.service_stats().unwrap();
    assert_eq!(stats.get("tenancy_enabled").and_then(Value::as_bool), Some(true));
    assert_eq!(stats.get("sessions_total").and_then(Value::as_usize), Some(2));
    assert_eq!(stats.get("max_sessions").and_then(Value::as_usize), Some(2));
}

/// `session_close` must actually free worker memory: aggregated
/// `cache_stats` shows resident shard sessions (and their embedding
/// bytes) before the close and zero after, and a query on the closed
/// session fails with typed `UnknownSession`.
#[test]
fn close_drops_shard_state_and_frees_worker_memory() {
    let mut h = ClusterHarness::builder()
        .bucket("ten-close")
        .workers(2)
        .coord_tweak(|c| c.coordinator.tenancy.enabled = true)
        .build();
    let mut client = h.client();
    h.push(&mut client, "close-sess");
    let ids = h.query_ids(&mut client, "close-sess", 5, "least_confidence");
    assert_eq!(ids.len(), 5);

    let before = client.cache_stats().unwrap();
    let sessions = before.get("sessions").and_then(Value::as_usize).unwrap_or(0);
    let bytes = before.get("session_bytes").and_then(Value::as_usize).unwrap_or(0);
    assert!(sessions >= 2, "each worker should hold a resident shard session, got {sessions}");
    assert!(bytes > 0, "resident shard embeddings should account bytes");

    assert!(client.close_session("close-sess").unwrap());
    let after = client.cache_stats().unwrap();
    assert_eq!(
        after.get("sessions").and_then(Value::as_usize),
        Some(0),
        "close must drop every worker shard session"
    );
    assert_eq!(after.get("session_bytes").and_then(Value::as_usize), Some(0));

    match client.query("close-sess", 5, Some("least_confidence")) {
        Err(RpcError::UnknownSession(m)) => assert!(m.contains("close-sess"), "got: {m}"),
        Ok(_) => panic!("query on a closed session must fail"),
        Err(other) => panic!("expected typed UnknownSession, got {other:?}"),
    }
}

/// Shed parity (ISSUE 10): a standalone `AlServer` arbitrates its
/// scatter-shaped work through the same `AdmissionGate` as the
/// coordinator. The same 6-into-a-1-deep-queue burst must produce the
/// identical failure surface — typed `Overloaded` with a positive
/// `retry_after_ms`, not a timeout or an unbounded queue — and the
/// gate's counters must show up in `service_stats` in the coordinator's
/// shape.
#[test]
fn single_server_sheds_with_same_typed_overloaded_as_coordinator() {
    let h = ClusterHarness::builder()
        .bucket("ten-shed-single")
        .workers(0)
        .with_single(true)
        .sizes(60, 1200, 0) // heavy pool: each select is long enough to pile up behind
        .cfg_tweak(|c| {
            c.coordinator.tenancy.enabled = true;
            c.coordinator.tenancy.max_concurrent = 1;
            c.coordinator.tenancy.admit_queue_len = 1;
        })
        .build();
    let mut client = h.single_client();
    client.push_data("shed-sess", &h.manifest, Some(&h.labels.init)).unwrap();

    let addr = h.single_addr();
    let start = Arc::new(Barrier::new(6));
    let mut threads = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        let start = start.clone();
        threads.push(std::thread::spawn(move || {
            let mut c = AlClient::connect(&addr).unwrap();
            start.wait();
            c.query("shed-sess", 5, Some("k_center_greedy")).map(|_| ())
        }));
    }
    let mut ok = 0usize;
    let mut shed = Vec::new();
    for t in threads {
        match t.join().unwrap() {
            Ok(()) => ok += 1,
            Err(e) => shed.push(e),
        }
    }
    h.log(&format!("single-server shed burst: {ok} completed, {} shed", shed.len()));
    assert!(ok >= 1, "the running + queued selects must still complete");
    assert!(!shed.is_empty(), "6 concurrent selects into a 1-deep queue must shed");
    for e in &shed {
        match e {
            RpcError::Overloaded { retry_after_ms, .. } => {
                assert!(*retry_after_ms > 0, "shed reply must carry a positive retry hint");
            }
            other => panic!("expected typed Overloaded, got {other:?}"),
        }
    }
    // the burst has drained: a retry is admitted normally
    let (picked, _, _) = client.query("shed-sess", 5, Some("least_confidence")).unwrap();
    assert_eq!(picked.len(), 5);

    // the gate's book-keeping surfaces in the coordinator's stats shape
    let stats = client.service_stats().unwrap();
    assert_eq!(stats.get("tenancy_enabled").and_then(Value::as_bool), Some(true));
    assert!(stats.get("admitted_total").and_then(Value::as_usize).unwrap_or(0) >= 1);
    assert!(stats.get("shed_total").and_then(Value::as_usize).unwrap_or(0) >= 1);
    assert!(stats.get("running").is_some(), "gate stats missing 'running'");
    assert!(stats.get("queued").is_some(), "gate stats missing 'queued'");
}

/// The tenancy layer is pure admission control: with a single session
/// and no contention, selections are bit-identical whether the gate is
/// enabled cluster-wide or not.
#[test]
fn single_session_selection_bit_identical_tenancy_on_off() {
    let run = |tenancy: bool| {
        let mut h = ClusterHarness::builder()
            .bucket("ten-par")
            .workers(3)
            .cfg_tweak(move |c| c.coordinator.tenancy.enabled = tenancy)
            .build();
        let mut client = h.client();
        h.push(&mut client, "par-sess");
        let lc = h.query_ids(&mut client, "par-sess", 10, "least_confidence");
        let kc = h.query_ids(&mut client, "par-sess", 10, "k_center_greedy");
        (lc, kc)
    };
    let (lc_off, kc_off) = run(false);
    let (lc_on, kc_on) = run(true);
    assert_eq!(lc_off, lc_on, "tenancy gate must not perturb margin selections");
    assert_eq!(kc_off, kc_on, "tenancy gate must not perturb refine selections");
}

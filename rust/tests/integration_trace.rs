//! End-to-end distributed tracing over real TCP (ISSUE 6 acceptance,
//! DESIGN.md §Observability):
//!
//! * a 2-worker scattered query yields ONE assembled span tree on the
//!   coordinator — `rpc.query` root, `scatter`/`merge` stage children,
//!   and per shard an adopted worker subtree (`rpc.select_shard` with
//!   its `scan.wait` / `select.candidates` stage spans),
//! * the slow-query log retains such a trace verbatim past a tiny
//!   threshold, and `metrics_text` serves Prometheus-style lines,
//! * tracing is observation only: selections are bit-identical with
//!   `[observability] trace = false`.

mod common;

use std::collections::HashMap;

use alaas::json::Value;
use alaas::trace::SpanRecord;

use common::cluster_harness::ClusterHarness;

fn span_by_name<'a>(spans: &'a [SpanRecord], name: &str) -> &'a SpanRecord {
    spans
        .iter()
        .find(|s| s.name == name)
        .unwrap_or_else(|| panic!("no '{name}' span in {:?}", names(spans)))
}

fn names(spans: &[SpanRecord]) -> Vec<&str> {
    spans.iter().map(|s| s.name.as_str()).collect()
}

#[test]
fn scattered_query_assembles_one_end_to_end_tree() {
    let h = ClusterHarness::builder()
        .sizes(60, 200, 0)
        .workers(2)
        .bucket("trace-ds")
        // real scatter roundtrips take > 1 ms, so the query also lands in
        // the slow-query log (retained verbatim, asserted below)
        .cfg_tweak(|cfg| cfg.observability.slow_query_ms = 1)
        .build();
    let mut client = h.client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let (sel, _, _) = client.query("s", 20, Some("entropy")).unwrap();
    assert_eq!(sel.len(), 20);

    // the trace plane lists the query as a recent root
    let recent = client.trace_recent(0).unwrap();
    assert_eq!(recent.get("enabled").and_then(Value::as_bool), Some(true));
    let roots = recent.get("roots").and_then(Value::as_array).unwrap();
    let query_root = roots
        .iter()
        .find(|r| r.get("name").and_then(Value::as_str) == Some("rpc.query"))
        .unwrap_or_else(|| panic!("no rpc.query root in {roots:?}"));
    let trace_id =
        query_root.get("trace").and_then(Value::as_i64).expect("trace id") as u64;

    // one trace_get on the coordinator returns the full cross-process tree
    let spans = client.trace_get(trace_id).unwrap();
    assert!(
        spans.iter().all(|s| s.trace_id == trace_id),
        "mixed trace ids in {:?}",
        names(&spans)
    );
    let by_id: HashMap<u64, &SpanRecord> =
        spans.iter().map(|s| (s.span_id, s)).collect();

    // coordinator skeleton: rpc.query root with scatter + merge children
    let root = span_by_name(&spans, "rpc.query");
    assert_eq!(root.parent, 0, "client sent no context, so the query roots");
    let scatter = span_by_name(&spans, "scatter");
    assert_eq!(scatter.parent, root.span_id);
    let merge = span_by_name(&spans, "merge");
    assert_eq!(merge.parent, root.span_id);

    // one shard.select per worker, each with straggler-attributable notes
    let shard_selects: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.name == "shard.select").collect();
    assert_eq!(shard_selects.len(), 2, "one scatter leg per shard");
    for leg in &shard_selects {
        assert_eq!(leg.parent, scatter.span_id);
        assert!(
            leg.notes.iter().any(|(k, _)| k == "shard"),
            "scatter leg missing shard note: {:?}",
            leg.notes
        );
    }

    // each leg adopted its worker's piggybacked subtree: an
    // rpc.select_shard entry span plus the worker-side stage spans
    for leg in &shard_selects {
        let worker = spans
            .iter()
            .find(|s| s.name == "rpc.select_shard" && s.parent == leg.span_id)
            .unwrap_or_else(|| {
                panic!("shard leg {:?} has no worker subtree in {:?}", leg.notes, names(&spans))
            });
        for stage in ["scan.wait", "select.candidates"] {
            let st = spans
                .iter()
                .find(|s| s.name == stage && s.parent == worker.span_id)
                .unwrap_or_else(|| panic!("worker subtree missing '{stage}' stage span"));
            assert!(st.duration_ns() <= worker.duration_ns());
        }
    }

    // every span (except the root) hangs off a parent within the tree
    for s in &spans {
        assert!(
            s.parent == 0 || by_id.contains_key(&s.parent),
            "span '{}' dangles from unknown parent {:012x}",
            s.name,
            s.parent
        );
    }

    // the rendered tree nests worker stages under the coordinator root
    let rendered = alaas::trace::render_tree(&spans);
    let root_line = rendered.lines().next().unwrap();
    assert!(root_line.starts_with("rpc.query"), "{rendered}");
    assert!(
        rendered.lines().any(|l| l.starts_with("      rpc.select_shard")),
        "worker subtree not nested at depth 3:\n{rendered}"
    );

    // >1ms root span: the slow-query log retained the trace verbatim
    let slow = recent.get("slow").and_then(Value::as_array).unwrap();
    assert!(
        slow.iter().any(|e| {
            e.get("trace").and_then(Value::as_i64) == Some(trace_id as i64)
        }),
        "query trace missing from slow log: {slow:?}"
    );

    // the Prometheus text surface serves over the same connection
    let text = client.metrics_text().unwrap();
    assert!(
        text.lines().any(|l| l.starts_with("alaas_cluster_shard_scan_us{quantile=")),
        "no per-shard scan series in metrics_text:\n{text}"
    );
}

#[test]
fn selections_bit_identical_with_tracing_disabled() {
    let traced = ClusterHarness::builder()
        .sizes(60, 200, 0)
        .workers(2)
        .bucket("trace-on-ds")
        .build();
    let untraced = ClusterHarness::builder()
        .sizes(60, 200, 0)
        .workers(2)
        .bucket("trace-off-ds")
        .cfg_tweak(|cfg| cfg.observability.trace = false)
        .build();
    let mut a = traced.client();
    let mut b = untraced.client();
    a.push_data("s", &traced.manifest, Some(&traced.labels.init)).unwrap();
    b.push_data("s", &untraced.manifest, Some(&untraced.labels.init)).unwrap();

    // tracing never touches the selection RNG or candidate order: exact
    // ids for the top-k strategies and for the refine protocol alike
    for strategy in ["entropy", "least_confidence", "random", "k_center_greedy"] {
        let (x, _, _) = a.query("s", 24, Some(strategy)).unwrap();
        let (y, _, _) = b.query("s", 24, Some(strategy)).unwrap();
        let ids = |sel: &[alaas::store::SampleRef]| -> Vec<u32> {
            sel.iter().map(|s| s.id).collect()
        };
        assert_eq!(
            ids(&x),
            ids(&y),
            "{strategy}: tracing changed the selection"
        );
    }

    // the disabled plane says so and records nothing
    let recent = b.trace_recent(0).unwrap();
    assert_eq!(recent.get("enabled").and_then(Value::as_bool), Some(false));
    assert!(recent.get("roots").and_then(Value::as_array).unwrap().is_empty());

    // ...while the traced cluster accumulated roots for the same flow
    let recent = a.trace_recent(0).unwrap();
    assert!(!recent.get("roots").and_then(Value::as_array).unwrap().is_empty());
}

/// `trace_get` is queryable by the hex string form the CLI and logs
/// print, not just the raw number.
#[test]
fn trace_get_accepts_hex_string_ids() {
    let h = ClusterHarness::builder()
        .sizes(40, 80, 0)
        .workers(2)
        .bucket("trace-hex-ds")
        .build();
    let mut client = h.client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    client.query("s", 10, Some("random")).unwrap();
    let recent = client.trace_recent(1).unwrap();
    let roots = recent.get("roots").and_then(Value::as_array).unwrap();
    let id = roots[0].get("trace").and_then(Value::as_i64).unwrap() as u64;

    let mut p = alaas::json::Map::new();
    p.insert("trace", Value::from(format!("{id:012x}")));
    let v = client.call("trace_get", Value::Object(p)).unwrap();
    let spans = alaas::trace::spans_from_value(v.get("spans").unwrap());
    assert!(!spans.is_empty());
    assert!(spans.iter().all(|s| s.trace_id == id));

    // unknown method shape: a bad hex id is a clean remote error
    let mut p = alaas::json::Map::new();
    p.insert("trace", Value::from("not-hex"));
    let err = client.call("trace_get", Value::Object(p)).unwrap_err();
    assert!(format!("{err}").contains("bad hex"), "{err}");
}

//! Runtime integration: the AOT artifacts executed through PJRT must match
//! the pure-Rust host reference (which itself mirrors python ref.py — so
//! this closes the L1/L2 <-> L3 numerics loop).
//!
//! Requires `make artifacts`; every test no-ops with a notice otherwise
//! (CI runs them via `make test`, which builds artifacts first).

use std::sync::Arc;

use alaas::runtime::backend::{host_eval_logits, host_scores, host_sqdist, host_train_step};
use alaas::runtime::{ArtifactIndex, ComputeBackend, PjrtBackend, PjrtPool};
use alaas::util::mat::Mat;
use alaas::util::rng::Rng;

fn pjrt() -> Option<PjrtBackend> {
    let dir = alaas::runtime::find_artifacts_dir(None)?;
    let index = Arc::new(ArtifactIndex::load(&dir).expect("manifest parses"));
    let pool = Arc::new(PjrtPool::new(index, 2, 32));
    Some(PjrtBackend::new(pool))
}

macro_rules! require_artifacts {
    ($be:ident) => {
        let Some($be) = pjrt() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
    };
}

fn rand_mat(rng: &mut Rng, r: usize, c: usize, scale: f32) -> Mat {
    Mat::from_vec((0..r * c).map(|_| scale * rng.normal_f32()).collect(), r, c)
}

fn assert_close(a: &Mat, b: &Mat, tol: f32, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    for (i, (x, y)) in a.as_slice().iter().zip(b.as_slice()).enumerate() {
        assert!(
            (x - y).abs() <= tol + tol * y.abs(),
            "{what}: element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn scores_match_host_reference() {
    require_artifacts!(be);
    let mut rng = Rng::new(42);
    for &b in &[1usize, 3, 16, 37, 130] {
        let logits = rand_mat(&mut rng, b, 10, 4.0);
        let got = be.scores(&logits).expect("pjrt scores");
        let want = host_scores(&logits);
        assert_close(&got, &want, 1e-5, &format!("scores b={b}"));
    }
}

#[test]
fn sqdist_matches_host_reference_with_tiling() {
    require_artifacts!(be);
    let mut rng = Rng::new(7);
    // Cover: tile-exact, sub-tile, and ragged multi-tile shapes.
    for &(m, n) in &[(256usize, 256usize), (40, 70), (300, 513), (1, 257)] {
        let x = rand_mat(&mut rng, m, 64, 1.0);
        let y = rand_mat(&mut rng, n, 64, 1.0);
        let got = be.sqdist(&x, &y).expect("pjrt sqdist");
        let want = host_sqdist(&x, &y).expect("host sqdist");
        assert_close(&got, &want, 1e-3, &format!("sqdist {m}x{n}"));
    }
}

#[test]
fn embed_is_deterministic_and_batch_invariant() {
    require_artifacts!(be);
    let mut rng = Rng::new(3);
    let images = rand_mat(&mut rng, 37, 3072, 0.3);
    let full = be.embed(&images).expect("embed full");
    assert_eq!(full.shape(), (37, 64));
    let again = be.embed(&images).expect("embed again");
    assert_close(&full, &again, 0.0, "determinism");
    // chunk/pad invariance: single-row forward equals batched row
    let single = be.embed(&images.take_rows(1)).expect("embed single");
    for k in 0..64 {
        assert!(
            (full.get(0, k) - single.get(0, k)).abs() < 1e-4,
            "batch leak at col {k}: {} vs {}",
            full.get(0, k),
            single.get(0, k)
        );
    }
}

#[test]
fn forward_fuses_embed_head_and_scores() {
    require_artifacts!(be);
    let mut rng = Rng::new(4);
    let images = rand_mat(&mut rng, 19, 3072, 0.3);
    let w = rand_mat(&mut rng, 64, 10, 0.2);
    let b: Vec<f32> = (0..10).map(|_| 0.1 * rng.normal_f32()).collect();

    let (emb, scores) = be.forward(&images, &w, &b).expect("forward");
    assert_eq!(emb.shape(), (19, 64));
    assert_eq!(scores.shape(), (19, 4));

    // Cross-check: forward == embed -> host head -> pjrt scores
    let emb2 = be.embed(&images).expect("embed");
    assert_close(&emb, &emb2, 1e-4, "forward emb vs embed");
    let logits = host_eval_logits(&emb2, &w, &b).unwrap();
    let s2 = be.scores(&logits).expect("scores");
    assert_close(&scores, &s2, 1e-3, "forward scores vs composed");
}

#[test]
fn train_step_matches_host_and_descends() {
    require_artifacts!(be);
    let mut rng = Rng::new(5);
    let x = rand_mat(&mut rng, 64, 64, 1.0);
    let mut y = Mat::zeros(64, 10);
    for i in 0..64 {
        y.set(i, i % 10, 1.0);
    }

    let mut w_p = Mat::zeros(64, 10);
    let mut b_p = vec![0.0f32; 10];
    let mut w_h = Mat::zeros(64, 10);
    let mut b_h = vec![0.0f32; 10];

    let mut first = None;
    let mut last = 0.0;
    for step in 0..20 {
        let lp = be.train_step(&mut w_p, &mut b_p, &x, &y, 0.5).expect("pjrt step");
        let lh = host_train_step(&mut w_h, &mut b_h, &x, &y, 0.5).expect("host step");
        assert!(
            (lp - lh).abs() < 1e-3 + 1e-3 * lh.abs(),
            "step {step}: pjrt loss {lp} vs host {lh}"
        );
        if first.is_none() {
            first = Some(lp);
            assert!((lp - (10.0f32).ln()).abs() < 1e-4, "first loss {lp}");
        }
        last = lp;
    }
    assert!(last < first.unwrap() * 0.8, "no descent: {first:?} -> {last}");
    assert_close(&w_p, &w_h, 1e-3, "weights after 20 steps");
}

#[test]
fn train_step_tail_padding_is_inert() {
    require_artifacts!(be);
    let mut rng = Rng::new(6);
    let x = rand_mat(&mut rng, 30, 64, 1.0); // < train_batch, gets padded
    let mut y = Mat::zeros(30, 10);
    for i in 0..30 {
        y.set(i, (i * 3) % 10, 1.0);
    }
    let mut w = Mat::zeros(64, 10);
    let mut b = vec![0.0f32; 10];
    let loss = be.train_step(&mut w, &mut b, &x, &y, 0.3).expect("padded step");

    let mut w_h = Mat::zeros(64, 10);
    let mut b_h = vec![0.0f32; 10];
    let loss_h = host_train_step(&mut w_h, &mut b_h, &x, &y, 0.3).unwrap();
    assert!((loss - loss_h).abs() < 1e-4, "{loss} vs {loss_h}");
    assert_close(&w, &w_h, 1e-4, "padded-step weights");
}

#[test]
fn eval_logits_matches_host() {
    require_artifacts!(be);
    let mut rng = Rng::new(8);
    for &n in &[1usize, 100, 256, 300] {
        let x = rand_mat(&mut rng, n, 64, 1.0);
        let w = rand_mat(&mut rng, 64, 10, 0.3);
        let b: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
        let got = be.eval_logits(&x, &w, &b).expect("pjrt eval");
        let want = host_eval_logits(&x, &w, &b).unwrap();
        assert_close(&got, &want, 1e-3, &format!("eval n={n}"));
    }
}

#[test]
fn pool_serves_concurrent_callers() {
    require_artifacts!(be);
    let be = Arc::new(be);
    let mut rng = Rng::new(9);
    let logits = Arc::new(rand_mat(&mut rng, 64, 10, 2.0));
    let want = host_scores(&logits);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let be = be.clone();
            let logits = logits.clone();
            let want = want.clone();
            s.spawn(move || {
                for _ in 0..5 {
                    let got = be.scores(&logits).expect("concurrent scores");
                    assert_close(&got, &want, 1e-5, "concurrent");
                }
            });
        }
    });
}

#[test]
fn unknown_artifact_errors_cleanly() {
    let Some(dir) = alaas::runtime::find_artifacts_dir(None) else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let index = Arc::new(ArtifactIndex::load(&dir).unwrap());
    let pool = PjrtPool::new(index, 1, 4);
    let err = pool.call("definitely_not_an_artifact", vec![]).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("definitely_not_an_artifact"), "{msg}");
}

#[test]
fn warmup_compiles_on_all_replicas() {
    require_artifacts!(be);
    let pool = be.pool();
    pool.warmup(&["scores_b16".to_string()]).expect("warmup");
    // After warmup, calls are served without compile hiccups; just verify
    // the path still works.
    let mut rng = Rng::new(10);
    let logits = rand_mat(&mut rng, 16, 10, 1.0);
    be.scores(&logits).expect("post-warmup scores");
}

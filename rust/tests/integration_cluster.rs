//! Cluster integration over real TCP: a coordinator + N in-process
//! workers against the same dataset as a single `AlServer`, proving the
//! distributed selection semantics (DESIGN.md §Cluster):
//!
//! * exact index parity for random + the four uncertainty strategies,
//! * quality parity (cover radius within a constant factor) for the
//!   candidate-then-refine diversity/hybrid strategies,
//! * failure-aware scatter-gather: a worker killed after push still
//!   yields a full-budget selection via shard re-dispatch.
//!
//! All topology spawn/kill plumbing lives in the shared
//! `common::cluster_harness` (ISSUE 5 satellite); membership-enabled
//! fault-injection scenarios live in `integration_membership.rs`.

mod common;

use std::collections::HashMap;
use std::sync::Arc;

use alaas::cache::DataCache;
use alaas::cluster::worker::register_with;
use alaas::pipeline::{run_pipeline, PipelineParams};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::WireMode;
use alaas::store::{Manifest, SampleRef};
use alaas::trainer::LinearHead;

use common::cluster_harness::ClusterHarness;

/// The historical fixture: 60-init dataset, `pool` pool rows, N workers,
/// plus the single-server reference.
fn harness(pool: usize, n_workers: usize) -> ClusterHarness {
    ClusterHarness::builder()
        .sizes(60, pool, 0)
        .workers(n_workers)
        .with_single(true)
        .build()
}

fn harness_wire(
    pool: usize,
    n_workers: usize,
    coord_wire: WireMode,
    worker_wire: WireMode,
) -> ClusterHarness {
    ClusterHarness::builder()
        .sizes(60, pool, 0)
        .workers(n_workers)
        .with_single(true)
        .wires(coord_wire, worker_wire)
        .build()
}

fn ids(sel: &[SampleRef]) -> Vec<u32> {
    sel.iter().map(|s| s.id).collect()
}

fn assert_valid(sel: &[SampleRef], manifest: &Manifest, budget: usize) {
    assert_eq!(sel.len(), budget.min(manifest.pool.len()), "selection size");
    let pool_ids: std::collections::HashSet<u32> =
        manifest.pool.iter().map(|s| s.id).collect();
    let mut seen = std::collections::HashSet::new();
    for s in sel {
        assert!(pool_ids.contains(&s.id), "id {} not in pool", s.id);
        assert!(seen.insert(s.id), "duplicate id {}", s.id);
    }
}

#[test]
fn exact_parity_random_and_uncertainty() {
    let h = harness(320, 4);
    let mut single = h.single_client();
    let mut cluster = h.client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    for strategy in [
        "random",
        "least_confidence",
        "margin_confidence",
        "ratio_confidence",
        "entropy",
    ] {
        let (want, _, _) = single.query("s", 40, Some(strategy)).unwrap();
        let (got, named, _) = cluster.query("s", 40, Some(strategy)).unwrap();
        assert_eq!(named, strategy);
        assert_valid(&got, &h.manifest, 40);
        assert_eq!(
            ids(&got),
            ids(&want),
            "{strategy}: 4-worker selection differs from single server"
        );
    }
}

/// Pool embeddings in manifest order (embeddings are trunk-only, so the
/// untrained head reproduces exactly what the servers computed).
fn pool_embeddings(h: &ClusterHarness) -> alaas::util::mat::Mat {
    let cache = DataCache::new(0, 1, false);
    let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
    let head = LinearHead::zeros(64, h.manifest.num_classes);
    let out = run_pipeline(
        &h.manifest.pool,
        &h.store,
        &cache,
        &backend,
        &head,
        &PipelineParams::default(),
        None,
    )
    .unwrap();
    assert!(out.errors.is_empty());
    out.embeddings
}

fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Max over the pool of the min distance to a selection — the k-center
/// objective both diversity strategies optimize.
fn cover_radius(emb: &alaas::util::mat::Mat, rows: &[usize]) -> f32 {
    let mut worst = 0.0f32;
    for i in 0..emb.rows() {
        let best = rows
            .iter()
            .map(|&s| sqdist(emb.row(i), emb.row(s)))
            .fold(f32::INFINITY, f32::min);
        worst = worst.max(best);
    }
    worst
}

#[test]
fn refine_parity_for_diversity_and_hybrid() {
    let h = harness(240, 4);
    let mut single = h.single_client();
    let mut cluster = h.client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();

    let emb = pool_embeddings(&h);
    let id_to_row: HashMap<u32, usize> =
        h.manifest.pool.iter().enumerate().map(|(i, s)| (s.id, i)).collect();
    let rows =
        |sel: &[SampleRef]| -> Vec<usize> { sel.iter().map(|s| id_to_row[&s.id]).collect() };

    for strategy in ["k_center_greedy", "core_set", "dbal"] {
        let (want, _, _) = single.query("s", 24, Some(strategy)).unwrap();
        let (got, _, _) = cluster.query("s", 24, Some(strategy)).unwrap();
        assert_valid(&got, &h.manifest, 24);
        // distributed selection is deterministic
        let (again, _, _) = cluster.query("s", 24, Some(strategy)).unwrap();
        assert_eq!(ids(&got), ids(&again), "{strategy}: not deterministic");
        if strategy != "dbal" {
            // quality parity: the refined union must cover the pool nearly
            // as tightly as the single-server selection
            // radii are squared distances, so 4x here = 2x in metric terms
            let r_single = cover_radius(&emb, &rows(&want));
            let r_cluster = cover_radius(&emb, &rows(&got));
            assert!(
                r_cluster <= 4.0 * r_single + 1e-4,
                "{strategy}: cluster cover radius {r_cluster} vs single {r_single}"
            );
        }
    }
}

#[test]
fn worker_death_mid_scan_redispatches() {
    let mut h = harness(180, 3);
    let mut cluster = h.client();
    cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    // kill one worker right after the scatter — its shard may still be
    // scanning; the coordinator must re-dispatch it to a survivor
    h.kill_worker(0);
    let (sel, _, _) = cluster.query("s", 40, Some("entropy")).unwrap();
    assert_valid(&sel, &h.manifest, 40);
    // a second query (now fully re-assigned) also works, as does a
    // refine-protocol strategy over the surviving workers
    let (sel2, _, _) = cluster.query("s", 40, Some("entropy")).unwrap();
    assert_eq!(ids(&sel), ids(&sel2));
    let (div, _, _) = cluster.query("s", 15, Some("k_center_greedy")).unwrap();
    assert_valid(&div, &h.manifest, 15);
}

#[test]
fn workers_can_register_dynamically() {
    // coordinator starts empty; push_data must fail until workers join
    let mut h = ClusterHarness::builder()
        .bucket("reg-ds")
        .data_seed(9)
        .sizes(40, 120, 0)
        .workers(0)
        .build();
    let mut client = h.client();
    let err = client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap_err();
    assert!(format!("{err}").contains("no live workers"), "{err}");

    let w1 = h.add_worker_unregistered();
    let w2 = h.add_worker_unregistered();
    let coord_addr = h.coord_addr.to_string();
    register_with(&h.worker_addr(w1), &coord_addr).unwrap();
    register_with(&h.worker_addr(w2), &coord_addr).unwrap();
    assert_eq!(h.coordinator().live_workers(), 2);

    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let (sel, _, _) = client.query("s", 20, Some("least_confidence")).unwrap();
    assert_valid(&sel, &h.manifest, 20);

    let status = client.call("cluster_status", alaas::json::Value::Null).unwrap();
    let workers = status.get("workers").unwrap().as_array().unwrap();
    assert_eq!(workers.len(), 2);
    assert!(workers.iter().all(|w| w.get("alive").unwrap().as_bool() == Some(true)));
    // static fallback: the membership block reports disabled
    let membership = status.get("membership").unwrap();
    assert_eq!(membership.get("enabled").unwrap().as_bool(), Some(false));
}

#[test]
fn per_shard_metrics_and_straggler_gauge() {
    let h = harness(160, 4);
    let mut cluster = h.client();
    cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    cluster.query("s", 20, Some("entropy")).unwrap();

    let snap = h.coord_metrics.snapshot();
    let hists = snap.get("histograms").unwrap();
    for i in 0..4 {
        let name = format!("cluster.shard{i}.scan");
        let shard = hists.get(&name).unwrap_or_else(|| panic!("missing {name}"));
        assert!(
            shard.get("count").unwrap().as_i64().unwrap() >= 1,
            "{name} never recorded"
        );
    }
    assert!(hists.get("cluster.shard_scan").is_some());
    let counters = snap.get("counters").unwrap();
    assert!(
        counters.get("cluster.scan.straggler_ms").is_some(),
        "straggler gauge missing"
    );
    // the same numbers are visible to clients through the metrics RPC
    let remote = cluster.metrics().unwrap();
    assert!(remote.get("histograms").unwrap().get("cluster.shard0.scan").is_some());
}

/// Selection parity across the wire matrix (DESIGN.md §Wire): every
/// coordinator/worker encoding combination — including the mixed pair
/// that exercises the binary→JSON negotiation fallback — must yield the
/// exact single-server selection for the top-k strategies and the exact
/// same refined selection as every other combination.
#[test]
fn wire_mode_parity_and_mixed_pair_fallback() {
    let combos = [
        (WireMode::Json, WireMode::Json),
        (WireMode::Binary, WireMode::Binary),
        // mixed pair: binary coordinator, JSON-forced workers
        (WireMode::Binary, WireMode::Json),
        (WireMode::Json, WireMode::Binary),
    ];
    let mut entropy_sel: Vec<Vec<u32>> = Vec::new();
    let mut kcg_sel: Vec<Vec<u32>> = Vec::new();
    for (coord_wire, worker_wire) in combos {
        let tag = format!("coord={coord_wire:?} worker={worker_wire:?}");
        let h = harness_wire(160, 2, coord_wire, worker_wire);
        let mut single = h.single_client();
        let mut cluster = h.client();
        single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
        cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();

        // exact top-k strategy: must equal the single server bit-for-bit
        let (want, _, _) = single.query("s", 20, Some("entropy")).unwrap();
        let (got, _, _) = cluster.query("s", 20, Some("entropy")).unwrap();
        assert_valid(&got, &h.manifest, 20);
        assert_eq!(ids(&got), ids(&want), "{tag}: entropy parity broke");
        entropy_sel.push(ids(&got));

        // refine strategy: ships embeddings (tensor sections on the
        // binary wire); the selection must not depend on the encoding
        let (kcg, _, _) = cluster.query("s", 15, Some("k_center_greedy")).unwrap();
        assert_valid(&kcg, &h.manifest, 15);
        kcg_sel.push(ids(&kcg));

        let snap = h.coord_metrics.snapshot();
        let counters = snap.get("counters").unwrap();
        let counter = |name: &str| -> i64 {
            counters.get(name).and_then(|v| v.as_i64()).unwrap_or(0)
        };
        assert!(counter("wire.rx_bytes") > 0, "{tag}: no wire bytes recorded");
        if coord_wire == WireMode::Binary && worker_wire == WireMode::Json {
            // the mixed pair must have downgraded at least one worker
            assert!(
                counter("wire.json_fallbacks") >= 1,
                "{tag}: negotiation fallback never fired"
            );
        }
        if coord_wire == WireMode::Binary && worker_wire == WireMode::Binary {
            assert!(
                counter("wire.frames.binary") > 0,
                "{tag}: binary cluster never exchanged a v2 frame"
            );
            assert_eq!(counter("wire.json_fallbacks"), 0, "{tag}: spurious fallback");
        }
    }
    // the dataset and seeds are identical across harnesses, so selections
    // must agree across every wire combination
    for (i, sel) in entropy_sel.iter().enumerate().skip(1) {
        assert_eq!(sel, &entropy_sel[0], "entropy differs across wire combos ({i})");
    }
    for (i, sel) in kcg_sel.iter().enumerate().skip(1) {
        assert_eq!(sel, &kcg_sel[0], "k_center_greedy differs across wire combos ({i})");
    }
}

/// The PR 4 acceptance pin, deterministic (counts, not timings): N
/// scatter RPCs over the connection pool perform at most one dial per
/// worker — not one per RPC, as the pre-pool coordinator did.
#[test]
fn pooled_scatter_dials_once_per_worker_not_per_rpc() {
    let h = harness(160, 3);
    let mut cluster = h.client();
    cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    for strategy in ["entropy", "random", "k_center_greedy"] {
        let (sel, _, _) = cluster.query("s", 20, Some(strategy)).unwrap();
        assert_valid(&sel, &h.manifest, 20);
    }
    let snap = h.coord_metrics.snapshot();
    let counters = snap.get("counters").unwrap();
    let counter =
        |name: &str| -> i64 { counters.get(name).and_then(|v| v.as_i64()).unwrap_or(0) };
    // 1 push + 3 query scatters = 12 worker RPCs over 3 workers: the pool
    // dials each worker exactly once and reuses the negotiated conn
    assert_eq!(counter("pool.dials"), 3, "scatter must reuse pooled connections");
    assert!(counter("pool.hits") >= 9, "reused calls must count as hits");
    assert_eq!(counter("pool.retries"), 0, "healthy cluster must not retry");
    assert_eq!(counter("pool.in_flight"), 0, "gauge must return to zero");
    // negotiation happened on the pooled dials, not per call
    assert_eq!(counter("wire.json_fallbacks"), 0);
}

/// Pooling is a transport optimization only: with `[server.pool]` reuse
/// disabled the coordinator dials per call (the pre-pool behavior) and
/// every selection is identical.
#[test]
fn per_call_dialing_matches_pooled_selections() {
    let pooled = harness(200, 3);
    let per_call = ClusterHarness::builder()
        .sizes(60, 200, 0)
        .workers(3)
        .coord_tweak(|cfg| {
            cfg.server.pool.max_idle_per_peer = 0;
        })
        .build();
    let mut a = pooled.client();
    let mut b = per_call.client();
    a.push_data("s", &pooled.manifest, Some(&pooled.labels.init)).unwrap();
    b.push_data("s", &per_call.manifest, Some(&per_call.labels.init)).unwrap();
    for strategy in ["entropy", "least_confidence", "random", "k_center_greedy"] {
        let (x, _, _) = a.query("s", 24, Some(strategy)).unwrap();
        let (y, _, _) = b.query("s", 24, Some(strategy)).unwrap();
        assert_valid(&x, &pooled.manifest, 24);
        assert_eq!(ids(&x), ids(&y), "{strategy}: pooled vs per-call selections diverged");
    }
    // and per-call mode really did dial per scatter RPC
    let snap = per_call.coord_metrics.snapshot();
    let counters = snap.get("counters").unwrap();
    let counter =
        |name: &str| -> i64 { counters.get(name).and_then(|v| v.as_i64()).unwrap_or(0) };
    assert!(
        counter("pool.dials") >= 3 + 4 * 3,
        "expected a dial per scatter RPC, saw {}",
        counter("pool.dials")
    );
    assert_eq!(counter("pool.hits"), 0, "per-call mode must never reuse");
}

#[test]
fn coordinator_error_paths() {
    let h = harness(60, 2);
    let mut cluster = h.client();
    let err = cluster.query("nope", 5, None).unwrap_err();
    assert!(format!("{err}").contains("unknown session"), "{err}");
    cluster.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let err = cluster.query("s", 5, Some("not_a_strategy")).unwrap_err();
    assert!(format!("{err}").contains("unknown strategy"), "{err}");
    let err = cluster.query("s", 5, Some("auto")).unwrap_err();
    assert!(format!("{err}").contains("agent"), "{err}");
    // budget larger than the pool degrades to the whole pool
    let (sel, _, _) = cluster.query("s", 10_000, Some("random")).unwrap();
    assert_eq!(sel.len(), 60);
    // the connection survives the error responses
    cluster.ping().unwrap();
    // the client-facing surface matches the single server
    let zoo = cluster.strategies().unwrap();
    assert!(zoo.contains(&"core_set".to_string()));
    let cs = cluster.cache_stats().unwrap();
    assert!(cs.get("misses").unwrap().as_i64().unwrap() > 0);
}

/// PR8 tentpole pin: N concurrent scatters interleave on the muxed wire
/// and hold at most ONE connection per worker — the coordinator never
/// falls back to dialing per in-flight RPC.
#[test]
fn mux_scatter_holds_one_connection_per_worker() {
    let h = harness(320, 3);
    let mut seed_client = h.client();
    seed_client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    // concurrent scatters from independent clients: every coordinator
    // thread funnels its shard fan-out through the shared per-worker conn
    let clients: Vec<_> = (0..4).map(|_| h.client()).collect();
    std::thread::scope(|sc| {
        for mut c in clients {
            sc.spawn(move || {
                for _ in 0..3 {
                    let (sel, _, _) = c.query("s", 24, Some("entropy")).unwrap();
                    assert_eq!(sel.len(), 24);
                }
            });
        }
    });
    let dials = h.coord_counter("pool.dials");
    assert!(
        h.coord_counter("mux.frames") > 0,
        "scatters must ride the muxed wire, not the classic pool"
    );
    assert!(
        dials <= h.n_workers() as u64,
        "mux scatter must hold at most one connection per worker \
         (dials={dials}, workers={})",
        h.n_workers()
    );
    assert_eq!(h.coord_counter("pool.retries"), 0, "no dead-conn retries expected");
}

/// PR8 parity pin: the muxed wire changes connection usage only — the
/// selections a cluster returns are bit-identical with mux on (default)
/// and off (an old-peer coordinator), for deterministic strategies.
#[test]
fn cluster_selections_match_with_mux_off() {
    let h_on = harness(320, 3);
    let h_off = ClusterHarness::builder()
        .sizes(60, 320, 0)
        .workers(3)
        .coord_tweak(|cfg| cfg.server.mux = false)
        .build();
    let mut on = h_on.client();
    let mut off = h_off.client();
    on.push_data("s", &h_on.manifest, Some(&h_on.labels.init)).unwrap();
    off.push_data("s", &h_off.manifest, Some(&h_off.labels.init)).unwrap();
    for strategy in ["random", "least_confidence", "margin_confidence", "entropy"] {
        let (a, _, _) = on.query("s", 40, Some(strategy)).unwrap();
        let (b, _, _) = off.query("s", 40, Some(strategy)).unwrap();
        assert_valid(&a, &h_on.manifest, 40);
        assert_eq!(
            ids(&a),
            ids(&b),
            "{strategy}: selections must be bit-identical mux on/off"
        );
    }
    // and the wires really differed
    assert!(h_on.coord_counter("mux.frames") > 0, "mux-on cluster must mux");
    assert_eq!(h_off.coord_counter("mux.frames"), 0, "mux-off cluster must not mux");
}

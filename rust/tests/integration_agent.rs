//! Agent-as-a-service integration (DESIGN.md §Agent): the PSHEA loop run
//! as a server-side job must reproduce the in-process `pshea::run` trace
//! bit-for-bit — same elimination order, surviving strategy, and
//! rounds-to-stop — on both serving topologies:
//!
//! * single `AlServer` (`agent_start` selects over the session's
//!   candidate view),
//! * 2-worker coordinator (each arm's select scatters over the worker
//!   shards and merges exactly, per §Cluster).
//!
//! Plus the job-lifecycle edge cases: unknown ids, status after
//! completion, cancellation actually stopping labeling spend, and a
//! worker killed mid-job degrading via shard re-dispatch. Topology
//! plumbing comes from the shared `common::cluster_harness`.

mod common;

use std::sync::Arc;
use std::time::Duration;

use alaas::agent::{run_pshea, PsheaConfig, PsheaTrace, StopReason};
use alaas::data::{generate, DatasetSpec};
use alaas::runtime::backend::ComputeBackend;
use alaas::runtime::HostBackend;
use alaas::server::AlClient;
use alaas::sim::AlExperiment;
use alaas::trainer::TrainConfig;

use common::cluster_harness::{ClusterHarness, Labels};

/// The shared fixture: every test uses this spec so the in-process
/// comparator and the remote jobs see byte-identical data.
const DATA_SEED: u64 = 7;
const AGENT_SEED: u64 = 4242;
const N_INIT: usize = 60;
const N_POOL: usize = 240;
const N_TEST: usize = 120;

fn spec() -> DatasetSpec {
    DatasetSpec::cifarsim(DATA_SEED).with_sizes(N_INIT, N_POOL, N_TEST)
}

/// The headline fixture config: unreachable target so the loop runs to
/// its round limit; min_history 2 so eliminations start at round 1.
fn agent_cfg() -> PsheaConfig {
    PsheaConfig {
        target_accuracy: 2.0,
        max_budget: 1_000_000,
        round_budget: 20,
        converge_rounds: 0,
        converge_eps: 0.0,
        max_rounds: 4,
        min_history: 2,
        initial_accuracy: None,
    }
}

fn arm_names() -> Vec<String> {
    ["least_confidence", "margin_confidence", "entropy"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// The ground truth: Algorithm 1 run in-process on the same generated
/// data, via `sim::AlExperiment` (the CLI agent's engine).
fn in_process_trace() -> PsheaTrace {
    let gen = generate(&spec());
    let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
    let mut exp = AlExperiment::from_generated(
        backend,
        &gen,
        spec().num_classes,
        TrainConfig::default(),
        AGENT_SEED,
    )
    .unwrap();
    run_pshea(&mut exp, &arm_names(), &agent_cfg()).unwrap()
}

fn elimination_order(t: &PsheaTrace) -> Vec<(usize, String)> {
    t.records
        .iter()
        .filter(|r| r.eliminated)
        .map(|r| (r.round, r.strategy.clone()))
        .collect()
}

fn assert_trace_parity(got: &PsheaTrace, want: &PsheaTrace, tag: &str) {
    assert_eq!(got.stop, want.stop, "{tag}: stop reason");
    assert_eq!(got.rounds, want.rounds, "{tag}: rounds-to-stop");
    assert_eq!(got.survivors, want.survivors, "{tag}: surviving strategy");
    assert_eq!(
        elimination_order(got),
        elimination_order(want),
        "{tag}: elimination order"
    );
    assert_eq!(got.total_budget, want.total_budget, "{tag}: budget spent");
    assert_eq!(got.records.len(), want.records.len(), "{tag}: record count");
    for (a, b) in got.records.iter().zip(&want.records) {
        assert_eq!((a.round, &a.strategy), (b.round, &b.strategy), "{tag}: record order");
        assert!(
            (a.accuracy - b.accuracy).abs() < 1e-9,
            "{tag}: round {} {} accuracy {} vs {}",
            a.round,
            a.strategy,
            a.accuracy,
            b.accuracy
        );
    }
    assert!((got.best_accuracy - want.best_accuracy).abs() < 1e-9, "{tag}: best accuracy");
}

/// Single-server fixture via the shared harness (no cluster workers).
fn single_harness() -> ClusterHarness {
    ClusterHarness::builder()
        .bucket("ag-ds")
        .data_seed(DATA_SEED)
        .sizes(N_INIT, N_POOL, N_TEST)
        .workers(0)
        .with_single(true)
        .build()
}

fn cluster_harness(n_workers: usize) -> ClusterHarness {
    ClusterHarness::builder()
        .bucket("ag-cl-ds")
        .data_seed(DATA_SEED)
        .sizes(N_INIT, N_POOL, N_TEST)
        .workers(n_workers)
        .build()
}

fn run_remote_job(
    client: &mut AlClient,
    manifest: &alaas::store::Manifest,
    labels: &Labels,
    cfg: &PsheaConfig,
) -> PsheaTrace {
    client.push_data("s", manifest, Some(&labels.init)).unwrap();
    let job = client
        .agent_start("s", &arm_names(), cfg, &labels.pool, &labels.test, AGENT_SEED)
        .unwrap();
    client.agent_result(&job, Duration::from_secs(600)).unwrap()
}

#[test]
fn remote_agent_matches_in_process_pshea_on_single_server() {
    let want = in_process_trace();
    // the loop must actually eliminate arms for the parity to be
    // meaningful: 3 arms, elimination from round 1, round limit 4
    assert_eq!(want.stop, StopReason::RoundLimit);
    assert_eq!(elimination_order(&want).len(), 2);
    assert_eq!(want.survivors.len(), 1);

    let h = single_harness();
    let mut client = h.single_client();
    let got = run_remote_job(&mut client, &h.manifest, &h.labels, &agent_cfg());
    assert_trace_parity(&got, &want, "single-server");
}

#[test]
fn remote_agent_matches_in_process_pshea_on_cluster() {
    let want = in_process_trace();
    let h = cluster_harness(2);
    let mut client = h.client();
    let got = run_remote_job(&mut client, &h.manifest, &h.labels, &agent_cfg());
    assert_trace_parity(&got, &want, "2-worker coordinator");
}

#[test]
fn agent_job_edge_cases_unknown_id_and_status_after_completion() {
    let h = single_harness();
    let mut client = h.single_client();

    // unknown job ids are clean remote errors on every method
    for call in ["agent_status", "agent_result", "agent_cancel"] {
        let mut p = alaas::json::Map::new();
        p.insert("job", alaas::json::Value::from("nope"));
        let err = client.call(call, alaas::json::Value::Object(p)).unwrap_err();
        assert!(format!("{err}").contains("unknown job"), "{call}: {err}");
    }
    // starting on an unknown session fails cleanly too
    let err = client
        .agent_start("ghost", &arm_names(), &agent_cfg(), &[], &[], 1)
        .unwrap_err();
    assert!(format!("{err}").contains("unknown session"), "{err}");

    // a quick 2-round single-arm job; status after completion keeps the
    // full round log and the final state
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let cfg = PsheaConfig { max_rounds: 2, ..agent_cfg() };
    let strategies = vec!["entropy".to_string()];
    let job = client
        .agent_start("s", &strategies, &cfg, &h.labels.pool, &h.labels.test, AGENT_SEED)
        .unwrap();
    let trace = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    assert_eq!(trace.rounds, 2);
    assert_eq!(trace.total_budget, 2 * cfg.round_budget);
    assert_eq!(trace.survivors, strategies);

    let st = client.agent_status(&job).unwrap();
    assert_eq!(st.get("status").unwrap().as_str(), Some("done"));
    assert_eq!(st.get("rounds").unwrap().as_usize(), Some(2));
    assert_eq!(
        st.get("budget_spent").unwrap().as_usize(),
        Some(2 * cfg.round_budget)
    );
    assert_eq!(
        st.get("records").unwrap().as_array().map(|a| a.len()),
        Some(2),
        "round log preserved after completion"
    );

    // label-array validation: wrong pool_labels length is refused
    let err = client
        .agent_start("s", &strategies, &cfg, &[1, 2, 3], &h.labels.test, 1)
        .unwrap_err();
    assert!(format!("{err}").contains("pool_labels"), "{err}");
}

#[test]
fn agent_cancel_mid_run_stops_labeling_spend() {
    let h = single_harness();
    let mut client = h.single_client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    // a long job: tiny rounds, no caps except the pool itself
    let cfg = PsheaConfig {
        target_accuracy: 2.0,
        max_budget: 1_000_000,
        round_budget: 1,
        converge_rounds: 0,
        converge_eps: 0.0,
        max_rounds: 0,
        min_history: 2,
        initial_accuracy: None,
    };
    let strategies = vec!["least_confidence".to_string(), "entropy".to_string()];
    let job = client
        .agent_start("s", &strategies, &cfg, &h.labels.pool, &h.labels.test, AGENT_SEED)
        .unwrap();
    // wait until the job demonstrably spends budget, then cancel
    let mut spent = 0;
    for _ in 0..600 {
        let st = client.agent_status(&job).unwrap();
        spent = st.get("budget_spent").unwrap().as_usize().unwrap();
        if spent >= 3 {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(spent >= 3, "job never started spending");
    assert!(client.agent_cancel(&job).unwrap(), "job should still be running");
    let err = client.agent_result(&job, Duration::from_secs(120)).unwrap_err();
    assert!(format!("{err}").contains("cancelled"), "{err}");
    // spend is frozen: the status after cancellation stops moving
    let st = client.agent_status(&job).unwrap();
    assert_eq!(st.get("status").unwrap().as_str(), Some("cancelled"));
    let frozen = st.get("budget_spent").unwrap().as_usize().unwrap();
    assert!(frozen < N_POOL * 2, "cancel did not stop the loop");
    std::thread::sleep(Duration::from_millis(300));
    let st = client.agent_status(&job).unwrap();
    assert_eq!(
        st.get("budget_spent").unwrap().as_usize().unwrap(),
        frozen,
        "labeling spend moved after cancellation"
    );
    // cancelling a finished job reports not-running
    assert!(!client.agent_cancel(&job).unwrap());
}

#[test]
fn worker_killed_mid_job_redispatches_and_finishes() {
    let want = in_process_trace();
    let mut h = cluster_harness(2);
    let mut client = h.client();
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let job = client
        .agent_start(
            "s",
            &arm_names(),
            &agent_cfg(),
            &h.labels.pool,
            &h.labels.test,
            AGENT_SEED,
        )
        .unwrap();
    // kill one worker immediately: its shard must be re-dispatched to the
    // survivor and the job must still finish with the exact trace (the
    // top-k merges are shard-layout independent)
    h.kill_worker(0);
    let got = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    assert_trace_parity(&got, &want, "kill-mid-job");

    let snap = h.coord_metrics.snapshot();
    let counters = snap.get("counters").unwrap();
    let counter = |name: &str| -> i64 {
        counters.get(name).and_then(|v| v.as_i64()).unwrap_or(0)
    };
    assert!(
        counter("cluster.shard_redispatch") >= 1,
        "the dead worker's shard was never re-dispatched"
    );
    assert!(counter("cluster.workers_dead") >= 1);
    assert!(
        counters.get("cluster.scan.straggler_ms").is_some(),
        "straggler gauge missing"
    );
    assert!(counter("agent.jobs_done") == 1);
}

#[test]
fn agent_metrics_flow_on_single_server() {
    let h = single_harness();
    let mut client = h.single_client();
    let got = run_remote_job(&mut client, &h.manifest, &h.labels, &agent_cfg());
    assert!(!got.survivors.is_empty());
    let m = client.metrics().unwrap();
    let counters = m.get("counters").unwrap();
    let counter = |name: &str| -> i64 {
        counters.get(name).and_then(|v| v.as_i64()).unwrap_or(0)
    };
    assert_eq!(counter("agent.jobs_started"), 1);
    assert_eq!(counter("agent.jobs_done"), 1);
    assert_eq!(counter("agent.eliminations"), 2);
    assert_eq!(counter("agent.live_arms"), 1);
    assert!(counter("agent.rounds") >= 4);
    let meters = m.get("meters").unwrap();
    assert_eq!(
        meters.get("agent.labels").unwrap().get("count").unwrap().as_usize(),
        Some(got.total_budget)
    );
    assert!(m.get("histograms").unwrap().get("agent.round").is_some());
    // the agent path records rpc latencies like every other method
    assert!(m.get("histograms").unwrap().get("rpc.agent_start").is_some());
}

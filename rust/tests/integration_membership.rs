//! Live-membership integration (ISSUE 5; DESIGN.md §Cluster): workers
//! join, die, wedge, and return mid-session via heartbeat/lease
//! auto-discovery, and the coordinator rebalances shard ownership with
//! the rendezvous planner — while selections stay bit-identical to the
//! single-server reference (the exact-merge protocols are shard-layout
//! independent). Fault injection comes from the shared
//! `common::cluster_harness`: abrupt kills, graceful leaves, wedged
//! processes (heartbeats stop, sockets stay open), scripted faults at
//! named flow points, and virtual-time lease expiry through the
//! coordinator's membership clock.
//!
//! Acceptance pins:
//! * membership enabled + no faults ⇒ selections and agent traces
//!   bit-identical to the static-config cluster and the in-process run;
//! * a worker killed mid-session ⇒ its shard is redistributed across
//!   ≥ 2 survivors (per-shard layout + scan metrics) and selections
//!   still match the single-server reference.

mod common;

use std::time::Duration;

use alaas::server::AlClient;

use common::cluster_harness::{ClusterHarness, FaultAction, FaultPoint};

/// Harness lease geometry (also the defaults in the builder): 50 ms
/// beats, 60 s lease. Expiry in tests comes from the virtual clock or
/// keepalive probes — never from a wall-clock race.
const HB_MS: u64 = 50;
const LEASE_MS: u64 = 60_000;

fn membership_harness(pool: usize, n_workers: usize, bucket: &str) -> ClusterHarness {
    ClusterHarness::builder()
        .bucket(bucket)
        .sizes(60, pool, 0)
        .workers(n_workers)
        .membership(true)
        .lease(HB_MS, LEASE_MS)
        .with_single(true)
        .build()
}

const UNCERTAINTY: [&str; 5] =
    ["random", "least_confidence", "margin_confidence", "ratio_confidence", "entropy"];

/// Selection ids from the single-server reference for `strategy`.
fn single_ids(h: &ClusterHarness, strategy: &str, budget: usize) -> Vec<u32> {
    let mut c = h.single_client();
    let (sel, _, _) = c.query("s", budget, Some(strategy)).unwrap();
    sel.iter().map(|s| s.id).collect()
}

/// Assert the membership cluster matches the single server on every
/// layout-independent strategy.
fn assert_single_parity(h: &mut ClusterHarness, client: &mut AlClient, tag: &str) {
    for strategy in UNCERTAINTY {
        let want = single_ids(h, strategy, 40);
        let got = h.query_ids(client, "s", 40, strategy);
        assert_eq!(got, want, "{tag}: {strategy} diverged from the single server");
    }
}

/// The tier-1 smoke (named in CI): one join and one graceful leave
/// mid-session, selections exact throughout, rows actually rebalanced.
#[test]
fn membership_smoke_join_and_leave() {
    let mut h = membership_harness(240, 2, "mem-smoke");
    let mut client = h.client();
    let mut single = h.single_client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    h.push(&mut client, "s");
    assert_single_parity(&mut h, &mut client, "2 workers");
    let before: Vec<(String, usize)> = h.shard_rows_by_worker("s");
    assert_eq!(before.len(), 2);

    // -- join: the next query must use the new worker --------------------
    let w = h.spawn_worker();
    h.wait_members(3);
    assert_single_parity(&mut h, &mut client, "after join");
    let after_join = h.shard_rows_by_worker("s");
    assert_eq!(after_join.len(), 3, "joiner did not receive a shard");
    let joiner_rows = after_join
        .iter()
        .find(|(addr, _)| *addr == h.worker_addr(w))
        .map(|(_, rows)| *rows)
        .unwrap_or(0);
    assert!(joiner_rows > 0, "joiner owns no rows: {after_join:?}");
    // incumbents only shrank (minimal moves)
    for (addr, rows) in &before {
        let now = after_join.iter().find(|(a, _)| a == addr).map(|(_, r)| *r).unwrap();
        assert!(now <= *rows, "{addr} grew on an unrelated join");
    }
    assert!(h.coord_counter("membership.rebalances") >= 1);
    assert!(h.coord_counter("membership.moved_rows") as usize >= joiner_rows);

    // -- graceful leave: rows rebalance immediately (no lease wait) ------
    h.leave_worker(w);
    h.wait_members(2);
    assert!(h.coord_counter("membership.deregisters") >= 1);
    assert_single_parity(&mut h, &mut client, "after leave");
    let after_leave = h.shard_rows_by_worker("s");
    assert_eq!(after_leave.len(), 2);
    assert_eq!(
        after_leave.iter().map(|(_, r)| r).sum::<usize>(),
        h.manifest.pool.len(),
        "rows lost in the rebalance"
    );
}

/// Acceptance pin 1: with membership enabled and no faults injected, a
/// 3-worker cluster produces bit-identical selections to the
/// static-config cluster (and both to the single server).
#[test]
fn no_fault_parity_with_static_config_cluster() {
    let mut mem = membership_harness(240, 3, "mem-par");
    let stat = ClusterHarness::builder()
        .bucket("mem-par")
        .sizes(60, 240, 0)
        .workers(3)
        .build();
    let mut mc = mem.client();
    let mut sc = stat.client();
    let mut single = mem.single_client();
    single.push_data("s", &mem.manifest, Some(&mem.labels.init)).unwrap();
    mem.push(&mut mc, "s");
    sc.push_data("s", &stat.manifest, Some(&stat.labels.init)).unwrap();
    for strategy in UNCERTAINTY {
        let want = single_ids(&mem, strategy, 40);
        let got_mem = mem.query_ids(&mut mc, "s", 40, strategy);
        let (got_stat, _, _) = sc.query("s", 40, Some(strategy)).unwrap();
        let got_stat: Vec<u32> = got_stat.iter().map(|s| s.id).collect();
        assert_eq!(got_mem, want, "{strategy}: membership != single");
        assert_eq!(got_mem, got_stat, "{strategy}: membership != static config");
    }
    // no faults ⇒ no rebalances, stable generation (3 joins)
    assert_eq!(mem.coord_counter("membership.rebalances"), 0);
    assert_eq!(mem.coord_counter("membership.live_workers"), 3);
    assert_eq!(mem.coord_counter("membership.expirations"), 0);
}

/// Acceptance pin 1b: the server-side PSHEA agent produces the exact
/// in-process trace on a membership-enabled cluster (arm scatters run
/// against the versioned view; exact-merge arms are layout-independent).
#[test]
fn no_fault_agent_trace_parity() {
    use alaas::agent::{run_pshea, PsheaConfig};
    use alaas::data::{generate, DatasetSpec};
    use alaas::runtime::backend::ComputeBackend;
    use alaas::runtime::HostBackend;
    use alaas::sim::AlExperiment;
    use alaas::trainer::TrainConfig;
    use std::sync::Arc;

    let spec = DatasetSpec::cifarsim(7).with_sizes(60, 240, 120);
    let cfg = PsheaConfig {
        target_accuracy: 2.0,
        max_budget: 1_000_000,
        round_budget: 20,
        converge_rounds: 0,
        converge_eps: 0.0,
        max_rounds: 4,
        min_history: 2,
        initial_accuracy: None,
    };
    let arms: Vec<String> =
        ["least_confidence", "margin_confidence", "entropy"].map(String::from).to_vec();
    let want = {
        let gen = generate(&spec);
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        let mut exp = AlExperiment::from_generated(
            backend,
            &gen,
            spec.num_classes,
            TrainConfig::default(),
            4242,
        )
        .unwrap();
        run_pshea(&mut exp, &arms, &cfg).unwrap()
    };

    let mut h = ClusterHarness::builder()
        .bucket("mem-ag")
        .sizes(60, 240, 120)
        .workers(2)
        .membership(true)
        .lease(HB_MS, LEASE_MS)
        .build();
    let mut client = h.client();
    h.push(&mut client, "s");
    let job = client
        .agent_start("s", &arms, &cfg, &h.labels.pool, &h.labels.test, 4242)
        .unwrap();
    let got = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    assert_eq!(got.stop, want.stop, "stop reason");
    assert_eq!(got.rounds, want.rounds, "rounds-to-stop");
    assert_eq!(got.survivors, want.survivors, "surviving strategy");
    assert_eq!(got.total_budget, want.total_budget, "budget spent");
    for (a, b) in got.records.iter().zip(&want.records) {
        assert!(
            (a.accuracy - b.accuracy).abs() < 1e-9,
            "round {} {} accuracy {} vs {}",
            a.round,
            a.strategy,
            a.accuracy,
            b.accuracy
        );
    }
}

/// Acceptance pin 2: a worker killed mid-session is evicted (keepalive
/// probe on the suspect half of its lease) and its shard is
/// redistributed across **both** survivors — not dumped on one — while
/// selections keep matching the single-server reference.
#[test]
fn dead_worker_shard_splits_across_survivors() {
    let mut h = membership_harness(240, 3, "mem-kill");
    let mut client = h.client();
    let mut single = h.single_client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    h.push(&mut client, "s");
    let before = h.shard_rows_by_worker("s");
    assert_eq!(before.len(), 3);
    let dead_addr = h.worker_addr(0);
    let dead_rows =
        before.iter().find(|(a, _)| *a == dead_addr).map(|(_, r)| *r).unwrap();
    assert!(dead_rows > 0);

    h.kill_worker(0);
    // age every lease into the suspect half: the next sweep probes all
    // members, survivors pass, the dead socket fails and is evicted —
    // before any query pays a scatter dial timeout
    h.advance_time_ms(LEASE_MS / 2 + 1);
    h.wait_member_gone(&dead_addr);
    h.wait_members(2);
    assert!(
        h.coord_counter("membership.probe_evictions")
            + h.coord_counter("membership.evictions")
            >= 1,
        "dead worker never evicted"
    );

    // next query rebalances: the dead shard splits across BOTH survivors
    assert_single_parity(&mut h, &mut client, "after kill");
    let after = h.shard_rows_by_worker("s");
    assert_eq!(after.len(), 2, "expected 2 shards after the kill: {after:?}");
    let mut gained = 0;
    for (addr, rows) in &after {
        let was = before.iter().find(|(a, _)| a == addr).map(|(_, r)| *r).unwrap();
        assert!(*rows > was, "{addr} gained nothing from the dead shard");
        gained += rows - was;
    }
    assert_eq!(gained, dead_rows, "dead worker's rows were not fully redistributed");
    // per-shard scan metrics: both surviving shard positions rescanned
    let snap = h.coord_metrics.snapshot();
    let hists = snap.get("histograms").unwrap();
    for i in 0..2 {
        let name = format!("cluster.shard{i}.scan");
        assert!(
            hists.get(&name).and_then(|s| s.get("count")).and_then(|c| c.as_i64()).unwrap_or(0)
                >= 1,
            "{name} never recorded after the rebalance"
        );
    }
    assert!(h.coord_counter("membership.rebalances") >= 1);
    assert!(h.coord_counter("membership.moved_rows") as usize >= dead_rows);
}

/// A worker killed *at the moment a query is issued* (scripted fault at
/// the named BeforeQuery point): whichever path races first — in-flight
/// shard re-dispatch against the pinned layout, or eviction + rebalance
/// — the selection must equal the single server's.
#[test]
fn kill_at_query_point_keeps_selection_exact() {
    let mut h = membership_harness(200, 3, "mem-script");
    let mut client = h.client();
    let mut single = h.single_client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    h.push(&mut client, "s");
    let want = single_ids(&h, "entropy", 40);

    h.script(FaultPoint::BeforeQuery, FaultAction::Kill(0));
    let got = h.query_ids(&mut client, "s", 40, "entropy");
    assert_eq!(got, want, "kill at BeforeQuery changed the selection");
    // once the view settles, the layout is fully rebalanced and still exact
    h.advance_time_ms(LEASE_MS / 2 + 1);
    h.wait_members(2);
    let got = h.query_ids(&mut client, "s", 40, "entropy");
    assert_eq!(got, want, "post-eviction selection diverged");
}

/// A *wedged* worker (process alive, heartbeats stopped) passes
/// keepalive probes — only virtual-time lease expiry can evict it. After
/// resuming, it re-joins as a fresh member and takes back a slice.
#[test]
fn hung_worker_expires_via_virtual_time_then_rejoins() {
    let mut h = membership_harness(200, 3, "mem-hang");
    let mut client = h.client();
    let mut single = h.single_client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    h.push(&mut client, "s");
    let hung_addr = h.worker_addr(0);

    h.hang_worker(0);
    // jump past the full lease: the hung worker cannot renew, so the
    // sweep expires it. (Live workers are transiently expired too and
    // re-join on their next beat within ~one heartbeat — the flap is
    // absorbed by waiting for the view to settle.)
    h.advance_time_ms(LEASE_MS + 1);
    h.wait_member_gone(&hung_addr);
    h.wait_members(2);
    assert!(h.coord_counter("membership.expirations") >= 1, "lease never expired");
    assert_single_parity(&mut h, &mut client, "hung worker evicted");
    // the wedged process is still alive — it was evicted by lease, not
    // by a dead socket
    AlClient::connect(&hung_addr).unwrap().ping().unwrap();

    // recovery: heartbeats resume, the worker re-joins, rows come back
    h.resume_worker(0);
    h.wait_members(3);
    assert_single_parity(&mut h, &mut client, "hung worker rejoined");
    let layout = h.shard_rows_by_worker("s");
    assert!(
        layout.iter().any(|(a, r)| *a == hung_addr && *r > 0),
        "rejoined worker owns no rows: {layout:?}"
    );
}

/// Coordinator restart: the workers' heartbeat loops keep beating at the
/// old address, re-register with the new process on their own, and a
/// re-pushed session serves exact selections again.
#[test]
fn coordinator_restart_workers_reregister() {
    let mut h = membership_harness(160, 2, "mem-coord-restart");
    let mut client = h.client();
    let mut single = h.single_client();
    single.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    h.push(&mut client, "s");
    assert_single_parity(&mut h, &mut client, "before restart");

    h.restart_coordinator();
    // rediscovery is automatic: no register calls, no static config
    h.wait_members(2);
    let mut client = h.client();
    // sessions died with the coordinator; a re-push restores service
    let err = client.query("s", 10, Some("entropy")).unwrap_err();
    assert!(format!("{err}").contains("unknown session"), "{err}");
    h.push(&mut client, "s");
    assert_single_parity(&mut h, &mut client, "after restart");
}

/// The membership RPC surface and its metrics, pinned: heartbeat,
/// members (generation + leases), deregister of an unknown address, the
/// membership gauges, and — the ISSUE 5 pool satellite — keepalive
/// probes counting under `pool.keepalive_probes`, never `pool.dials`.
#[test]
fn heartbeat_members_rpcs_and_metrics_pins() {
    let mut h = membership_harness(160, 2, "mem-rpc");
    let mut client = h.client();
    h.push(&mut client, "s");
    h.query_ids(&mut client, "s", 20, "entropy");

    // members: generation-numbered view with live leases
    let (generation, members) = h.members_view();
    assert!(generation >= 2, "two joins must have bumped the generation");
    assert_eq!(members.len(), 2);
    let v = client.members().unwrap();
    assert_eq!(v.get("enabled").unwrap().as_bool(), Some(true));
    for e in v.get("members").unwrap().as_array().unwrap() {
        let left = e.get("lease_ms_left").unwrap().as_usize().unwrap();
        assert!(left > 0, "live member with an expired lease in `members`");
    }

    // heartbeat on a live member: renewal, not a join; same generation
    let g = client.heartbeat(&h.worker_addr(0)).unwrap();
    assert_eq!(g, generation, "a renewal must not bump the generation");
    // deregister of a stranger is a clean no-op
    assert!(!client.deregister("127.0.0.1:9").unwrap());

    // gauges + counters
    assert!(h.coord_counter("membership.heartbeats") >= 3);
    assert_eq!(h.coord_counter("membership.joins"), 2);
    assert_eq!(h.coord_counter("membership.generation"), generation);
    assert_eq!(h.coord_counter("membership.live_workers"), 2);

    // keepalive probes: age the leases into the suspect half, sweep, and
    // verify probes ran without touching pool.dials (the PR 4 pin's
    // invariant survives health checking)
    let dials_before = h.coord_counter("pool.dials");
    h.advance_time_ms(LEASE_MS / 2 + 1);
    h.tick();
    assert!(
        h.coord_counter("pool.keepalive_probes") >= 1,
        "suspect members were never probed"
    );
    assert_eq!(
        h.coord_counter("pool.dials"),
        dials_before,
        "keepalive probes leaked into pool.dials"
    );
    h.wait_members(2); // probes passed: nobody was evicted
    assert_eq!(h.coord_counter("membership.probe_evictions"), 0);

    // worker-side heartbeat metrics are visible over the worker's own
    // metrics RPC
    let m = AlClient::connect(&h.worker_addr(0)).unwrap().metrics().unwrap();
    let hb = m
        .get("counters")
        .and_then(|c| c.get("membership.worker.heartbeats"))
        .and_then(|v| v.as_i64())
        .unwrap_or(0);
    assert!(hb >= 1, "worker never recorded a successful heartbeat");
}

/// Static-config interop: a `--discover` worker pointed at a coordinator
/// with membership *disabled* still registers (heartbeat degrades to
/// `register`), so mixed fleets keep working.
#[test]
fn heartbeat_degrades_to_register_when_membership_disabled() {
    let mut h = ClusterHarness::builder()
        .bucket("mem-fallback")
        .sizes(40, 120, 0)
        .workers(0)
        .build();
    let w = h.add_worker_unregistered();
    let mut client = h.client();
    let g = client.heartbeat(&h.worker_addr(w)).unwrap();
    assert_eq!(g, 0, "disabled membership reports generation 0");
    assert_eq!(h.coordinator().live_workers(), 1);
    h.push(&mut client, "s");
    let sel = h.query_ids(&mut client, "s", 15, "least_confidence");
    assert_eq!(sel.len(), 15);
    let v = client.members().unwrap();
    assert_eq!(v.get("enabled").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("members").unwrap().as_array().unwrap().len(), 1);
}

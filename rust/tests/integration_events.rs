//! Push event streaming for agent jobs (DESIGN.md §Events): the
//! `job_subscribe` RPC must deliver every job event as a sequenced,
//! gapless push stream over the multiplexed wire — replacing the
//! `agent_status` sleep-poll loop — and the streamed records must be
//! bit-identical to the durable coordinator's WAL records, mid-job
//! catch-up and crash-restart reconnects included.
//!
//! Acceptance pins (ISSUE 10):
//! * following a 2-worker cluster job via the stream reproduces the
//!   `agent_result` trace exactly, with zero `agent_status` calls after
//!   `agent_start` (metrics-asserted);
//! * a subscriber attaching mid-job catches up from seq 1 and the full
//!   streamed sequence equals the WAL's job-scoped records verbatim.
//!
//! (The crash-restart reconnect pin lives with the other crash-safety
//! tests in `integration_durability.rs`.)

mod common;

use std::time::Duration;

use alaas::agent::job as agent_job;
use alaas::agent::{PsheaConfig, PsheaTrace};
use alaas::durable::{DurabilityConfig, DurableLog};
use alaas::json::Value;
use alaas::server::{AlClient, JobEvent};

use common::cluster_harness::ClusterHarness;

/// Same fixture as `integration_agent.rs` so the traces have real
/// structure (3 arms, 2 eliminations, 4 rounds).
const DATA_SEED: u64 = 7;
const AGENT_SEED: u64 = 4242;
const N_INIT: usize = 60;
const N_POOL: usize = 240;
const N_TEST: usize = 120;

fn agent_cfg() -> PsheaConfig {
    PsheaConfig {
        target_accuracy: 2.0,
        max_budget: 1_000_000,
        round_budget: 20,
        converge_rounds: 0,
        converge_eps: 0.0,
        max_rounds: 4,
        min_history: 2,
        initial_accuracy: None,
    }
}

fn arm_names() -> Vec<String> {
    ["least_confidence", "margin_confidence", "entropy"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn cluster(bucket: &str, durable: bool) -> ClusterHarness {
    ClusterHarness::builder()
        .bucket(bucket)
        .data_seed(DATA_SEED)
        .sizes(N_INIT, N_POOL, N_TEST)
        .workers(2)
        .durable(durable)
        // keep every record in the WAL (no compaction) so the
        // stream-vs-WAL comparison sees the full physical sequence
        .coord_tweak(|c| c.durability.snapshot_every = 1_000_000)
        .build()
}

fn start_job(h: &ClusterHarness, client: &mut AlClient) -> String {
    client.push_data("s", &h.manifest, Some(&h.labels.init)).unwrap();
    let job = client
        .agent_start("s", &arm_names(), &agent_cfg(), &h.labels.pool, &h.labels.test, AGENT_SEED)
        .unwrap();
    h.track_job(&job);
    job
}

/// Every event's sequence number must be exactly its 1-based position:
/// no gaps, no duplicates, no reordering.
fn assert_gapless(events: &[JobEvent], tag: &str) {
    for (i, ev) in events.iter().enumerate() {
        assert_eq!(ev.seq, (i + 1) as u64, "{tag}: event {i} has seq {}", ev.seq);
    }
}

fn event_type(ev: &Value) -> &str {
    ev.get("t").and_then(Value::as_str).unwrap_or("")
}

/// The terminal `job_done` event carries the full trace; parse it the
/// same way `agent_result` replies are parsed.
fn streamed_trace(events: &[JobEvent]) -> PsheaTrace {
    let done = events.last().expect("stream delivered no events");
    assert_eq!(event_type(&done.value), "job_done", "stream must end on job_done");
    agent_job::trace_from_value(done.value.get("trace").expect("job_done missing trace"))
        .unwrap()
}

fn assert_trace_parity(got: &PsheaTrace, want: &PsheaTrace, tag: &str) {
    assert_eq!(got.stop, want.stop, "{tag}: stop reason");
    assert_eq!(got.rounds, want.rounds, "{tag}: rounds-to-stop");
    assert_eq!(got.survivors, want.survivors, "{tag}: surviving strategy");
    assert_eq!(got.total_budget, want.total_budget, "{tag}: budget spent");
    assert_eq!(got.records.len(), want.records.len(), "{tag}: record count");
    for (a, b) in got.records.iter().zip(&want.records) {
        assert_eq!((a.round, &a.strategy), (b.round, &b.strategy), "{tag}: record order");
        assert!(
            (a.accuracy - b.accuracy).abs() < 1e-9,
            "{tag}: round {} {} accuracy {} vs {}",
            a.round,
            a.strategy,
            a.accuracy,
            b.accuracy
        );
    }
}

/// The job-scoped records a terminated coordinator left in its WAL, in
/// physical append order, `job_start` excluded (events start after it).
fn wal_job_records(data_dir: &str, job: &str) -> Vec<Value> {
    let cfg = DurabilityConfig {
        enabled: true,
        data_dir: data_dir.to_string(),
        ..DurabilityConfig::default()
    };
    let (_log, replay) = DurableLog::open(&cfg, None).unwrap();
    assert!(replay.snapshot.is_none(), "test fixture must not compact");
    replay
        .records
        .into_iter()
        .filter(|r| {
            r.get("job").and_then(Value::as_str) == Some(job)
                && r.get("t").and_then(Value::as_str) != Some("job_start")
        })
        .collect()
}

/// Headline: follow a 2-worker cluster job start-to-finish through the
/// push stream. The streamed `job_done` trace and the per-round
/// `job_record` events must match `agent_result` exactly, and the
/// coordinator must never serve an `agent_status` poll.
#[test]
fn streamed_trace_matches_agent_result_with_zero_status_polls() {
    let h = cluster("ev-follow", false);
    let mut client = h.client();
    let job = start_job(&h, &mut client);

    let mut stream = client.subscribe_job(&job, 0).unwrap();
    assert_eq!(stream.status(), "running");
    let mut events: Vec<JobEvent> = Vec::new();
    for item in stream.by_ref() {
        events.push(item.unwrap());
    }
    assert_eq!(stream.end_reason(), Some("all events delivered"), "stream must end cleanly");
    assert_gapless(&events, "follow");

    let want = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    assert_trace_parity(&streamed_trace(&events), &want, "streamed job_done");

    // the per-round record events ARE the trace, in order
    let streamed_records: Vec<_> = events
        .iter()
        .filter(|e| event_type(&e.value) == "job_record")
        .map(|e| agent_job::record_from_value(e.value.get("record").unwrap()).unwrap())
        .collect();
    assert_eq!(streamed_records.len(), want.records.len());
    for (a, b) in streamed_records.iter().zip(&want.records) {
        assert_eq!((a.round, &a.strategy), (b.round, &b.strategy));
        assert!((a.accuracy - b.accuracy).abs() < 1e-9);
        assert_eq!(a.budget_spent, b.budget_spent);
    }
    // one spend per arm-round, none lost
    assert!(
        events.iter().any(|e| event_type(&e.value) == "job_spend"),
        "spend events missing from the stream"
    );

    // the poll loop is dead: the server never saw an agent_status call
    let snap = h.coord_metrics.snapshot();
    let hist = snap.get("histograms").unwrap();
    assert!(
        hist.get("rpc.agent_status").is_none(),
        "agent_status was polled despite the push stream"
    );
    assert!(hist.get("rpc.job_subscribe").is_some(), "job_subscribe was never served");
}

/// A subscriber attaching mid-job (at least one completed round) catches
/// up from seq 1, follows to the end, and the full streamed sequence is
/// bit-identical to the WAL's job-scoped records — same order, same
/// values, 1-based contiguous seqs.
#[test]
fn mid_job_catch_up_stream_equals_wal_records() {
    let h = cluster("ev-wal", true);
    let mut client = h.client();
    let job = start_job(&h, &mut client);

    // let the job make real progress before subscribing, so the stream
    // exercises the catch-up replay path, not just live tailing
    let mut rounds = 0;
    for _ in 0..1_500 {
        let st = client.agent_status(&job).unwrap();
        rounds = st.get("rounds").unwrap().as_usize().unwrap();
        if rounds >= 1 || st.get("status").unwrap().as_str() != Some("running") {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(rounds >= 1, "job never completed a round");

    let mut stream = client.subscribe_job(&job, 0).unwrap();
    let mut events: Vec<JobEvent> = Vec::new();
    for item in stream.by_ref() {
        events.push(item.unwrap());
    }
    assert_eq!(stream.end_reason(), Some("all events delivered"));
    assert_gapless(&events, "catch-up");
    client.agent_result(&job, Duration::from_secs(600)).unwrap();

    // seal the log (coordinator down), then replay it independently
    let dir = h.data_dir.clone().expect("durable harness has a data dir");
    drop(client);
    drop(h);
    let wal = wal_job_records(&dir, &job);
    assert_eq!(
        events.len(),
        wal.len(),
        "streamed event count diverges from the WAL's job records"
    );
    for (i, (ev, rec)) in events.iter().zip(&wal).enumerate() {
        assert_eq!(
            &ev.value, rec,
            "event seq {} (index {i}) is not the WAL record",
            ev.seq
        );
    }
}

/// The stream rides through a worker kill: the coordinator re-dispatches
/// the dead worker's shard (exact merges are layout-independent), the
/// job finishes with the same trace, and the follower — whose connection
/// is to the coordinator, not the worker — sees an uninterrupted gapless
/// stream the whole way.
#[test]
fn stream_survives_worker_kill_and_redispatch() {
    let mut h = cluster("ev-kill", false);
    let mut client = h.client();
    let job = start_job(&h, &mut client);

    let mut stream = client.subscribe_job(&job, 0).unwrap();
    h.kill_worker(0);
    let mut events: Vec<JobEvent> = Vec::new();
    for item in stream.by_ref() {
        events.push(item.unwrap());
    }
    assert_eq!(stream.end_reason(), Some("all events delivered"));
    assert_gapless(&events, "worker-kill");

    let want = client.agent_result(&job, Duration::from_secs(600)).unwrap();
    assert_trace_parity(&streamed_trace(&events), &want, "streamed through kill");
    let snap = h.coord_metrics.snapshot();
    let counters = snap.get("counters").unwrap();
    assert!(
        counters.get("cluster.shard_redispatch").and_then(Value::as_i64).unwrap_or(0) >= 1,
        "the dead worker's shard was never re-dispatched"
    );
}

// The remaining streaming pin — a subscriber reconnecting across a
// coordinator crash-restart without gaps or duplicates — lives with the
// other crash-safety tests in `integration_durability.rs`.

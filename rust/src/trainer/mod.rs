//! Last-layer fine-tuning — the paper's model-update step ("we only
//! fine-tune ResNet-18's last layer with the AL-selected and human-labeled
//! samples", §4.1).
//!
//! The head is a softmax-regression layer `(w: [D, C], b: [C])` trained on
//! trunk embeddings via the AOT `train_step` artifact (or the host
//! reference — anything implementing `ComputeBackend`). Evaluation
//! reports top-1/top-5, the two columns of Table 2.

use crate::runtime::backend::{ComputeBackend, RtResult};
use crate::util::mat::Mat;
use crate::util::rng::Rng;

/// The fine-tuned classifier head.
#[derive(Debug, Clone, PartialEq)]
pub struct LinearHead {
    pub w: Mat,
    pub b: Vec<f32>,
}

impl LinearHead {
    pub fn zeros(embed_dim: usize, num_classes: usize) -> Self {
        LinearHead { w: Mat::zeros(embed_dim, num_classes), b: vec![0.0; num_classes] }
    }

    pub fn num_classes(&self) -> usize {
        self.b.len()
    }
}

/// Fine-tuning hyperparameters (defaults follow the paper's simple setup).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    /// Per-epoch multiplicative LR decay.
    pub lr_decay: f32,
    /// Minibatch size (must be <= the compiled train_batch, 64).
    pub batch: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 30, lr: 0.8, lr_decay: 0.97, batch: 64, seed: 0 }
    }
}

/// Accuracy pair reported everywhere (Table 2 columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResult {
    pub top1: f64,
    pub top5: f64,
    pub n: usize,
}

/// Train a head from scratch on labeled embeddings.
///
/// Returns the head and the per-epoch mean losses (the PSHEA predictor and
/// the convergence checks consume accuracy, but losses make the examples'
/// logs informative).
pub fn fit(
    backend: &dyn ComputeBackend,
    embeddings: &Mat,
    labels: &[u8],
    num_classes: usize,
    cfg: &TrainConfig,
) -> RtResult<(LinearHead, Vec<f32>)> {
    assert_eq!(embeddings.rows(), labels.len(), "embeddings/labels length");
    let n = labels.len();
    let mut head = LinearHead::zeros(embeddings.cols(), num_classes);
    if n == 0 {
        return Ok((head, vec![]));
    }
    let mut rng = Rng::new(cfg.seed ^ 0x7ea1);
    let mut order: Vec<usize> = (0..n).collect();
    let mut lr = cfg.lr;
    let mut losses = Vec::with_capacity(cfg.epochs);
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f64;
        let mut batches = 0usize;
        for chunk in order.chunks(cfg.batch) {
            let x = embeddings.gather_rows(chunk);
            let mut y = Mat::zeros(chunk.len(), num_classes);
            for (r, &i) in chunk.iter().enumerate() {
                y.set(r, labels[i] as usize, 1.0);
            }
            let loss = backend.train_step(&mut head.w, &mut head.b, &x, &y, lr)?;
            epoch_loss += loss as f64;
            batches += 1;
        }
        losses.push((epoch_loss / batches.max(1) as f64) as f32);
        lr *= cfg.lr_decay;
    }
    Ok((head, losses))
}

/// Top-1/top-5 accuracy of `head` on labeled embeddings.
pub fn evaluate(
    backend: &dyn ComputeBackend,
    head: &LinearHead,
    embeddings: &Mat,
    labels: &[u8],
) -> RtResult<EvalResult> {
    assert_eq!(embeddings.rows(), labels.len(), "embeddings/labels length");
    let n = labels.len();
    if n == 0 {
        return Ok(EvalResult { top1: 0.0, top5: 0.0, n: 0 });
    }
    let logits = backend.eval_logits(embeddings, &head.w, &head.b)?;
    let c = head.num_classes();
    let k = 5.min(c);
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    for i in 0..n {
        let row = logits.row(i);
        let truth = labels[i] as usize;
        let truth_logit = row[truth];
        // rank of the true class = #logits strictly greater (ties favor
        // the true class, deterministic across backends)
        let rank = row.iter().filter(|&&v| v > truth_logit).count();
        if rank == 0 {
            top1 += 1;
        }
        if rank < k {
            top5 += 1;
        }
    }
    Ok(EvalResult { top1: top1 as f64 / n as f64, top5: top5 as f64 / n as f64, n })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;

    /// Linearly separable toy embeddings: class k concentrated on dim k.
    fn toy(n: usize, d: usize, c: usize, seed: u64) -> (Mat, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let mut emb = Mat::zeros(n, d);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = rng.below(c);
            labels.push(class as u8);
            let row = emb.row_mut(i);
            for j in 0..d {
                row[j] = 0.3 * rng.normal_f32();
            }
            row[class] += 2.0;
        }
        (emb, labels)
    }

    #[test]
    fn fit_reaches_high_accuracy_on_separable_data() {
        let backend = HostBackend::new();
        let (emb, labels) = toy(400, 16, 10, 1);
        let (head, losses) =
            fit(&backend, &emb, &labels, 10, &TrainConfig::default()).unwrap();
        assert!(losses[0] > losses[losses.len() - 1], "loss must fall: {losses:?}");
        let acc = evaluate(&backend, &head, &emb, &labels).unwrap();
        assert!(acc.top1 > 0.9, "top1 = {}", acc.top1);
        assert!(acc.top5 >= acc.top1);
        assert!(acc.top5 > 0.99, "top5 = {}", acc.top5);
    }

    /// Harder toy: weak signal, strong noise — accuracy is data-limited.
    fn hard_toy(n: usize, d: usize, c: usize, seed: u64) -> (Mat, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let mut emb = Mat::zeros(n, d);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = rng.below(c);
            labels.push(class as u8);
            let row = emb.row_mut(i);
            for j in 0..d {
                row[j] = 1.0 * rng.normal_f32();
            }
            row[class] += 0.8;
        }
        (emb, labels)
    }

    #[test]
    fn more_data_helps_generalization() {
        let backend = HostBackend::new();
        let (test_emb, test_labels) = hard_toy(800, 16, 10, 99);
        let mut accs = vec![];
        for n in [30usize, 600] {
            let (emb, labels) = hard_toy(n, 16, 10, 7);
            let cfg = TrainConfig { epochs: 20, ..Default::default() };
            let (head, _) = fit(&backend, &emb, &labels, 10, &cfg).unwrap();
            accs.push(evaluate(&backend, &head, &test_emb, &test_labels).unwrap().top1);
        }
        assert!(
            accs[1] > accs[0] + 0.02,
            "600 samples should clearly beat 30: {accs:?}"
        );
    }

    #[test]
    fn fit_is_deterministic() {
        let backend = HostBackend::new();
        let (emb, labels) = toy(100, 8, 4, 3);
        let cfg = TrainConfig { epochs: 5, ..Default::default() };
        let (h1, l1) = fit(&backend, &emb, &labels, 4, &cfg).unwrap();
        let (h2, l2) = fit(&backend, &emb, &labels, 4, &cfg).unwrap();
        assert_eq!(h1, h2);
        assert_eq!(l1, l2);
    }

    #[test]
    fn empty_training_set_gives_zero_head() {
        let backend = HostBackend::new();
        let emb = Mat::zeros(0, 8);
        let (head, losses) =
            fit(&backend, &emb, &[], 4, &TrainConfig::default()).unwrap();
        assert_eq!(head, LinearHead::zeros(8, 4));
        assert!(losses.is_empty());
    }

    #[test]
    fn evaluate_top5_with_fewer_classes_than_5() {
        let backend = HostBackend::new();
        let (emb, labels) = toy(50, 8, 3, 4);
        let head = LinearHead::zeros(8, 3);
        let r = evaluate(&backend, &head, &emb, &labels).unwrap();
        // zero head: all logits tie, rank = 0 for everyone -> top1 = 100%
        // by the tie convention; top5 covers all 3 classes.
        assert_eq!(r.top1, 1.0);
        assert_eq!(r.top5, 1.0);
        assert_eq!(r.n, 50);
    }

    #[test]
    fn tail_minibatch_smaller_than_batch_is_fine() {
        let backend = HostBackend::new();
        let (emb, labels) = toy(70, 8, 4, 5); // 70 = 64 + 6 tail
        let cfg = TrainConfig { epochs: 3, batch: 64, ..Default::default() };
        let (_, losses) = fit(&backend, &emb, &labels, 4, &cfg).unwrap();
        assert_eq!(losses.len(), 3);
    }
}

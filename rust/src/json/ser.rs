//! JSON serializer: compact and pretty writers.

use super::value::Value;

/// Compact serialization (wire format).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, None, 0);
    out
}

/// Pretty serialization with 2-space indents (manifests, config dumps).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, Some(2), 0);
    out
}

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(out, *n),
        Value::String(s) => write_string(out, s),
        Value::Array(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_value(out, e, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push(']');
        }
        Value::Object(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, e, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like serde_json's lossy mode would
        // reject — we choose null and document it.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // Shortest roundtrip representation rust provides.
        out.push_str(&format!("{n}"));
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::value::obj;
    use super::*;

    #[test]
    fn compact_shapes() {
        let v = obj([
            ("a", Value::from(1i64)),
            ("b", Value::Array(vec![Value::from(true), Value::Null])),
        ]);
        assert_eq!(to_string(&v), "{\"a\":1,\"b\":[true,null]}");
    }

    #[test]
    fn pretty_is_indented() {
        let v = obj([("a", Value::from(1i64))]);
        assert_eq!(to_string_pretty(&v), "{\n  \"a\": 1\n}");
    }

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(to_string(&Value::from(3i64)), "3");
        assert_eq!(to_string(&Value::Number(2.5)), "2.5");
        assert_eq!(to_string(&Value::Number(-0.0)), "0");
    }

    #[test]
    fn control_chars_escaped() {
        assert_eq!(to_string(&Value::from("\u{1}")), "\"\\u0001\"");
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(to_string(&Value::Number(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Number(f64::INFINITY)), "null");
    }
}

//! JSON value model: `Value` plus an insertion-ordered `Map`.

/// Insertion-ordered string map (JSON object). Linear lookup is fine at the
/// sizes we carry (RPC frames, manifests); ordering stability matters more
/// (deterministic serialization for goldens and shas).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Map {
    entries: Vec<(String, Value)>,
}

impl Map {
    pub fn new() -> Self {
        Map { entries: Vec::new() }
    }

    /// Insert or replace; replacement keeps the original position.
    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<Value>) {
        let key = key.into();
        let value = value.into();
        if let Some(slot) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = value;
        } else {
            self.entries.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Remove a key, returning its value; the other entries keep their
    /// order. Lets RPC decode move large subtrees out of an envelope
    /// instead of cloning them.
    pub fn remove(&mut self, key: &str) -> Option<Value> {
        let i = self.entries.iter().position(|(k, _)| k == key)?;
        Some(self.entries.remove(i).1)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Value)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(k, _)| k.as_str())
    }
}

impl FromIterator<(String, Value)> for Map {
    fn from_iter<I: IntoIterator<Item = (String, Value)>>(iter: I) -> Self {
        let mut m = Map::new();
        for (k, v) in iter {
            m.insert(k, v);
        }
        m
    }
}

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All JSON numbers; integers survive exactly up to 2^53.
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(Map),
}

impl Value {
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view: only when the number is a whole value in i64 range.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Number(n) if n.fract() == 0.0 && n.abs() <= 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&Map> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Array element access.
    pub fn idx(&self, i: usize) -> Option<&Value> {
        self.as_array().and_then(|a| a.get(i))
    }

    /// Dotted-path access: `v.path("active_learning.model.name")`.
    pub fn path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Self {
        Value::Number(n as f64)
    }
}
impl From<i32> for Value {
    fn from(n: i32) -> Self {
        Value::Number(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Self {
        Value::Number(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::String(s)
    }
}
impl From<Vec<Value>> for Value {
    fn from(a: Vec<Value>) -> Self {
        Value::Array(a)
    }
}
impl From<Map> for Value {
    fn from(m: Map) -> Self {
        Value::Object(m)
    }
}
impl<T: Into<Value> + Clone> From<&[T]> for Value {
    fn from(xs: &[T]) -> Self {
        Value::Array(xs.iter().cloned().map(Into::into).collect())
    }
}

/// Convenience constructor for object literals:
/// `obj([("a", Value::from(1)), ("b", Value::from("x"))])`.
pub fn obj<const N: usize>(entries: [(&str, Value); N]) -> Value {
    let mut m = Map::new();
    for (k, v) in entries {
        m.insert(k, v);
    }
    Value::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_insert_replace_keeps_order() {
        let mut m = Map::new();
        m.insert("a", Value::from(1));
        m.insert("b", Value::from(2));
        m.insert("a", Value::from(3));
        assert_eq!(m.keys().collect::<Vec<_>>(), vec!["a", "b"]);
        assert_eq!(m.get("a").unwrap().as_i64(), Some(3));
    }

    #[test]
    fn map_remove_takes_value_and_keeps_order() {
        let mut m = Map::new();
        m.insert("a", Value::from(1));
        m.insert("b", Value::from(2));
        m.insert("c", Value::from(3));
        assert_eq!(m.remove("b").unwrap().as_i64(), Some(2));
        assert!(m.remove("b").is_none());
        assert_eq!(m.keys().collect::<Vec<_>>(), vec!["a", "c"]);
    }

    #[test]
    fn path_access() {
        let v = obj([(
            "active_learning",
            obj([("model", obj([("name", Value::from("resnet18"))]))]),
        )]);
        assert_eq!(
            v.path("active_learning.model.name").and_then(Value::as_str),
            Some("resnet18")
        );
        assert!(v.path("active_learning.missing.name").is_none());
    }

    #[test]
    fn integer_boundaries() {
        assert_eq!(Value::from(42i64).as_i64(), Some(42));
        assert_eq!(Value::Number(1.5).as_i64(), None);
        assert_eq!(Value::Number(1e306).as_i64(), None);
        assert_eq!(Value::from(7usize).as_usize(), Some(7));
        assert_eq!(Value::from(-7i64).as_usize(), None);
    }
}

//! In-tree JSON (serde/serde_json are not in the offline registry).
//!
//! Used for: the RPC wire format (`server::rpc`), `artifacts/manifest.json`
//! (written by python/compile/aot.py), dataset manifests, and metrics
//! snapshots. Full RFC 8259 parser + serializer with the usual pragmatic
//! choices: numbers are f64 (with an i64 fast path on access), object keys
//! keep insertion order via a Vec-backed map.

mod parse;
mod ser;
pub mod value;

pub use parse::{parse, ParseError};
pub use ser::{to_string, to_string_pretty};
pub use value::{obj, Map, Value};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn arbitrary_value(rng: &mut Rng, depth: usize) -> Value {
        let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => {
                // Mix integers and floats; keep floats exactly representable
                // through a parse round-trip by limiting magnitude.
                if rng.below(2) == 0 {
                    Value::from(rng.below(1_000_000) as i64 - 500_000)
                } else {
                    Value::from((rng.f64() - 0.5) * 1e6)
                }
            }
            3 => {
                let len = rng.below(12);
                let s: String = (0..len)
                    .map(|_| {
                        // printable ascii + some escapes + some unicode
                        match rng.below(10) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => '\u{1F600}',
                            _ => (b' ' + rng.below(94) as u8) as char,
                        }
                    })
                    .collect();
                Value::from(s)
            }
            4 => {
                let len = rng.below(5);
                Value::Array((0..len).map(|_| arbitrary_value(rng, depth - 1)).collect())
            }
            _ => {
                let len = rng.below(5);
                let mut m = Map::new();
                for i in 0..len {
                    m.insert(format!("k{i}"), arbitrary_value(rng, depth - 1));
                }
                Value::Object(m)
            }
        }
    }

    #[test]
    fn prop_roundtrip_parse_serialize() {
        check("json-roundtrip", 200, |rng| {
            let v = arbitrary_value(rng, 3);
            let s = to_string(&v);
            let back = parse(&s).map_err(|e| format!("parse failed on {s}: {e}"))?;
            prop_assert!(back == v, "roundtrip mismatch:\n  in : {v:?}\n  out: {back:?}\n  str: {s}");
            // pretty form parses to the same value too
            let back2 = parse(&to_string_pretty(&v)).map_err(|e| e.to_string())?;
            prop_assert!(back2 == v, "pretty roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn parses_canonical_document() {
        let doc = r#"
        {
          "name": "IMG_CLASSIFICATION",
          "version": 0.1,
          "replicas": 3,
          "auto": true,
          "none": null,
          "tags": ["al", "mlops"],
          "nested": {"a": [1, 2.5, -3e2]}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").and_then(Value::as_str), Some("IMG_CLASSIFICATION"));
        assert_eq!(v.get("replicas").and_then(Value::as_i64), Some(3));
        assert_eq!(v.get("auto").and_then(Value::as_bool), Some(true));
        assert!(v.get("none").map(Value::is_null).unwrap_or(false));
        let nested = v.get("nested").unwrap().get("a").unwrap().as_array().unwrap();
        assert_eq!(nested[2].as_f64(), Some(-300.0));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "{'a':1}", "{\"a\" 1}", "nul", "tru", "01",
            "1.2.3", "\"unterminated", "{\"a\":1,}", "[1,2,]", "\u{0}",
            "\"bad \\x escape\"", "{\"dup\":1 \"b\":2}",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad:?}");
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        assert!(parse("{} extra").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::from("line1\nline2\ttab \"quoted\" \\ slash \u{1F600} \u{7}");
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_surrogate_pairs() {
        let v = parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        // lone surrogate is an error
        assert!(parse("\"\\ud83d\"").is_err());
    }

    #[test]
    fn deep_nesting_guard() {
        let mut s = String::new();
        for _ in 0..10_000 {
            s.push('[');
        }
        assert!(parse(&s).is_err(), "must not blow the stack");
    }

    #[test]
    fn number_access_paths() {
        let v = parse("{\"i\": 42, \"f\": 2.5, \"neg\": -7}").unwrap();
        assert_eq!(v.get("i").and_then(Value::as_i64), Some(42));
        assert_eq!(v.get("i").and_then(Value::as_f64), Some(42.0));
        assert_eq!(v.get("f").and_then(Value::as_i64), None);
        assert_eq!(v.get("neg").and_then(Value::as_i64), Some(-7));
    }
}

//! Recursive-descent JSON parser (RFC 8259) with a depth guard.

use std::fmt;

use super::value::{Map, Value};

/// Parse failure with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

const MAX_DEPTH: usize = 256;

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { msg: msg.into(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal (expected '{word}')")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = Map::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string().map_err(|e| ParseError {
                msg: format!("object key: {}", e.msg),
                offset: e.offset,
            })?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            m.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(a)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => out.push(self.unicode_escape()?),
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("unescaped control character in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c)
                            .ok_or_else(|| self.err("invalid utf-8 lead byte"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8 sequence"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8 sequence"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char, ParseError> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // Expect a low surrogate: \uXXXX
            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                return Err(self.err("lone high surrogate"));
            }
            let lo = self.hex4()?;
            if !(0xDC00..0xE000).contains(&lo) {
                return Err(self.err("invalid low surrogate"));
            }
            let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            char::from_u32(c).ok_or_else(|| self.err("invalid surrogate pair"))
        } else if (0xDC00..0xE000).contains(&hi) {
            Err(self.err("lone low surrogate"))
        } else {
            char::from_u32(hi).ok_or_else(|| self.err("invalid codepoint"))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part: 0 | [1-9][0-9]*
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(b'0'..=b'9')) {
                    return Err(self.err("leading zero"));
                }
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // fraction
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // exponent
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("number out of range: {text}")))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

//! YAML-subset parser — configuration-as-a-service (Fig 2 of the paper).
//!
//! The paper's whole accessibility story is "one YAML file starts the
//! service"; with no serde_yaml offline we parse the subset those configs
//! actually use (and that `example.yml` in Fig 2 exercises):
//!
//! * nested mappings by 2+-space indentation
//! * block lists (`- item`, including lists of mappings)
//! * scalars: strings (bare / single / double quoted), ints, floats,
//!   booleans (`true/false`), `null`/`~`
//! * `#` comments and blank lines
//! * inline flow lists of scalars: `[1, 2, 3]`
//!
//! Deliberately NOT supported (rejected, never misparsed): anchors/aliases,
//! multi-document streams, block scalars (`|`, `>`), tabs for indentation.
//!
//! Output is the same `json::Value` the rest of the system speaks.

use crate::json::{Map, Value};
use std::fmt;

/// Parse failure with 1-based line context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct YamlError {
    pub msg: String,
    pub line: usize,
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "yaml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

struct Line {
    indent: usize,
    /// Content with comment stripped; never empty.
    text: String,
    /// 1-based source line for errors.
    no: usize,
}

/// Parse a YAML document into a Value.
pub fn parse(input: &str) -> Result<Value, YamlError> {
    let lines = logical_lines(input)?;
    if lines.is_empty() {
        return Ok(Value::Null);
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(YamlError {
            msg: "unexpected de-indent / trailing content".into(),
            line: lines[pos].no,
        });
    }
    Ok(v)
}

fn logical_lines(input: &str) -> Result<Vec<Line>, YamlError> {
    let mut out = Vec::new();
    for (i, raw) in input.lines().enumerate() {
        let no = i + 1;
        if raw.contains('\t') {
            return Err(YamlError { msg: "tabs are not allowed in indentation".into(), line: no });
        }
        let text = strip_comment(raw);
        let trimmed = text.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        if trimmed.starts_with("---") {
            return Err(YamlError { msg: "multi-document streams unsupported".into(), line: no });
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push(Line { indent, text: trimmed.trim_start().to_string(), no });
    }
    Ok(out)
}

/// Strip a `#` comment that is not inside quotes.
fn strip_comment(raw: &str) -> String {
    let mut out = String::new();
    let mut in_single = false;
    let mut in_double = false;
    let chars: Vec<char> = raw.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match c {
            '\'' if !in_double => in_single = !in_single,
            '"' if !in_single => in_double = !in_double,
            '#' if !in_single && !in_double => {
                // YAML requires '#' preceded by space/start to be a comment.
                if i == 0 || chars[i - 1] == ' ' {
                    break;
                }
            }
            _ => {}
        }
        out.push(c);
        i += 1;
    }
    out
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let first = &lines[*pos];
    if first.text.starts_with("- ") || first.text == "-" {
        parse_list(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_list(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError { msg: "unexpected indent in list".into(), line: line.no });
        }
        if !(line.text.starts_with("- ") || line.text == "-") {
            break;
        }
        let rest = line.text[1..].trim_start().to_string();
        let no = line.no;
        *pos += 1;
        if rest.is_empty() {
            // "-" alone: nested block follows with greater indent.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let inner_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, inner_indent)?);
            } else {
                items.push(Value::Null);
            }
        } else if rest.contains(": ") || rest.ends_with(':') {
            // List of mappings: "- key: value" starts an inline map whose
            // continuation lines are indented past the dash.
            let virt_indent = indent + 2;
            let mut m = Map::new();
            parse_map_entry(&rest, no, lines, pos, virt_indent, &mut m)?;
            while *pos < lines.len() && lines[*pos].indent == virt_indent {
                let l = &lines[*pos];
                if l.text.starts_with("- ") {
                    break;
                }
                let text = l.text.clone();
                let lno = l.no;
                *pos += 1;
                parse_map_entry(&text, lno, lines, pos, virt_indent, &mut m)?;
            }
            items.push(Value::Object(m));
        } else {
            items.push(scalar(&rest, no)?);
        }
    }
    Ok(Value::Array(items))
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let mut m = Map::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError { msg: "unexpected indent".into(), line: line.no });
        }
        if line.text.starts_with("- ") || line.text == "-" {
            break;
        }
        let text = line.text.clone();
        let no = line.no;
        *pos += 1;
        parse_map_entry(&text, no, lines, pos, indent, &mut m)?;
    }
    Ok(Value::Object(m))
}

fn parse_map_entry(
    text: &str,
    no: usize,
    lines: &[Line],
    pos: &mut usize,
    indent: usize,
    m: &mut Map,
) -> Result<(), YamlError> {
    let (key_raw, rest) = split_key(text, no)?;
    let key = unquote(key_raw.trim(), no)?;
    if m.contains_key(&key) {
        return Err(YamlError { msg: format!("duplicate key '{key}'"), line: no });
    }
    let rest = rest.trim();
    if rest.is_empty() {
        // Nested block (map or list) at deeper indent, or empty -> null.
        if *pos < lines.len() && lines[*pos].indent > indent {
            let inner = lines[*pos].indent;
            let v = parse_block(lines, pos, inner)?;
            m.insert(key, v);
        } else if *pos < lines.len()
            && lines[*pos].indent == indent
            && (lines[*pos].text.starts_with("- ") || lines[*pos].text == "-")
        {
            // Lists are commonly written at the same indent as their key.
            let v = parse_list(lines, pos, indent)?;
            m.insert(key, v);
        } else {
            m.insert(key, Value::Null);
        }
    } else {
        m.insert(key, scalar(rest, no)?);
    }
    Ok(())
}

/// Split "key: value" respecting quoted keys.
fn split_key(text: &str, no: usize) -> Result<(&str, &str), YamlError> {
    let bytes = text.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    for i in 0..bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b':' if !in_single && !in_double => {
                // ':' must be followed by space or end-of-line to be a key
                // separator (YAML rule), so URLs like s3sim://x are safe
                // inside values but keys split correctly.
                if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                    return Ok((&text[..i], &text[i + 1..]));
                }
            }
            _ => {}
        }
    }
    Err(YamlError { msg: format!("expected 'key: value' in {text:?}"), line: no })
}

fn unquote(s: &str, no: usize) -> Result<String, YamlError> {
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        Ok(s[1..s.len() - 1].to_string())
    } else if s.starts_with('"') || s.starts_with('\'') {
        Err(YamlError { msg: format!("unterminated quote in {s:?}"), line: no })
    } else {
        Ok(s.to_string())
    }
}

fn scalar(s: &str, no: usize) -> Result<Value, YamlError> {
    let s = s.trim();
    // flow list of scalars
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(YamlError { msg: "unterminated flow list".into(), line: no });
        }
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(scalar(part, no)?);
        }
        return Ok(Value::Array(items));
    }
    if s.starts_with('&') || s.starts_with('*') {
        return Err(YamlError { msg: "anchors/aliases unsupported".into(), line: no });
    }
    if s == "|" || s == ">" {
        return Err(YamlError { msg: "block scalars unsupported".into(), line: no });
    }
    if s.starts_with('"') || s.starts_with('\'') {
        return unquote(s, no).map(Value::String);
    }
    Ok(match s {
        "null" | "~" | "Null" | "NULL" => Value::Null,
        "true" | "True" | "TRUE" => Value::Bool(true),
        "false" | "False" | "FALSE" => Value::Bool(false),
        _ => {
            if let Ok(i) = s.parse::<i64>() {
                Value::from(i)
            } else if let Ok(f) = s.parse::<f64>() {
                Value::Number(f)
            } else {
                Value::String(s.to_string())
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig 2 config from the paper, verbatim structure.
    const FIG2: &str = r#"
name: "IMG_CLASSIFICATION"
version: 0.1
active_learning:
  strategy:
    type: "auto"
  model:
    name: "resnet18"
    hub_name: "pytorch/vision:release/0.12"
    batch_size: 1
  device: CPU
al_worker:
  protocol: "grpc"
  host: "0.0.0.0"
  port: 60035
  replicas: 1
"#;

    #[test]
    fn parses_paper_fig2_config() {
        let v = parse(FIG2).unwrap();
        assert_eq!(v.path("name").and_then(Value::as_str), Some("IMG_CLASSIFICATION"));
        assert_eq!(v.path("version").and_then(Value::as_f64), Some(0.1));
        assert_eq!(
            v.path("active_learning.strategy.type").and_then(Value::as_str),
            Some("auto")
        );
        assert_eq!(
            v.path("active_learning.model.batch_size").and_then(Value::as_i64),
            Some(1)
        );
        assert_eq!(v.path("al_worker.port").and_then(Value::as_i64), Some(60035));
        assert_eq!(v.path("al_worker.host").and_then(Value::as_str), Some("0.0.0.0"));
        assert_eq!(v.path("active_learning.device").and_then(Value::as_str), Some("CPU"));
    }

    #[test]
    fn lists_block_and_flow() {
        let v = parse("xs:\n  - 1\n  - 2\nys: [3, 4, five]\nsame_indent:\n- a\n- b\n").unwrap();
        let xs = v.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs[1].as_i64(), Some(2));
        let ys = v.get("ys").unwrap().as_array().unwrap();
        assert_eq!(ys[2].as_str(), Some("five"));
        let same = v.get("same_indent").unwrap().as_array().unwrap();
        assert_eq!(same.len(), 2);
    }

    #[test]
    fn list_of_mappings() {
        let doc = "workers:\n  - host: a\n    port: 1\n  - host: b\n    port: 2\n";
        let v = parse(doc).unwrap();
        let ws = v.get("workers").unwrap().as_array().unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].get("host").unwrap().as_str(), Some("a"));
        assert_eq!(ws[1].get("port").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = "# header\na: 1  # trailing\n\nb: \"#not-a-comment\"\n";
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("#not-a-comment"));
    }

    #[test]
    fn urls_with_colons_survive() {
        let v = parse("uri: s3sim://bucket/key\nhub: pytorch/vision:release/0.12\n").unwrap();
        assert_eq!(v.get("uri").unwrap().as_str(), Some("s3sim://bucket/key"));
        assert_eq!(v.get("hub").unwrap().as_str(), Some("pytorch/vision:release/0.12"));
    }

    #[test]
    fn scalar_types() {
        let v = parse("i: 3\nf: 2.5\nt: true\nn: null\ntil: ~\ns: plain text\n").unwrap();
        assert_eq!(v.get("i").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("t").unwrap().as_bool(), Some(true));
        assert!(v.get("n").unwrap().is_null());
        assert!(v.get("til").unwrap().is_null());
        assert_eq!(v.get("s").unwrap().as_str(), Some("plain text"));
    }

    #[test]
    fn rejects_unsupported_yaml() {
        assert!(parse("a: &anchor 1").is_err());
        assert!(parse("a: |").is_err());
        assert!(parse("---\na: 1").is_err());
        assert!(parse("\ta: 1").is_err());
        assert!(parse("a: 1\na: 2").is_err()); // duplicate key
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("").unwrap(), Value::Null);
        assert_eq!(parse("# only comments\n\n").unwrap(), Value::Null);
    }

    #[test]
    fn error_carries_line_number() {
        let e = parse("a: 1\n  broken\n").unwrap_err();
        assert_eq!(e.line, 2);
    }
}

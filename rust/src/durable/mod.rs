//! Durable coordinator state: CRC-framed write-ahead log + compacting
//! snapshots (DESIGN.md §Durability).
//!
//! Everything the cluster coordinator used to keep only in RAM — the
//! session registry, shard-layout/epoch counters, membership view
//! generations, and in-flight PSHEA job progress — dies with the process.
//! This module provides the storage half of crash safety: an append-only
//! log of JSON records, each framed as `[len u32 LE][crc32 u32 LE]
//! [payload]`, plus a periodically compacted snapshot so the log cannot
//! grow without bound. The *meaning* of the records (what to log, how to
//! fold a replay back into coordinator state, how to resume a PSHEA job
//! bit-identically) lives in `cluster::coordinator`; this layer is
//! deliberately generic over `json::Value` payloads.
//!
//! Durability contract:
//! * **Append-before-ack.** Callers append a record and only then
//!   acknowledge the client RPC. With `fsync: always` (the default) every
//!   append is `fdatasync`ed, so an acknowledged operation survives power
//!   loss; `fsync: never` leaves flushing to the OS (faster, survives
//!   process crashes but not host crashes).
//! * **Torn tails are expected, not fatal.** Replay walks frames from the
//!   start and stops at the first frame whose length is implausible,
//!   whose CRC32 mismatches, or whose payload is not valid JSON — i.e. at
//!   the last complete record. `open` then truncates the file back to
//!   that valid prefix so subsequent appends never interleave with
//!   garbage. Property tests pin this for truncation and bit flips at
//!   arbitrary offsets.
//! * **Compaction is rotation-based and crash-safe at every step.** The
//!   log rotates to `wal.<n+1>.log` first (new appends land there), then
//!   a snapshot covering sequences `<= n` is written to a temp file,
//!   fsynced, and atomically renamed over `snapshot.json`; only then are
//!   covered log files deleted. A crash between any two steps replays
//!   the old snapshot plus every uncovered log file — the coordinator's
//!   fold is idempotent for the record types that can straddle a
//!   rotation (see §Durability).
//!
//! Metrics (when a registry is attached): `wal.appends` / `wal.bytes`
//! counters, the `wal.fsync_ms` histogram, and `wal.compactions`.

use std::fs::{self, File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::json::{self, Value};
use crate::metrics::Registry;

/// Frame overhead: `len` + `crc32`, both little-endian u32.
const FRAME_HEADER: usize = 8;
/// Upper bound on a single record payload. Matches the RPC `MAX_FRAME`
/// ceiling; a corrupted length field beyond this is treated as a torn
/// tail instead of an allocation request.
pub const MAX_RECORD: usize = 64 * 1024 * 1024;

/// When appends hit the disk (`[durability] fsync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fdatasync` after every append: an acknowledged operation survives
    /// power loss. The default.
    Always,
    /// Leave flushing to the OS page cache: survives process crashes,
    /// not host crashes.
    Never,
}

impl FsyncPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Never => "never",
        }
    }

    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        match s {
            "always" => Some(FsyncPolicy::Always),
            "never" => Some(FsyncPolicy::Never),
            _ => None,
        }
    }
}

/// `[durability]` knobs (DESIGN.md §Durability).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DurabilityConfig {
    /// Master switch. Off by default: the coordinator behaves exactly as
    /// before (pure in-memory state). `serve --data-dir <dir>` turns it
    /// on from the CLI.
    pub enabled: bool,
    /// Directory holding `wal.<seq>.log` + `snapshot.json`. Created on
    /// first open.
    pub data_dir: String,
    /// Fsync policy for appends.
    pub fsync: FsyncPolicy,
    /// Compaction cadence: attempt a snapshot after this many appends
    /// since the last one.
    pub snapshot_every: usize,
    /// Hard ceiling on total on-disk WAL bytes (uncovered log files).
    /// `0` disables the cap. When live bytes reach it the coordinator
    /// forces a rotate+snapshot even while jobs are running — in-flight
    /// jobs are fully reconstructible from the fold, so the cadence-based
    /// quiescence gate does not apply (DESIGN.md §Durability).
    pub max_wal_bytes: u64,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        DurabilityConfig {
            enabled: false,
            data_dir: "alaas-data".into(),
            fsync: FsyncPolicy::Always,
            snapshot_every: 256,
            max_wal_bytes: 0,
        }
    }
}

/// CRC-32 (IEEE 802.3, reflected) over `bytes` — the frame checksum.
/// Table-driven, table built at compile time; no external crates.
pub fn crc32(bytes: &[u8]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        table
    };
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Encode one record frame: `[len][crc32][payload]`.
fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Walk `buf` frame by frame. Returns the decoded records and the byte
/// length of the valid prefix; anything past it (torn write, truncation,
/// bit flip) is reported, not replayed.
fn decode_frames(buf: &[u8]) -> (Vec<Value>, usize) {
    let mut records = Vec::new();
    let mut pos = 0usize;
    while buf.len() - pos >= FRAME_HEADER {
        let len = u32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[pos + 4..pos + 8].try_into().unwrap());
        if len > MAX_RECORD || buf.len() - pos - FRAME_HEADER < len {
            break; // implausible length or truncated payload: torn tail
        }
        let payload = &buf[pos + FRAME_HEADER..pos + FRAME_HEADER + len];
        if crc32(payload) != crc {
            break; // bit flip / torn write inside this frame
        }
        // a CRC-valid frame whose payload is not JSON means the writer
        // itself was corrupted mid-frame — stop, same as a CRC failure
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(v) = json::parse(text) else { break };
        records.push(v);
        pos += FRAME_HEADER + len;
    }
    (records, pos)
}

/// Result of replaying a durable directory at open.
pub struct Replay {
    /// The installed snapshot's state value, if a valid snapshot exists.
    pub snapshot: Option<Value>,
    /// Every WAL record not covered by the snapshot, in append order.
    pub records: Vec<Value>,
    /// Bytes discarded from torn tails across the replayed log files.
    pub torn_bytes: u64,
}

fn wal_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal.{seq}.log"))
}

/// Parse `wal.<seq>.log` → seq.
fn wal_seq(name: &str) -> Option<u64> {
    name.strip_prefix("wal.")?.strip_suffix(".log")?.parse().ok()
}

/// The append-only log + snapshot pair for one coordinator data dir.
/// Single-writer: callers serialize through [`SharedLog`].
pub struct DurableLog {
    dir: PathBuf,
    file: File,
    /// Sequence number of the file currently appended to.
    seq: u64,
    fsync: FsyncPolicy,
    snapshot_every: usize,
    appends_since_compact: usize,
    max_wal_bytes: u64,
    /// Bytes across every uncovered `wal.<seq>.log` on disk (the quantity
    /// `max_wal_bytes` caps). Maintained incrementally on append and
    /// recomputed from the directory after each snapshot install.
    live_bytes: u64,
    metrics: Option<Arc<Registry>>,
}

impl DurableLog {
    /// Open (creating the directory if needed), replay snapshot + logs,
    /// truncate any torn tail on the active log, and position for
    /// appends.
    pub fn open(
        cfg: &DurabilityConfig,
        metrics: Option<Arc<Registry>>,
    ) -> std::io::Result<(DurableLog, Replay)> {
        let dir = PathBuf::from(&cfg.data_dir);
        fs::create_dir_all(&dir)?;

        // snapshot: one CRC-framed record {covered, state}
        let mut snapshot = None;
        let mut covered = 0u64; // wal seqs <= covered are folded into it
        let snap_path = dir.join("snapshot.json");
        if let Ok(buf) = fs::read(&snap_path) {
            let (mut recs, _) = decode_frames(&buf);
            if let Some(v) = recs.pop() {
                covered = v.get("covered").and_then(Value::as_usize).unwrap_or(0) as u64;
                snapshot = v.get("state").cloned();
            } else {
                crate::log_warn!(
                    "durable",
                    "snapshot at {} is unreadable; replaying logs only",
                    snap_path.display()
                );
            }
        }

        // uncovered logs, oldest first
        let mut seqs: Vec<u64> = fs::read_dir(&dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| wal_seq(&e.file_name().to_string_lossy()))
            .filter(|&s| s > covered)
            .collect();
        seqs.sort_unstable();

        let mut records = Vec::new();
        let mut torn_bytes = 0u64;
        let mut live_bytes = 0u64;
        for &s in &seqs {
            let path = wal_path(&dir, s);
            let mut buf = Vec::new();
            File::open(&path)?.read_to_end(&mut buf)?;
            let (recs, valid) = decode_frames(&buf);
            torn_bytes += (buf.len() - valid) as u64;
            live_bytes += valid as u64;
            if valid < buf.len() {
                // truncate back to the valid prefix so future appends
                // never interleave with garbage
                let f = OpenOptions::new().write(true).open(&path)?;
                f.set_len(valid as u64)?;
                f.sync_data()?;
            }
            records.extend(recs);
        }

        let seq = seqs.last().copied().unwrap_or(covered + 1);
        let mut file =
            OpenOptions::new().create(true).append(true).open(wal_path(&dir, seq))?;
        file.seek(SeekFrom::End(0))?;
        if torn_bytes > 0 {
            crate::log_warn!(
                "durable",
                "discarded {torn_bytes} torn tail byte(s) during replay of {}",
                dir.display()
            );
        }
        Ok((
            DurableLog {
                dir,
                file,
                seq,
                fsync: cfg.fsync,
                snapshot_every: cfg.snapshot_every.max(1),
                appends_since_compact: 0,
                max_wal_bytes: cfg.max_wal_bytes,
                live_bytes,
                metrics,
            },
            Replay { snapshot, records, torn_bytes },
        ))
    }

    /// Append one record; with `fsync: always` it is on disk when this
    /// returns.
    pub fn append(&mut self, v: &Value) -> std::io::Result<()> {
        let buf = frame(json::to_string(v).as_bytes());
        self.file.write_all(&buf)?;
        if self.fsync == FsyncPolicy::Always {
            let t0 = Instant::now();
            self.file.sync_data()?;
            if let Some(m) = &self.metrics {
                m.time("wal.fsync_ms", t0.elapsed());
            }
        }
        self.appends_since_compact += 1;
        self.live_bytes += buf.len() as u64;
        if let Some(m) = &self.metrics {
            m.counter("wal.appends").fetch_add(1, Ordering::Relaxed);
            m.counter("wal.bytes").fetch_add(buf.len() as u64, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Is a compaction due? (Appends since the last snapshot reached the
    /// configured cadence.)
    pub fn compact_due(&self) -> bool {
        self.appends_since_compact >= self.snapshot_every
    }

    /// Have uncovered log files reached `[durability] max_wal_bytes`?
    /// Always false when the cap is disabled (`0`).
    pub fn over_byte_cap(&self) -> bool {
        self.max_wal_bytes > 0 && self.live_bytes >= self.max_wal_bytes
    }

    /// Total bytes across uncovered `wal.<seq>.log` files.
    pub fn wal_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// Step 1 of compaction: rotate appends to a fresh `wal.<n+1>.log`.
    /// Returns the highest sequence the upcoming snapshot must cover.
    /// The caller then builds the state value *after* this returns (so
    /// nothing acknowledged into the covered logs can be missed) and
    /// passes it to [`DurableLog::install_snapshot`].
    pub fn rotate(&mut self) -> std::io::Result<u64> {
        let covered = self.seq;
        self.seq += 1;
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(wal_path(&self.dir, self.seq))?;
        file.sync_data()?;
        self.file = file;
        self.appends_since_compact = 0;
        Ok(covered)
    }

    /// Step 2 of compaction: durably install `state` as the snapshot
    /// covering wal sequences `<= covered`, then delete the covered log
    /// files. Crash-safe: temp write + fsync + atomic rename.
    pub fn install_snapshot(&mut self, covered: u64, state: &Value) -> std::io::Result<()> {
        let mut wrapper = crate::json::Map::new();
        wrapper.insert("covered", Value::from(covered));
        wrapper.insert("state", state.clone());
        let buf = frame(json::to_string(&Value::Object(wrapper)).as_bytes());
        let tmp = self.dir.join("snapshot.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, self.dir.join("snapshot.json"))?;
        for s in fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| wal_seq(&e.file_name().to_string_lossy()))
            .filter(|&s| s <= covered)
        {
            let _ = fs::remove_file(wal_path(&self.dir, s));
        }
        // recompute from the directory rather than trusting the running
        // tally: this also settles files left by an earlier aborted
        // compaction that are only now covered
        let mut live = 0u64;
        for e in fs::read_dir(&self.dir)?.filter_map(|e| e.ok()) {
            if wal_seq(&e.file_name().to_string_lossy()).is_some() {
                live += e.metadata().map(|m| m.len()).unwrap_or(0);
            }
        }
        self.live_bytes = live;
        if let Some(m) = &self.metrics {
            m.counter("wal.compactions").fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

/// Thread-safe wrapper the coordinator shares across its RPC handlers,
/// tick thread, and agent-job threads. Also carries the crash-simulation
/// seal: [`SharedLog::seal`] makes every subsequent append a silent no-op,
/// which is how the test harness models a hard kill — whatever reached
/// the log before the seal is exactly what a restarted coordinator sees,
/// while the old process's still-running threads write into the void
/// instead of corrupting the new process's log.
pub struct SharedLog {
    inner: Mutex<DurableLog>,
    sealed: AtomicBool,
}

impl SharedLog {
    pub fn new(log: DurableLog) -> Arc<SharedLog> {
        Arc::new(SharedLog { inner: Mutex::new(log), sealed: AtomicBool::new(false) })
    }

    /// Append-before-ack: callers must propagate an `Err` instead of
    /// acknowledging the operation. A sealed log accepts and drops
    /// everything (the writer is "dead").
    pub fn append(&self, v: &Value) -> Result<(), String> {
        if self.sealed.load(Ordering::SeqCst) {
            return Ok(());
        }
        self.inner
            .lock()
            .unwrap()
            .append(v)
            .map_err(|e| format!("durability log append failed: {e}"))
    }

    /// Best-effort append for records whose loss only degrades recovery
    /// detail (membership views): failure is logged, never surfaced.
    pub fn append_best_effort(&self, v: &Value) {
        if let Err(e) = self.append(v) {
            crate::log_warn!("durable", "{e}");
        }
    }

    /// Append plus a caller-side bookkeeping action (`mirror`) run while
    /// the log lock is still held. The pairing matters for streams that a
    /// *forced* compaction snapshots from an in-memory mirror
    /// ([`SharedLog::compact_with`] captures those mirrors in the same
    /// critical section as the rotation): holding the lock across both
    /// guarantees every record lands on exactly one side of the rotation
    /// point in both the log and the mirror — nothing is ever snapshotted
    /// *and* replayed from the post-rotation log, or dropped by both.
    /// `mirror` runs only if the append succeeded (and never on a sealed
    /// log — a "dead" writer's mirrors no longer matter).
    pub fn append_with(&self, v: &Value, mirror: impl FnOnce()) -> Result<(), String> {
        if self.sealed.load(Ordering::SeqCst) {
            return Ok(());
        }
        let mut log = self.inner.lock().unwrap();
        match log.append(v) {
            Ok(()) => {
                mirror();
                Ok(())
            }
            Err(e) => Err(format!("durability log append failed: {e}")),
        }
    }

    /// [`SharedLog::append_with`] for records whose loss only degrades
    /// recovery detail: failure is logged, never surfaced.
    pub fn append_best_effort_with(&self, v: &Value, mirror: impl FnOnce()) {
        if let Err(e) = self.append_with(v, mirror) {
            crate::log_warn!("durable", "{e}");
        }
    }

    /// Crash simulation: drop every future append. Irreversible for this
    /// handle.
    pub fn seal(&self) {
        self.sealed.store(true, Ordering::SeqCst);
    }

    pub fn is_sealed(&self) -> bool {
        self.sealed.load(Ordering::SeqCst)
    }

    /// Run a compaction cycle if one is due: rotate, build the state
    /// value via `state` (called with no internal locks held), install.
    /// The caller gates this on quiescence for any non-idempotent record
    /// streams (the coordinator skips compaction while PSHEA jobs are
    /// running); `state` returning `None` aborts the install — the
    /// post-rotation re-check for a stream that went non-quiescent
    /// between the due-check and the rotation. An aborted cycle is
    /// harmless: the rotated logs stay on disk and the next successful
    /// install covers them. Returns whether a snapshot was installed.
    pub fn compact_if_due(
        &self,
        state: impl FnOnce() -> Option<Value>,
    ) -> Result<bool, String> {
        self.compact(false, state)
    }

    /// [`SharedLog::compact_if_due`] with an override: `force` skips the
    /// cadence due-check and rotates unconditionally. The byte-cap path
    /// (`[durability] max_wal_bytes`) uses this when a long-running job
    /// has pinned cadence compaction off but the uncovered log bytes hit
    /// the cap — the state builder then snapshots *with* in-flight job
    /// progress folded in.
    pub fn compact(
        &self,
        force: bool,
        state: impl FnOnce() -> Option<Value>,
    ) -> Result<bool, String> {
        self.compact_with(force, || (), |()| state())
    }

    /// [`SharedLog::compact`] with a capture hook: `at_rotate` runs in
    /// the same critical section as the rotation itself, so anything it
    /// reads is split *exactly* at the rotation point with respect to
    /// every [`SharedLog::append_with`] writer. The forced byte-cap path
    /// uses this to capture running jobs' record mirrors: captured
    /// records replay from the snapshot, later ones from the fresh log —
    /// never both, never neither. `at_rotate` must not append to this
    /// log or take locks that append paths hold (deadlock).
    pub fn compact_with<T>(
        &self,
        force: bool,
        at_rotate: impl FnOnce() -> T,
        state: impl FnOnce(T) -> Option<Value>,
    ) -> Result<bool, String> {
        if self.sealed.load(Ordering::SeqCst) {
            return Ok(false);
        }
        let (covered, captured) = {
            let mut log = self.inner.lock().unwrap();
            if !force && !log.compact_due() {
                return Ok(false);
            }
            let covered = log.rotate().map_err(|e| format!("wal rotate failed: {e}"))?;
            (covered, at_rotate())
        };
        let Some(value) = state(captured) else {
            return Ok(false);
        };
        self.inner
            .lock()
            .unwrap()
            .install_snapshot(covered, &value)
            .map_err(|e| format!("snapshot install failed: {e}"))?;
        Ok(true)
    }

    /// Whether uncovered log bytes have reached `[durability]
    /// max_wal_bytes` (always false when the cap is disabled or the log
    /// is sealed).
    pub fn over_byte_cap(&self) -> bool {
        !self.sealed.load(Ordering::SeqCst) && self.inner.lock().unwrap().over_byte_cap()
    }

    /// Total bytes across uncovered `wal.<seq>.log` files.
    pub fn wal_bytes(&self) -> u64 {
        self.inner.lock().unwrap().wal_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;
    use crate::prop_assert;
    use crate::util::prop::check;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "alaas-durable-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn cfg_for(dir: &Path) -> DurabilityConfig {
        DurabilityConfig {
            enabled: true,
            data_dir: dir.to_string_lossy().into_owned(),
            fsync: FsyncPolicy::Always,
            snapshot_every: 1000,
            max_wal_bytes: 0,
        }
    }

    fn rec(i: usize) -> Value {
        obj([("t", Value::from("test")), ("i", Value::from(i)), (
            "payload",
            Value::from(format!("record-{i}-{}", "x".repeat(i % 17))),
        )])
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn append_and_replay_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let cfg = cfg_for(&dir);
        {
            let (mut log, replay) = DurableLog::open(&cfg, None).unwrap();
            assert!(replay.snapshot.is_none());
            assert!(replay.records.is_empty());
            for i in 0..20 {
                log.append(&rec(i)).unwrap();
            }
        }
        let (_, replay) = DurableLog::open(&cfg, None).unwrap();
        assert_eq!(replay.records.len(), 20);
        assert_eq!(replay.torn_bytes, 0);
        for (i, r) in replay.records.iter().enumerate() {
            assert_eq!(r.get("i").and_then(Value::as_usize), Some(i));
        }
    }

    #[test]
    fn appends_after_reopen_extend_the_log() {
        let dir = tmp_dir("reopen");
        let cfg = cfg_for(&dir);
        {
            let (mut log, _) = DurableLog::open(&cfg, None).unwrap();
            log.append(&rec(0)).unwrap();
        }
        {
            let (mut log, replay) = DurableLog::open(&cfg, None).unwrap();
            assert_eq!(replay.records.len(), 1);
            log.append(&rec(1)).unwrap();
        }
        let (_, replay) = DurableLog::open(&cfg, None).unwrap();
        assert_eq!(replay.records.len(), 2);
    }

    #[test]
    fn compaction_folds_and_survives_reopen() {
        let dir = tmp_dir("compact");
        let cfg = cfg_for(&dir);
        {
            let (mut log, _) = DurableLog::open(&cfg, None).unwrap();
            for i in 0..10 {
                log.append(&rec(i)).unwrap();
            }
            let covered = log.rotate().unwrap();
            log.install_snapshot(covered, &obj([("n", Value::from(10u64))])).unwrap();
        }
        let (mut log, replay) = DurableLog::open(&cfg, None).unwrap();
        assert_eq!(
            replay.snapshot.as_ref().and_then(|s| s.get("n")).and_then(Value::as_usize),
            Some(10)
        );
        assert!(replay.records.is_empty(), "covered records must not replay");
        log.append(&rec(99)).unwrap();
        drop(log);
        let (_, replay) = DurableLog::open(&cfg, None).unwrap();
        assert!(replay.snapshot.is_some());
        assert_eq!(replay.records.len(), 1);
        assert_eq!(replay.records[0].get("i").and_then(Value::as_usize), Some(99));
    }

    #[test]
    fn shared_log_compact_if_due_and_seal() {
        let dir = tmp_dir("shared");
        let mut cfg = cfg_for(&dir);
        cfg.snapshot_every = 4;
        let (log, _) = DurableLog::open(&cfg, None).unwrap();
        let shared = SharedLog::new(log);
        for i in 0..4 {
            shared.append(&rec(i)).unwrap();
        }
        let compacted = shared
            .compact_if_due(|| Some(obj([("state", Value::from("folded"))])))
            .unwrap();
        assert!(compacted);
        assert!(!shared.compact_if_due(|| Some(Value::Null)).unwrap(), "not due again yet");
        shared.append(&rec(100)).unwrap();
        shared.seal();
        shared.append(&rec(101)).unwrap(); // dropped silently
        let (_, replay) = DurableLog::open(&cfg, None).unwrap();
        assert_eq!(
            replay.snapshot.as_ref().and_then(|s| s.get("state")).and_then(Value::as_str),
            Some("folded")
        );
        let ids: Vec<usize> =
            replay.records.iter().filter_map(|r| r.get("i").and_then(Value::as_usize)).collect();
        assert_eq!(ids, vec![100], "pre-seal record survives, post-seal one is dropped");
    }

    #[test]
    fn aborted_compaction_loses_nothing() {
        let dir = tmp_dir("abort");
        let mut cfg = cfg_for(&dir);
        cfg.snapshot_every = 3;
        let (log, _) = DurableLog::open(&cfg, None).unwrap();
        let shared = SharedLog::new(log);
        for i in 0..3 {
            shared.append(&rec(i)).unwrap();
        }
        // the state builder declines (post-rotation non-quiescence):
        // no snapshot installs, but the rotated log must still replay
        assert!(!shared.compact_if_due(|| None).unwrap());
        shared.append(&rec(3)).unwrap();
        let (_, replay) = DurableLog::open(&cfg, None).unwrap();
        assert!(replay.snapshot.is_none());
        let ids: Vec<usize> =
            replay.records.iter().filter_map(|r| r.get("i").and_then(Value::as_usize)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "records across the aborted rotation all replay");
    }

    #[test]
    fn prop_truncation_recovers_a_prefix() {
        check("wal-torn-tail", 60, |rng| {
            let dir = tmp_dir("prop-trunc");
            let cfg = cfg_for(&dir);
            let n = 1 + rng.below(12);
            {
                let (mut log, _) = DurableLog::open(&cfg, None).unwrap();
                for i in 0..n {
                    log.append(&rec(i)).unwrap();
                }
            }
            let path = wal_path(&dir, 1);
            let full = fs::read(&path).map_err(|e| e.to_string())?;
            let cut = rng.below(full.len() + 1);
            let f = OpenOptions::new().write(true).open(&path).map_err(|e| e.to_string())?;
            f.set_len(cut as u64).map_err(|e| e.to_string())?;
            drop(f);
            let (_, replay) = DurableLog::open(&cfg, None).unwrap();
            prop_assert!(replay.records.len() <= n, "more records than written");
            for (i, r) in replay.records.iter().enumerate() {
                prop_assert!(
                    r.get("i").and_then(Value::as_usize) == Some(i),
                    "replay is not a prefix at {i}"
                );
            }
            // whatever survived must itself be re-appendable and stable
            {
                let (mut log, _) = DurableLog::open(&cfg, None).unwrap();
                log.append(&rec(500)).unwrap();
            }
            let (_, replay2) = DurableLog::open(&cfg, None).unwrap();
            prop_assert!(
                replay2.records.len() == replay.records.len() + 1,
                "append after torn-tail truncation must extend the valid prefix"
            );
            let _ = fs::remove_dir_all(&dir);
            Ok(())
        });
    }

    #[test]
    fn prop_bit_flip_recovers_a_prefix_without_panic() {
        check("wal-bit-flip", 60, |rng| {
            let dir = tmp_dir("prop-flip");
            let cfg = cfg_for(&dir);
            let n = 2 + rng.below(10);
            {
                let (mut log, _) = DurableLog::open(&cfg, None).unwrap();
                for i in 0..n {
                    log.append(&rec(i)).unwrap();
                }
            }
            let path = wal_path(&dir, 1);
            let mut buf = fs::read(&path).map_err(|e| e.to_string())?;
            let byte = rng.below(buf.len());
            let bit = rng.below(8);
            buf[byte] ^= 1 << bit;
            fs::write(&path, &buf).map_err(|e| e.to_string())?;
            let (_, replay) = DurableLog::open(&cfg, None).unwrap();
            prop_assert!(replay.records.len() < n || replay.torn_bytes == 0, "flip vanished");
            for (i, r) in replay.records.iter().enumerate() {
                prop_assert!(
                    r.get("i").and_then(Value::as_usize) == Some(i),
                    "replay is not a prefix at {i} after bit flip"
                );
            }
            let _ = fs::remove_dir_all(&dir);
            Ok(())
        });
    }

    #[test]
    fn corrupted_snapshot_degrades_to_log_only_replay() {
        let dir = tmp_dir("bad-snap");
        let cfg = cfg_for(&dir);
        {
            let (mut log, _) = DurableLog::open(&cfg, None).unwrap();
            log.append(&rec(0)).unwrap();
            let covered = log.rotate().unwrap();
            log.install_snapshot(covered, &obj([("ok", Value::Bool(true))])).unwrap();
            log.append(&rec(1)).unwrap();
        }
        // flip a byte inside the snapshot payload
        let snap = dir.join("snapshot.json");
        let mut buf = fs::read(&snap).unwrap();
        let mid = buf.len() / 2;
        buf[mid] ^= 0x40;
        fs::write(&snap, &buf).unwrap();
        let (_, replay) = DurableLog::open(&cfg, None).unwrap();
        assert!(replay.snapshot.is_none(), "corrupt snapshot must not be trusted");
        // with no trustworthy snapshot every log file on disk replays
        assert!(
            replay.records.iter().any(|r| r.get("i").and_then(Value::as_usize) == Some(1)),
            "post-snapshot record must still replay"
        );
    }

    #[test]
    fn byte_cap_bounds_wal_during_endless_job() {
        // Shape of the reported bug: a multi-hour PSHEA job keeps the
        // cadence-based compaction gated off (here: cadence effectively
        // infinite), so the WAL used to grow without bound. With
        // max_wal_bytes set, the coordinator's forced compact() keeps
        // on-disk uncovered bytes at ~the cap no matter how many records
        // the job appends.
        let dir = tmp_dir("byte-cap");
        let mut cfg = cfg_for(&dir);
        cfg.snapshot_every = 1_000_000; // cadence never fires mid-job
        cfg.max_wal_bytes = 4096;
        let (log, _) = DurableLog::open(&cfg, None).unwrap();
        let shared = SharedLog::new(log);
        let disk_bytes = || -> u64 {
            fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| wal_seq(&e.file_name().to_string_lossy()).is_some())
                .filter_map(|e| e.metadata().ok())
                .map(|m| m.len())
                .sum()
        };
        let mut max_disk = 0u64;
        let mut forced = 0usize;
        for i in 0..2000 {
            shared.append(&rec(i)).unwrap();
            if shared.over_byte_cap() {
                // what the coordinator does when the cap trips with a
                // job still running: force rotate+snapshot, folding the
                // in-flight progress into the state value
                assert!(shared
                    .compact(true, || Some(obj([("upto", Value::from(i))])))
                    .unwrap());
                forced += 1;
            }
            max_disk = max_disk.max(disk_bytes());
        }
        assert!(forced > 5, "cap never tripped over 2000 appends");
        // bounded: the cap plus at most one record frame of overshoot
        assert!(
            max_disk < 4096 + 512,
            "wal disk usage {max_disk} exceeded max_wal_bytes despite forced compaction"
        );
        // cadence-based compaction alone is still off (job running shape)
        assert!(!shared.compact_if_due(|| Some(Value::Null)).unwrap());
        // the accounting survives a reopen
        drop(shared);
        let (log, replay) = DurableLog::open(&cfg, None).unwrap();
        assert!(replay.snapshot.is_some());
        assert_eq!(log.wal_bytes(), disk_bytes());
        assert!(!log.over_byte_cap());
    }

    #[test]
    fn fsync_policy_parse_and_metrics_names() {
        assert_eq!(FsyncPolicy::parse("always"), Some(FsyncPolicy::Always));
        assert_eq!(FsyncPolicy::parse("never"), Some(FsyncPolicy::Never));
        assert_eq!(FsyncPolicy::parse("sometimes"), None);
        assert_eq!(FsyncPolicy::Always.as_str(), "always");

        // appends under a registry move the wal.* metrics
        let dir = tmp_dir("metrics");
        let cfg = cfg_for(&dir);
        let m = Registry::new();
        let (mut log, _) = DurableLog::open(&cfg, Some(m.clone())).unwrap();
        log.append(&rec(0)).unwrap();
        log.append(&rec(1)).unwrap();
        assert_eq!(m.counter("wal.appends").load(Ordering::Relaxed), 2);
        assert!(m.counter("wal.bytes").load(Ordering::Relaxed) > 0);
        assert_eq!(m.histogram("wal.fsync_ms").count(), 2);
    }
}

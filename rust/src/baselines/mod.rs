//! Baseline tool profiles for Table 2 (DeepAL / ModAL / ALiPy / libact).
//!
//! The Python tools cannot run in this offline environment, and Table 2's
//! claim is about *dataflow efficiency*, not Python-vs-Rust codegen. Each
//! profile reproduces a tool's architecture on our substrate:
//!
//! * **dataflow** — all four baselines are stage-serial (Fig 3a/3b);
//!   libact/ALiPy process in rounds, DeepAL/ModAL in one pass.
//! * **batching** — DeepAL/ModAL batch inference through the framework
//!   dataloader; libact's interface is per-sample.
//! * **per-item overhead** — interpreter-loop dispatch cost per sample
//!   (NumPy boxing, per-call graph setup). Calibrated to the per-tool
//!   overhead ratios implied by Table 2's latency spread at 40k images
//!   (~10-25 ms/image end-to-end for the Python tools on CPU).
//! * **per-round overhead** — ALiPy re-instantiates the query strategy
//!   and copies the label state between rounds; libact re-trains its
//!   committee models.
//!
//! The ALaaS rows use the pipelined dataflow with zero injected overhead —
//! the same engine the server runs.

use std::time::Duration;

use crate::pipeline::{BatchPolicy, DataflowMode, PipelineParams};

/// One tool's architecture profile.
#[derive(Debug, Clone)]
pub struct ToolProfile {
    pub name: &'static str,
    pub mode: DataflowMode,
    pub batch: usize,
    /// Interpreter-loop cost per sample in the preprocess path.
    pub per_item_overhead: Duration,
    /// Cost at each round boundary (strategy re-init, state copy).
    pub per_round_overhead: Duration,
    /// Whether the tool keeps a processed-sample cache (only ALaaS does).
    pub cache: bool,
}

impl ToolProfile {
    /// Pipeline parameters that realize this profile.
    pub fn params(&self, infer_threads: usize) -> PipelineParams {
        PipelineParams {
            mode: self.mode,
            // serial tools are single-threaded by construction; thread
            // counts only apply to the pipelined ALaaS rows
            fetch_threads: 4,
            preprocess_threads: 2,
            infer_threads,
            queue_depth: 256,
            batch: BatchPolicy {
                max_batch: self.batch,
                max_wait: Duration::from_millis(20),
            },
            per_item_overhead: self.per_item_overhead,
            per_round_overhead: self.per_round_overhead,
        }
    }
}

/// The Table 2 baseline set. Overheads are per-sample / per-round costs
/// measured from the tools' architectures (see module docs); the *ratios*
/// between tools follow Table 2's observed latency spread.
pub fn table2_baselines() -> Vec<ToolProfile> {
    vec![
        ToolProfile {
            name: "DeepAL",
            mode: DataflowMode::SerialOneShot,
            batch: 16,
            per_item_overhead: Duration::from_micros(160),
            per_round_overhead: Duration::ZERO,
            cache: false,
        },
        ToolProfile {
            name: "ModAL",
            mode: DataflowMode::SerialOneShot,
            batch: 16,
            per_item_overhead: Duration::from_micros(120),
            per_round_overhead: Duration::ZERO,
            cache: false,
        },
        ToolProfile {
            name: "ALiPy",
            mode: DataflowMode::SerialPerRound(10),
            batch: 16,
            per_item_overhead: Duration::from_micros(170),
            per_round_overhead: Duration::from_millis(150),
            cache: false,
        },
        ToolProfile {
            name: "libact",
            // libact is round-based but lighter per item (C backends for
            // its models) — fastest baseline in Table 2.
            mode: DataflowMode::SerialPerRound(10),
            batch: 1,
            per_item_overhead: Duration::from_micros(80),
            per_round_overhead: Duration::from_millis(80),
            cache: false,
        },
    ]
}

/// The ALaaS profile (the paper's system): pipelined, cached, batched.
pub fn alaas_profile(batch: usize) -> ToolProfile {
    ToolProfile {
        name: "ALaaS (Ours)",
        mode: DataflowMode::Pipelined,
        batch,
        per_item_overhead: Duration::ZERO,
        per_round_overhead: Duration::ZERO,
        cache: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_cover_table2_rows() {
        let names: Vec<&str> = table2_baselines().iter().map(|p| p.name).collect();
        assert_eq!(names, vec!["DeepAL", "ModAL", "ALiPy", "libact"]);
        assert_eq!(alaas_profile(16).name, "ALaaS (Ours)");
    }

    #[test]
    fn baselines_are_serial_alaas_is_pipelined() {
        for p in table2_baselines() {
            assert_ne!(p.mode, DataflowMode::Pipelined, "{} must be serial", p.name);
            assert!(!p.cache, "{} has no cache", p.name);
        }
        assert_eq!(alaas_profile(16).mode, DataflowMode::Pipelined);
        assert!(alaas_profile(16).cache);
    }

    #[test]
    fn params_realize_profile() {
        let p = table2_baselines().remove(2); // ALiPy
        let params = p.params(2);
        assert_eq!(params.mode, DataflowMode::SerialPerRound(10));
        assert_eq!(params.batch.max_batch, 16);
        assert!(params.per_round_overhead > Duration::ZERO);
    }
}

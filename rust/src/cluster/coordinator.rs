//! Cluster coordinator: one `AlClient`-compatible endpoint that scales a
//! session across N workers (DESIGN.md §Cluster).
//!
//! The coordinator accepts the unchanged client API (`push_data`,
//! `query`, `status`, `metrics`, ...) plus the membership surface:
//! one-shot `register`, and — with `[cluster.membership]` enabled — the
//! `heartbeat`/`members`/`deregister` lease protocol. On `push_data` it
//! shards the manifest's pool across the live workers (each worker also
//! receives the full init split so every replica fine-tunes the
//! identical head) and scatters `scan_shard` calls; each worker then
//! pipelines its own shard concurrently. Every scatter runs against a
//! **generation-numbered membership view**: when the view moves (a
//! worker joins, dies, or returns), the session's shard layout is
//! re-planned by the rendezvous planner (`membership::assign`) before
//! the next scatter — a joiner takes over a proportional slice of the
//! pool, a dead worker's rows scatter across *all* survivors — while
//! scatters already in flight complete against the layout they started
//! on (shard instances are identified by stable `sid`s, lazily
//! re-pushable on `unknown session`). On `query` it scatters
//! `select_shard`, re-dispatching a dead worker's shard to a survivor,
//! and merges:
//!
//! * exact top-k for the uncertainty strategies,
//! * coordinator-side sampling for `random`,
//! * a candidate-then-refine pass (oversampled, embedding-carrying
//!   candidates; full KCG/Core-Set/DBAL over the union) for the
//!   diversity/hybrid strategies.
//!
//! Per-shard scan timings land in `cluster.shard{i}.scan` and the
//! max-minus-min spread in the `cluster.scan.straggler_ms` gauge.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::agent::job::{self, AgentTask, ArmSelect, JobRegistry, Picked};
use crate::agent::{PsheaConfig, RoundRecord};
use crate::config::AlaasConfig;
use crate::durable::{DurableLog, SharedLog};
use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::runtime::backend::ComputeBackend;
use crate::server::pool::{self, ConnPool};
use crate::server::rpc::{self, RpcError, ServiceError};
use crate::server::server::{parse_agent_start, parse_init_labels, str_param};
use crate::server::wire::{self, Body, Payload};
use crate::server::SELECT_SEED;
use crate::store::{Manifest, SampleRef};
use crate::strategies::{self, SelectCtx};
use crate::trainer::LinearHead;
use crate::util::mat::Mat;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

use super::membership::{self, Membership, MsClock};
use super::merge::{self, Candidate, MergeKind};
use super::recovery::{self, WalObserver};
use super::shard;
use super::tenancy::{self, AdmissionGate, AdmitPermit, TenantInfo, TenantRegistry};

/// Coordinator dependencies. The backend only runs the refine pass over
/// candidate unions (tiny next to a pool scan), so the host backend is a
/// fine default even when workers serve PJRT.
pub struct CoordinatorDeps {
    pub backend: Arc<dyn ComputeBackend>,
    pub metrics: Arc<Registry>,
}

struct WorkerSlot {
    addr: String,
    alive: bool,
}

/// One shard of a cluster session: which global pool positions it covers
/// and which worker slot currently owns it. `sid` is the stable identity
/// baked into the worker-side shard session id — it survives worker
/// reassignment (re-dispatch) but a rebalance that changes the shard's
/// row set mints a fresh one, so in-flight scatters pinned to the old
/// layout can never read the new content through a stale index mapping.
struct ShardState {
    sid: u64,
    indices: Vec<usize>,
    worker: usize,
    /// Exactly one shard per session carries the manifest's test split
    /// (agent-job evaluation, DESIGN.md §Agent).
    carries_test: bool,
}

struct ClusterSession {
    manifest: Manifest,
    /// Kept verbatim for shard re-dispatch after a worker death.
    init_labels: Option<Vec<u8>>,
    /// Push epoch baked into the worker-side shard session ids, so a
    /// re-pushed session never collides with (or reads through) shard
    /// data from an earlier push.
    epoch: u64,
    /// Membership view generation this session's shard layout reflects
    /// (0 under static config). A scatter whose view moved past it
    /// triggers `maybe_rebalance` first.
    view_gen: u64,
    /// Next shard instance id (`ShardState::sid`) for this session.
    next_sid: u64,
    shards: Vec<ShardState>,
    /// Shard instances retired by rebalances, as `(epoch, sid, last
    /// slot)`. A scatter pinned to the old layout may lazily re-push
    /// one of these onto a worker *after* the rebalance freed it; every
    /// sweep (next rebalance, or the fast path when the view is
    /// current) re-drops them so re-pushed orphans cannot accumulate in
    /// worker memory. Entries carry their own epoch so obligations
    /// survive a session re-push. Bounded by [`RETIRED_CAP`], newest
    /// kept (`ledger_push`).
    retired: Vec<(u64, u64, usize)>,
    /// Labeled-set embeddings, fetched once from a worker for the refine
    /// protocol.
    init_emb: Option<Mat>,
    /// Test-split embeddings, fetched once from a worker for agent-job
    /// evaluation.
    test_emb: Option<Mat>,
}

/// Lock a session mutex, recovering from poisoning. A scatter (or any
/// other holder) that panicked mid-operation must not turn every later
/// query/status/cancel on the same session into a panic cascade: session
/// mutations are transactional under the lock (ledger pushes, shard-list
/// swaps), so the inner state is still serviceable. The first recovery is
/// logged once so poisoning stays observable without flooding.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| {
        static LOGGED: AtomicBool = AtomicBool::new(false);
        if !LOGGED.swap(true, Ordering::Relaxed) {
            crate::log_warn!(
                "cluster",
                "recovered a poisoned session lock (a previous holder panicked); continuing with the inner state"
            );
        }
        poisoned.into_inner()
    })
}

struct CoordState {
    config: AlaasConfig,
    deps: CoordinatorDeps,
    /// Distributed-tracing plane (DESIGN.md §Observability). The pool
    /// shares it so worker replies' piggybacked span subtrees land in
    /// the coordinator ring, assembling one end-to-end tree per request.
    tracer: Arc<crate::trace::Tracer>,
    workers: Mutex<Vec<WorkerSlot>>,
    sessions: Mutex<HashMap<String, Arc<Mutex<ClusterSession>>>>,
    /// Monotonic push counter feeding `ClusterSession::epoch`.
    push_epoch: std::sync::atomic::AtomicU64,
    /// Persistent, per-worker negotiated connections (DESIGN.md §Wire):
    /// every worker RPC checks one out instead of dialing, so an
    /// N-shard scatter costs at most one dial per worker, not one per
    /// call. Invalidated per address on re-registration and on observed
    /// death.
    pool: ConnPool,
    /// Live-membership lease table + generation-numbered view (DESIGN.md
    /// §Cluster). Inert when `[cluster.membership]` is disabled: the
    /// static worker table alone drives scatter, exactly as in PR 1.
    membership: Mutex<Membership>,
    /// Clock the leases are measured on; carries a virtual offset so the
    /// fault-injection harness can expire leases deterministically.
    clock: MsClock,
    /// Background PSHEA jobs fanning out over worker shards (§Agent).
    jobs: JobRegistry,
    /// Durability plane (DESIGN.md §Durability): CRC-framed WAL +
    /// compacting snapshots under `[durability].data_dir`. `None` when
    /// the section is disabled — every append site stays a no-op and the
    /// coordinator is exactly the pre-durability in-memory server.
    wal: Option<Arc<SharedLog>>,
    /// Highest membership generation already recorded as a WAL `view`
    /// record — gates `rec_view` appends so the per-tick gauge refresh
    /// doesn't spam one record per sweep.
    last_logged_view_gen: AtomicU64,
    /// Multi-tenant session registry (DESIGN.md §Tenancy): opaque
    /// `tok-*` handles, per-session weight/worker-cap, `max_sessions`
    /// quota. Populated even with tenancy disabled (bookkeeping only).
    tenants: Arc<TenantRegistry>,
    /// Bounded weighted-fair admission queue in front of the scatter
    /// path. A pass-through no-op when tenancy is disabled.
    gate: Arc<AdmissionGate>,
    shutdown: AtomicBool,
}

/// A running cluster coordinator.
pub struct Coordinator {
    addr: SocketAddr,
    state: Arc<CoordState>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Lease-expiry / keepalive-probe sweep (membership enabled only).
    tick_thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Bind `config.al_worker.host:port` (0 = ephemeral) and start
    /// serving. Workers listed under `[cluster]` are pre-registered;
    /// more can join via the `register` RPC.
    pub fn start(config: AlaasConfig, deps: CoordinatorDeps) -> std::io::Result<Coordinator> {
        let listener =
            TcpListener::bind((config.al_worker.host.as_str(), config.al_worker.port))?;
        let addr = listener.local_addr()?;
        let workers = config
            .cluster
            .workers
            .iter()
            .map(|a| WorkerSlot { addr: a.clone(), alive: true })
            .collect();
        // worker connections: dial + negotiate once per worker, reuse
        // across every scatter (connect timeout matches the old per-call
        // dial so dead-worker detection latency is unchanged)
        crate::util::logger::set_format_from_config(&config.observability.log_format);
        let tracer = Arc::new(crate::trace::Tracer::new(
            config.observability.trace,
            config.observability.slow_query_ms,
        ));
        let conn_pool = ConnPool::new(
            config.server.pool.clone(),
            config.server.wire,
            Some(deps.metrics.clone()),
        )
        .with_mux(config.server.mux)
        .with_timeouts(WORKER_DIAL_TIMEOUT, POLL_RPC_TIMEOUT)
        .with_tracer(tracer.clone());
        let clock = MsClock::new();
        let mut mem = Membership::new();
        if config.cluster.membership.enabled {
            // statically configured workers boot as presumed-live members
            // (exactly the PR 1 assumption) — but now they must keep
            // heartbeating to stay in the view
            let now = clock.now_ms();
            for w in &config.cluster.workers {
                mem.heartbeat(w, now, config.cluster.membership.lease_ms);
            }
        }
        // durability (DESIGN.md §Durability): open the WAL + snapshot
        // pair and fold the replay BEFORE serving — restored sessions
        // must be resolvable by the first request in
        let (wal, recovered) = if config.durability.enabled {
            let (log, replay) =
                DurableLog::open(&config.durability, Some(deps.metrics.clone()))?;
            if replay.torn_bytes > 0 {
                crate::log_warn!(
                    "cluster",
                    "durable replay discarded a {}-byte torn WAL tail",
                    replay.torn_bytes
                );
            }
            let rec = recovery::fold(&replay);
            (Some(SharedLog::new(log)), Some(rec))
        } else {
            (None, None)
        };
        if let Some(rec) = &recovered {
            // the restarted lease table starts empty: raise the
            // generation past everything the WAL observed, so every
            // restored session's layout generation is stale and the
            // first scatter re-homes it through `plan_rebalance`
            if config.cluster.membership.enabled {
                mem.restore_generation(rec.view_gen + 1);
            }
        }
        let push_epoch =
            recovered.as_ref().and_then(|r| r.max_epoch).map_or(0, |e| e + 1);
        let tenants = Arc::new(TenantRegistry::new(config.coordinator.tenancy.clone()));
        let gate = Arc::new(AdmissionGate::new(
            &config.coordinator.tenancy,
            Some(deps.metrics.clone()),
        ));
        let state = Arc::new(CoordState {
            config,
            deps,
            tracer,
            workers: Mutex::new(workers),
            sessions: Mutex::new(HashMap::new()),
            push_epoch: std::sync::atomic::AtomicU64::new(push_epoch),
            pool: conn_pool,
            membership: Mutex::new(mem),
            clock,
            jobs: JobRegistry::new(),
            wal,
            last_logged_view_gen: AtomicU64::new(0),
            tenants,
            gate,
            shutdown: AtomicBool::new(false),
        });
        let resumable = match recovered {
            Some(rec) => install_recovered(&state, rec),
            None => Vec::new(),
        };
        {
            let mem = state.membership.lock().unwrap();
            update_membership_gauges(&state, mem.generation(), mem.len());
        }
        let accept_state = state.clone();
        let accept_thread = std::thread::Builder::new()
            .name("alaas-coord-accept".into())
            .spawn(move || accept_loop(listener, accept_state))?;
        let tick_thread = if state.config.cluster.membership.enabled {
            let tick_state = state.clone();
            let interval = Duration::from_millis(
                (state.config.cluster.membership.heartbeat_ms / 2).clamp(10, 1_000),
            );
            Some(
                std::thread::Builder::new()
                    .name("alaas-coord-membership".into())
                    .spawn(move || loop {
                        // sleep in small slices so shutdown joins promptly
                        let mut slept = Duration::ZERO;
                        while slept < interval {
                            if tick_state.shutdown.load(Ordering::SeqCst) {
                                return;
                            }
                            let step = Duration::from_millis(25).min(interval - slept);
                            std::thread::sleep(step);
                            slept += step;
                        }
                        membership_tick(&tick_state);
                    })?,
            )
        } else {
            None
        };
        // resume threads go last: the accept loop above is already
        // serving worker heartbeats, so their bootstrap retries converge
        for (job, slot) in resumable {
            spawn_resume(state.clone(), job, slot);
        }
        crate::log_info!("cluster", "coordinator listening on {addr}");
        Ok(Coordinator { addr, state, accept_thread: Some(accept_thread), tick_thread })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of currently-live registered workers.
    pub fn live_workers(&self) -> usize {
        self.state.workers.lock().unwrap().iter().filter(|w| w.alive).count()
    }

    /// `(generation, live members)` of the membership view — `(0, live
    /// slot count)` when membership is disabled.
    pub fn membership_snapshot(&self) -> (u64, usize) {
        if self.state.config.cluster.membership.enabled {
            let mem = self.state.membership.lock().unwrap();
            (mem.generation(), mem.len())
        } else {
            (0, self.live_workers())
        }
    }

    /// Advance the membership clock by `ms` of *virtual* time — the
    /// fault-injection harness's deterministic lease expiry (leases are
    /// measured on this clock, never on `Instant::now` directly).
    pub fn advance_time(&self, ms: u64) {
        self.state.clock.advance(ms);
    }

    /// Run one membership sweep (lease expiry + keepalive probes) now,
    /// without waiting for the background tick.
    pub fn membership_tick(&self) {
        membership_tick(&self.state);
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    /// Crash simulation for the durability harness: seal the WAL first —
    /// the on-disk state freezes at this instant, and any still-running
    /// job or resume thread writes into the void from here on — then
    /// tear down the accept/tick threads so the port frees for a
    /// same-data-dir restart. Unlike [`Coordinator::shutdown`], nothing
    /// is flushed, completed, or deregistered: exactly what a `kill -9`
    /// would leave behind, minus the process exit.
    pub fn hard_kill(mut self) {
        if let Some(wal) = &self.state.wal {
            wal.seal();
        }
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.state.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the accept loop through the shared dialing path (the
        // pool's `dial`), not an ad-hoc `TcpStream::connect`, so liveness
        // checks and real RPCs cannot diverge
        let _ = pool::dial(&self.addr.to_string(), Duration::from_millis(500));
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.tick_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Install WAL-replayed state into a fresh coordinator: sessions come
/// back with their manifests and monotonic identifiers but **empty**
/// shard layouts — the first scatter re-homes them onto whatever
/// workers are actually alive now (`plan_rebalance` under live
/// membership, [`rehome_static`] otherwise) — terminal jobs come back
/// queryable via `agent_status`, and in-flight jobs are returned for
/// the resume threads.
fn install_recovered(
    state: &Arc<CoordState>,
    rec: recovery::Recovered,
) -> Vec<(recovery::RecoveredJob, Arc<job::JobSlot>)> {
    let metrics = &state.deps.metrics;
    metrics
        .counter("recovery.replayed_records")
        .fetch_add(rec.replayed, Ordering::Relaxed);
    metrics
        .counter("recovery.skipped_records")
        .fetch_add(rec.skipped, Ordering::Relaxed);
    let n_sessions = rec.sessions.len();
    for t in rec.tenants {
        state.tenants.install(TenantInfo {
            name: t.name,
            token: t.token,
            weight: t.weight,
            max_workers: t.max_workers,
            explicit: t.explicit,
        });
    }
    {
        let mut sessions = state.sessions.lock().unwrap();
        for (name, rs) in rec.sessions {
            // implicit registrations are not WAL-logged; re-ensure so
            // recovered data sessions count against the quota again
            let _ = state.tenants.ensure(&name);
            sessions.insert(
                name,
                Arc::new(Mutex::new(ClusterSession {
                    manifest: rs.manifest,
                    init_labels: rs.init_labels,
                    epoch: rs.epoch,
                    view_gen: rs.view_gen,
                    next_sid: rs.next_sid,
                    shards: vec![],
                    retired: vec![],
                    init_emb: None,
                    test_emb: None,
                })),
            );
        }
    }
    let mut resumable = Vec::new();
    for j in rec.jobs {
        let slot = if let Some(st) = j.terminal_state() {
            state.jobs.restore(&j.id, st)
        } else if j.cancelled {
            // the cancel was acknowledged before the crash but the final
            // trace never landed: honor the ack, don't re-drive
            state.jobs.restore(&j.id, j.state_as(job::JobStatus::Cancelled))
        } else {
            state.jobs.restore(&j.id, j.state_as(job::JobStatus::Running))
        };
        // the WAL mirror leads with a rebuilt (deterministic) `job_start`
        // so a forced mid-job snapshot can re-fold the whole stream; the
        // push-event buffer seeds from the post-start records in physical
        // WAL order, keeping reconnecting subscribers' cursors continuous
        // across the restart (DESIGN.md §Events)
        slot.wal_mirror(&recovery::rec_job_start(
            &j.id,
            &j.session,
            &j.strategies,
            j.config.clone(),
            j.seed,
            &j.pool_labels,
            &j.test_labels,
            j.wait_ms,
        ));
        job::JobRegistry::seed_events(&slot, &j.raw);
        if j.done.is_none() && !j.cancelled {
            resumable.push((j, slot));
        }
    }
    if n_sessions > 0 || !resumable.is_empty() {
        crate::log_info!(
            "cluster",
            "recovered {n_sessions} session(s) and {} resumable job(s) from {}",
            resumable.len(),
            state.config.durability.data_dir
        );
    }
    resumable
}

/// How many times a resume thread retries its bootstrap (one retry per
/// heartbeat-ish interval) before declaring the job interrupted:
/// restarted workers re-join within a beat or two, but the coordinator
/// often comes back first.
const RESUME_BOOTSTRAP_ATTEMPTS: u32 = 20;

/// Drive one WAL-recovered in-flight job to completion on a background
/// thread. Failure (session gone, workers never returned, embedding
/// re-fetch failed) flips the job to `interrupted` — terminal like
/// `failed`, but the replayed spend ledger stays queryable — instead of
/// letting it vanish or sit "running" forever.
fn spawn_resume(
    state: Arc<CoordState>,
    job: recovery::RecoveredJob,
    slot: Arc<job::JobSlot>,
) {
    let job_id = job.id.clone();
    let slot_on_err = slot.clone();
    let metrics = state.deps.metrics.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("alaas-resume-{}", job.id))
        .spawn(move || {
            if let Err(e) = resume_job(&state, &job, &slot) {
                crate::log_warn!("cluster", "could not resume job {}: {e}", job.id);
                state
                    .deps
                    .metrics
                    .counter("agent.jobs_interrupted")
                    .fetch_add(1, Ordering::Relaxed);
                let mut s = slot.state.lock().unwrap();
                s.status = job::JobStatus::Interrupted;
                drop(s);
                slot.done.notify_all();
            }
        });
    if let Err(e) = spawned {
        // no thread will ever finish this slot: don't leave it "running"
        crate::log_warn!("cluster", "could not spawn resume thread for {job_id}: {e}");
        metrics.counter("agent.jobs_interrupted").fetch_add(1, Ordering::Relaxed);
        let mut s = slot_on_err.state.lock().unwrap();
        s.status = job::JobStatus::Interrupted;
        drop(s);
        slot_on_err.done.notify_all();
    }
}

/// The body of one resume thread: re-home the session, re-fetch the
/// labeled rows' embeddings (embeddings are never stored in the WAL —
/// the workers hold them), restore every live arm at its last completed
/// round, durably mark the resume point, and re-enter the PSHEA loop.
/// The resumed elimination trace is bit-identical to an uninterrupted
/// run: each arm's per-round seed derives from (base seed, rounds run),
/// and the crash-interrupted partial round was discarded at replay, so
/// the loop re-runs it from the same state the first run entered it in.
fn resume_job(
    state: &Arc<CoordState>,
    job: &recovery::RecoveredJob,
    slot: &Arc<job::JobSlot>,
) -> Result<(), String> {
    let sess = get_session(state, &job.session)?;
    let (manifest, init_labels) = {
        let s = lock_recover(&sess);
        (s.manifest.clone(), s.init_labels.clone())
    };
    let init_labels = init_labels.ok_or("recovered session has no init labels")?;
    let retry = Duration::from_millis(
        state.config.cluster.membership.heartbeat_ms.clamp(50, 1_000),
    );
    let mut boot = Err("bootstrap not attempted".to_string());
    for attempt in 0..RESUME_BOOTSTRAP_ATTEMPTS {
        if state.shutdown.load(Ordering::SeqCst) {
            return Err("coordinator shut down during resume".into());
        }
        boot = agent_bootstrap(state, &job.session, &sess, job.wait_ms);
        if boot.is_ok() {
            break;
        }
        if attempt + 1 < RESUME_BOOTSTRAP_ATTEMPTS {
            std::thread::sleep(retry);
        }
    }
    let (init_emb, test_emb, selectable) = boot?;
    let cfg = job::config_from_value(
        state.config.active_learning.agent.to_pshea(),
        Some(&job.config),
    )?;
    let sel = ClusterArmSelect {
        state: state.clone(),
        session_id: job.session.clone(),
        sess: sess.clone(),
        init_emb: init_emb.clone(),
        wait_ms: job.wait_ms,
        wal_job: state.wal.as_ref().map(|w| (w.clone(), slot.clone())),
    };
    // re-fetch each live arm's labeled-row embeddings against the
    // freshly homed layout, in original pick order
    let (_, _, epoch, specs) = snapshot_shards(&sess);
    let mut restores: Vec<(String, Vec<usize>, Vec<Vec<f32>>)> = Vec::new();
    for strategy in job.live() {
        let picks = job.arm_picks(&strategy);
        let fetched =
            sel.fetch_embeddings(&manifest, Some(&init_labels), epoch, &specs, &picks)?;
        let (labeled, rows) = fetched.into_iter().unzip();
        restores.push((strategy, labeled, rows));
    }
    let mut task = AgentTask::new(
        sel,
        state.deps.backend.clone(),
        selectable,
        init_emb,
        init_labels,
        job.pool_labels.clone(),
        test_emb,
        job.test_labels.clone(),
        manifest.num_classes,
        job.seed,
        Some(slot.cancel.clone()),
    )
    .with_tracer(state.tracer.clone());
    for (strategy, labeled, rows) in restores {
        let rounds = job.arm_rounds(&strategy);
        task.restore_arm(&strategy, labeled, rows, rounds).map_err(|e| e.to_string())?;
    }
    // durable resume point: on a second crash, replay truncates the
    // job's stream here instead of mixing two half-run rounds
    if let Some(w) = &state.wal {
        let resume = recovery::rec_job_resume(&job.id, job.completed_rounds);
        w.append_with(&resume, || slot.wal_mirror(&resume))?;
        slot.events.publish(resume);
    }
    state
        .deps
        .metrics
        .counter("recovery.resumed_jobs")
        .fetch_add(1, Ordering::Relaxed);
    crate::log_info!(
        "cluster",
        "resuming agent job {} on '{}' from round {}",
        job.id,
        job.session,
        job.completed_rounds
    );
    drive_and_log_done(state, slot, task, &job.strategies, &cfg, &job.records, &job.id);
    Ok(())
}

/// Run the PSHEA loop for one job and, when durability is on, tee every
/// loop event into the WAL (durable before observable) and append the
/// terminal `job_done` record when the loop exits — then attempt a
/// compaction, since this job no longer blocks one.
fn drive_and_log_done(
    state: &Arc<CoordState>,
    slot: &Arc<job::JobSlot>,
    task: AgentTask<ClusterArmSelect>,
    strategies: &[String],
    cfg: &PsheaConfig,
    prior: &[RoundRecord],
    job_id: &str,
) {
    match &state.wal {
        Some(w) => {
            let mut obs =
                WalObserver { wal: w.clone(), job: job_id.to_string(), slot: slot.clone() };
            job::drive_with(
                slot,
                task,
                strategies,
                cfg,
                &state.deps.metrics,
                prior,
                Some(&mut obs),
            );
            let (status, trace) = {
                let st = slot.state.lock().unwrap();
                (st.status.as_string(), st.trace.clone())
            };
            let done = recovery::rec_job_done(job_id, &status, trace.as_ref());
            w.append_best_effort_with(&done, || slot.wal_mirror(&done));
            try_compact(state);
        }
        None => job::drive(slot, task, strategies, cfg, &state.deps.metrics),
    }
}

/// [`job::fail`] plus the durable `job_done` record, so a restart
/// reports the job failed instead of retrying a doomed resume.
fn fail_logged(state: &CoordState, slot: &job::JobSlot, job_id: &str, err: String) {
    job::fail(slot, &state.deps.metrics, err);
    if let Some(w) = &state.wal {
        let status = slot.state.lock().unwrap().status.as_string();
        let done = recovery::rec_job_done(job_id, &status, None);
        w.append_best_effort_with(&done, || slot.wal_mirror(&done));
    }
}

/// Opportunistic WAL compaction. Cadence compaction is gated on no
/// running jobs: an in-flight job's stream would be cut in half by the
/// rotation — the closure re-checks after the rotation and aborts
/// (harmlessly) if a job started in the window, because that job's
/// `job_start` necessarily landed in the new, uncovered log.
///
/// The `[durability] max_wal_bytes` byte cap overrides the gate: when
/// uncovered log bytes reach it (a multi-hour job would otherwise grow
/// the WAL without bound), compaction is *forced* and the snapshot
/// embeds every running job's mirrored record stream, captured
/// atomically with the rotation — each record replays from exactly one
/// of snapshot or post-rotation log.
fn try_compact(state: &Arc<CoordState>) {
    let Some(wal) = &state.wal else { return };
    let force = wal.over_byte_cap();
    if !force && state.jobs.any_running() {
        return;
    }
    let st = state.clone();
    let cap = state.clone();
    let result = wal.compact_with(
        force,
        move || if force { capture_job_streams(&cap) } else { Vec::new() },
        move |streams| {
            if !force && st.jobs.any_running() {
                return None;
            }
            Some(snapshot_records(&st, streams))
        },
    );
    match result {
        Ok(true) if force => {
            crate::log_info!(
                "cluster",
                "forced wal compaction (max_wal_bytes cap); {} byte(s) live after",
                wal.wal_bytes()
            );
        }
        Err(e) => crate::log_warn!("cluster", "wal compaction failed: {e}"),
        _ => {}
    }
}

/// Capture every running job's mirrored WAL stream (`job_start` ..
/// latest record, verbatim). Runs inside [`SharedLog::compact_with`]'s
/// rotation critical section: every job-scoped append goes through
/// `append_with`, which pushes the mirror under the same lock, so each
/// stream splits exactly at the rotation point. Slots whose `job_start`
/// has not reached the log yet have an empty mirror and are skipped —
/// their whole stream lands in the post-rotation log.
fn capture_job_streams(state: &CoordState) -> Vec<Vec<Value>> {
    state
        .jobs
        .running_slots()
        .iter()
        .map(|s| s.mirror.lock().unwrap().clone())
        .filter(|m| !m.is_empty())
        .collect()
}

/// The compaction snapshot: a *compacted log* — `{"records": [...]}` in
/// the exact record vocabulary of the live WAL, replayed through the
/// same fold on open. Finished jobs are dropped here, mirroring the
/// in-process finished-job eviction; only sessions, tenants and the
/// view high-water survive a cadence compaction. A *forced* (byte-cap)
/// compaction additionally passes `job_streams` — running jobs'
/// mirrored record streams captured at the rotation point — so the
/// fold can reconstruct the in-flight jobs a cadence snapshot would
/// never contain.
fn snapshot_records(state: &CoordState, job_streams: Vec<Vec<Value>>) -> Value {
    let mut records = Vec::new();
    if state.config.cluster.membership.enabled {
        let generation = state.membership.lock().unwrap().generation();
        if generation > 0 {
            records.push(recovery::rec_view(generation));
        }
    }
    let sessions: Vec<(String, Arc<Mutex<ClusterSession>>)> = {
        let map = state.sessions.lock().unwrap();
        let mut v: Vec<_> = map.iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        // deterministic order: replay equivalence shouldn't depend on
        // hash-map iteration
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    };
    for t in state.tenants.list() {
        records.push(recovery::rec_tenant(
            &t.name,
            &t.token,
            t.weight,
            t.max_workers,
            t.explicit,
        ));
    }
    for (name, sess) in sessions {
        let s = lock_recover(&sess);
        records.push(recovery::rec_session(&name, &s.manifest, s.init_labels.as_deref()));
        records.push(recovery::rec_layout(&name, s.epoch, s.view_gen, s.next_sid));
    }
    for stream in job_streams {
        records.extend(stream);
    }
    crate::json::value::obj([("records", Value::Array(records))])
}

fn accept_loop(listener: TcpListener, state: Arc<CoordState>) {
    let pool = ThreadPool::new("alaas-coord-conn", 16, 64);
    for conn in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let state = state.clone();
                pool.execute(move || handle_conn(stream, state));
            }
            Err(e) => {
                crate::log_warn!("cluster", "accept error: {e}");
            }
        }
    }
    pool.shutdown();
}

fn handle_conn(mut stream: TcpStream, state: Arc<CoordState>) {
    rpc::serve_conn_ext(
        &mut stream,
        "cluster",
        &state.shutdown,
        &state.deps.metrics,
        Some(&state.tracer),
        state.config.server.wire,
        |method, params, _mode, ctx| dispatch(&state, method, params, ctx),
    );
}

fn dispatch(
    state: &Arc<CoordState>,
    method: &str,
    params: &Body,
    ctx: &rpc::RequestCtx,
) -> Result<Payload, String> {
    match method {
        "hello" => Ok(Payload::json(wire::hello_reply(
            &params.value,
            state.config.server.wire,
            state.config.server.mux,
        ))),
        "ping" => Ok(Payload::json(Value::from("pong"))),
        "register" => register(state, &params.value).map(Payload::json),
        // live-membership lease protocol (DESIGN.md §Cluster)
        "heartbeat" => heartbeat_rpc(state, &params.value).map(Payload::json),
        "members" => Ok(Payload::json(members_rpc(state))),
        "deregister" => deregister_rpc(state, &params.value).map(Payload::json),
        "push_data" => push_data(state, params).map(Payload::json),
        "status" => status(state, &params.value).map(Payload::json),
        "query" => query(state, &params.value).map(Payload::json),
        // multi-tenant session lifecycle (DESIGN.md §Tenancy)
        "session_create" => session_create(state, &params.value).map(Payload::json),
        "session_close" => session_close(state, &params.value).map(Payload::json),
        "service_stats" => Ok(Payload::json(service_stats(state))),
        "metrics" => Ok(Payload::json(state.deps.metrics.snapshot())),
        "metrics_text" => Ok(Payload::json(Value::from(
            crate::metrics::render_prometheus(&state.deps.metrics.snapshot()),
        ))),
        // trace plane (DESIGN.md §Observability)
        "trace_recent" => {
            Ok(Payload::json(crate::trace::rpc_recent(&state.tracer, &params.value)))
        }
        "trace_get" => {
            crate::trace::rpc_get(&state.tracer, &params.value).map(Payload::json)
        }
        "strategies" => Ok(Payload::json(Value::Array(
            strategies::zoo_names().into_iter().map(Value::from).collect(),
        ))),
        "cache_stats" => cache_stats(state).map(Payload::json),
        "cluster_status" => Ok(Payload::json(cluster_status(state))),
        // agent-as-a-service job family (DESIGN.md §Agent): same surface
        // as the single server, arms fan out over the worker shards
        "agent_start" => agent_start(state, params).map(Payload::json),
        "agent_status" => job::rpc_status(&state.jobs, &params.value).map(Payload::json),
        "agent_result" => job::rpc_result(&state.jobs, &params.value).map(Payload::json),
        // push event stream (DESIGN.md §Events): unsolicited frames on
        // this connection from the subscribe ack onward
        "job_subscribe" => {
            job::rpc_subscribe(&state.jobs, &params.value, ctx).map(Payload::json)
        }
        "job_events" => job::rpc_events(&state.jobs, &params.value).map(Payload::json),
        "agent_cancel" => {
            let reply = job::rpc_cancel(&state.jobs, &params.value).map(Payload::json)?;
            // durable after the fact: a crash between ack and the
            // driver loop noticing still replays as cancelled
            if let Some(wal) = &state.wal {
                if let Ok(id) = str_param(&params.value, "job") {
                    let cancel = recovery::rec_job_cancel(&id);
                    match state.jobs.get(&id) {
                        Ok(slot) => wal
                            .append_best_effort_with(&cancel, || slot.wal_mirror(&cancel)),
                        Err(_) => wal.append_best_effort(&cancel),
                    }
                }
            }
            Ok(reply)
        }
        other => Err(format!("unknown method '{other}'")),
    }
}


/// RPCs that answer promptly (`scan_shard` registers the session and
/// returns; processing is backgrounded).
const FAST_RPC_TIMEOUT: Duration = Duration::from_secs(30);
/// Monitoring polls (`status`, `cache_stats`) must never hang the
/// coordinator on one stuck worker.
const POLL_RPC_TIMEOUT: Duration = Duration::from_secs(10);
/// Connect timeout for worker dials (the pre-pool per-call value, kept
/// so dead-worker detection latency is unchanged).
const WORKER_DIAL_TIMEOUT: Duration = Duration::from_secs(2);

/// Read deadline for a `select_shard` call: the worker may legitimately
/// block for the client-requested `wait_ms` while its scan finishes, so
/// the transport deadline must exceed it or a slow scan would cascade
/// into mark-dead + re-dispatch on every worker in turn.
fn select_rpc_timeout(wait_ms: u64) -> Duration {
    Duration::from_millis(wait_ms) + Duration::from_secs(60)
}

/// One blocking RPC to a worker over a pooled, wire-negotiated
/// connection (DESIGN.md §Wire). The pool dials + `hello`-negotiates at
/// most once per connection, reuses it across calls, evicts stale
/// sockets, and retries a dead *parked* connection once on a fresh dial —
/// so transport errors surfacing here mean the worker itself is
/// unreachable, exactly as with the old per-call dial.
fn call_worker(
    state: &CoordState,
    addr: &str,
    method: &str,
    params: &Payload,
    read_timeout: Duration,
) -> Result<Body, RpcError> {
    state.pool.call(addr, method, params, Some(read_timeout))
}

/// Snapshot of live worker slots as (slot index, addr).
fn live_slots(state: &CoordState) -> Vec<(usize, String)> {
    state
        .workers
        .lock()
        .unwrap()
        .iter()
        .enumerate()
        .filter(|(_, w)| w.alive)
        .map(|(i, w)| (i, w.addr.clone()))
        .collect()
}

fn worker_addr(state: &CoordState, slot: usize) -> Option<String> {
    let ws = state.workers.lock().unwrap();
    ws.get(slot).filter(|w| w.alive).map(|w| w.addr.clone())
}

/// Slot index for `addr` in the worker table, creating or reviving it.
/// Returns `(slot, newly_alive)`.
fn ensure_slot(state: &CoordState, addr: &str) -> (usize, bool) {
    let mut ws = state.workers.lock().unwrap();
    if let Some(i) = ws.iter().position(|w| w.addr == addr) {
        let newly_alive = !ws[i].alive;
        ws[i].alive = true;
        (i, newly_alive)
    } else {
        ws.push(WorkerSlot { addr: addr.to_string(), alive: true });
        (ws.len() - 1, true)
    }
}

fn mark_dead(state: &CoordState, slot: usize) {
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.get_mut(slot) {
        if w.alive {
            w.alive = false;
            let addr = w.addr.clone();
            crate::log_warn!("cluster", "worker {} ({}) marked dead", slot, addr);
            drop(ws);
            // its pooled connections are junk now; free the sockets
            state.pool.invalidate(&addr);
            // count actual transitions, not every observation of a dead slot
            state
                .deps
                .metrics
                .counter("cluster.workers_dead")
                .fetch_add(1, Ordering::Relaxed);
            // live membership: an observed transport death leaves the
            // view (generation bump → sessions rebalance the dead
            // worker's rows across the survivors on their next scatter)
            // — but only if a keepalive probe agrees. One RPC timing out
            // against a slow-but-healthy, still-heartbeating worker is
            // not proof of death, and evicting it would oscillate the
            // view (rebalance out, heartbeat re-join, rebalance back —
            // two full rescans of its rows per cycle). The probe dials
            // fresh: the idle set was invalidated above, so a stale
            // parked socket cannot fake health.
            if state.config.cluster.membership.enabled {
                if state.pool.probe_peer(&addr, PROBE_TIMEOUT) {
                    crate::log_info!(
                        "cluster",
                        "worker {addr} failed an RPC but answers probes; \
                         keeping its membership (slot revives on its next beat)"
                    );
                } else {
                    let (removed, generation, live) = {
                        let mut mem = state.membership.lock().unwrap();
                        let removed = mem.remove(&addr);
                        (removed, mem.generation(), mem.len())
                    };
                    if removed {
                        state
                            .deps
                            .metrics
                            .counter("membership.evictions")
                            .fetch_add(1, Ordering::Relaxed);
                        update_membership_gauges(state, generation, live);
                    }
                }
            }
        }
    }
}

fn update_membership_gauges(state: &CoordState, generation: u64, live: usize) {
    state.deps.metrics.gauge_set("membership.generation", generation);
    state.deps.metrics.gauge_set("membership.live_workers", live as u64);
    // every view transition funnels through here: record generation
    // advances in the WAL (best-effort — a lost view record only lowers
    // the generation floor recovery restores, and the +1 re-home
    // guarantee comes from layout records too). `fetch_max` gates the
    // append so per-tick gauge refreshes don't re-log the same view.
    if let Some(wal) = &state.wal {
        let prev = state.last_logged_view_gen.fetch_max(generation, Ordering::SeqCst);
        if generation > prev {
            wal.append_best_effort(&recovery::rec_view(generation));
        }
    }
}

/// Join/renew `addr` in the membership view (the `register` and
/// `heartbeat` paths). Returns `(joined, generation)`.
fn membership_join(state: &CoordState, addr: &str) -> (bool, u64) {
    let lease_ms = state.config.cluster.membership.lease_ms;
    let now = state.clock.now_ms();
    let (joined, generation, live) = {
        let mut mem = state.membership.lock().unwrap();
        let (joined, generation) = mem.heartbeat(addr, now, lease_ms);
        (joined, generation, mem.len())
    };
    if joined {
        state.deps.metrics.counter("membership.joins").fetch_add(1, Ordering::Relaxed);
        // a joining (or returning) worker may be a new process: drop its
        // pooled connections so the next call re-dials + re-negotiates
        state.pool.invalidate(addr);
        crate::log_info!(
            "cluster",
            "worker {addr} joined the view (generation {generation}, {live} live)"
        );
    }
    update_membership_gauges(state, generation, live);
    (joined, generation)
}

/// `heartbeat {addr}` — lease renewal + auto-discovery. A first beat
/// from an unknown address joins the worker into the view, bumping the
/// generation (sessions rebalance a slice of the pool onto it at their
/// next scatter); later beats renew the lease. With membership disabled
/// this degrades to `register` — the static-config fallback — so
/// `--discover` workers interoperate with a statically configured
/// coordinator.
fn heartbeat_rpc(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let addr = str_param(params, "addr")?;
    if !addr.contains(':') {
        return Err(format!("worker address '{addr}' is not host:port"));
    }
    state.deps.metrics.counter("membership.heartbeats").fetch_add(1, Ordering::Relaxed);
    let (_, revived) = ensure_slot(state, &addr);
    let mut m = Map::new();
    if state.config.cluster.membership.enabled {
        let (joined, generation) = membership_join(state, &addr);
        m.insert("generation", Value::from(generation));
        m.insert(
            "lease_ms",
            Value::from(state.config.cluster.membership.lease_ms as usize),
        );
        m.insert("joined", Value::Bool(joined));
    } else {
        if revived {
            state.pool.invalidate(&addr);
            crate::log_info!(
                "cluster",
                "worker {addr} registered via heartbeat (static membership)"
            );
        }
        m.insert("generation", Value::from(0));
        m.insert("joined", Value::Bool(revived));
    }
    Ok(Value::Object(m))
}

/// `deregister {addr}` — graceful leave: the worker's rows rebalance
/// across the survivors at the next scatter instead of waiting out the
/// lease.
fn deregister_rpc(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let addr = str_param(params, "addr")?;
    let left = if state.config.cluster.membership.enabled {
        let (removed, generation, live) = {
            let mut mem = state.membership.lock().unwrap();
            let removed = mem.remove(&addr);
            (removed, mem.generation(), mem.len())
        };
        if removed {
            state
                .deps
                .metrics
                .counter("membership.deregisters")
                .fetch_add(1, Ordering::Relaxed);
            update_membership_gauges(state, generation, live);
            crate::log_info!(
                "cluster",
                "worker {addr} deregistered (generation {generation}, {live} live)"
            );
        }
        removed
    } else {
        false
    };
    // retire the slot quietly (a goodbye, not a death: no
    // cluster.workers_dead count)
    {
        let mut ws = state.workers.lock().unwrap();
        if let Some(w) = ws.iter_mut().find(|w| w.addr == addr) {
            w.alive = false;
        }
    }
    state.pool.invalidate(&addr);
    let mut m = Map::new();
    m.insert("left", Value::Bool(left));
    Ok(Value::Object(m))
}

/// `members` — the generation-numbered membership view (the static slot
/// table, generation 0, when membership is disabled).
fn members_rpc(state: &Arc<CoordState>) -> Value {
    let mut m = Map::new();
    let enabled = state.config.cluster.membership.enabled;
    m.insert("enabled", Value::Bool(enabled));
    if enabled {
        let now = state.clock.now_ms();
        let (generation, leases) = {
            let mem = state.membership.lock().unwrap();
            (mem.generation(), mem.leases())
        };
        m.insert("generation", Value::from(generation));
        m.insert(
            "members",
            Value::Array(
                leases
                    .into_iter()
                    .map(|(addr, deadline)| {
                        let mut e = Map::new();
                        e.insert("addr", Value::from(addr));
                        e.insert(
                            "lease_ms_left",
                            Value::from(deadline.saturating_sub(now) as usize),
                        );
                        Value::Object(e)
                    })
                    .collect(),
            ),
        );
    } else {
        let ws = state.workers.lock().unwrap();
        m.insert("generation", Value::from(0));
        m.insert(
            "members",
            Value::Array(
                ws.iter()
                    .filter(|w| w.alive)
                    .map(|w| {
                        let mut e = Map::new();
                        e.insert("addr", Value::from(w.addr.clone()));
                        Value::Object(e)
                    })
                    .collect(),
            ),
        );
    }
    Value::Object(m)
}

/// Connect bound for one keepalive probe.
const PROBE_TIMEOUT: Duration = Duration::from_millis(750);

/// One membership sweep (the background tick; also callable directly
/// through [`Coordinator::membership_tick`]): expire overdue leases,
/// then keepalive-probe the members in the *suspect* half of their lease
/// — reusing lease state, so a healthy recently-renewed worker is never
/// probed — evicting dead peers before any query pays a scatter dial
/// timeout. Probes go through `ConnPool::probe_peer`, which counts
/// `pool.keepalive_probes` and never `pool.dials`.
fn membership_tick(state: &Arc<CoordState>) {
    let mcfg = &state.config.cluster.membership;
    if !mcfg.enabled {
        return;
    }
    let now = state.clock.now_ms();
    let expired = state.membership.lock().unwrap().expire(now);
    for addr in &expired {
        state
            .deps
            .metrics
            .counter("membership.expirations")
            .fetch_add(1, Ordering::Relaxed);
        crate::log_warn!("cluster", "worker {addr} lease expired");
        retire_slot(state, addr);
    }
    // suspects: more than half the lease gone without a renewal
    let suspects: Vec<String> = {
        let mem = state.membership.lock().unwrap();
        mem.leases()
            .into_iter()
            .filter(|(_, deadline)| deadline.saturating_sub(now) < mcfg.lease_ms / 2)
            .map(|(addr, _)| addr)
            .collect()
    };
    // probe concurrently: K unreachable suspects cost one probe timeout,
    // not K of them, so the sweep cadence (and a shutdown joining this
    // thread) never stalls behind a serial probe walk
    let failed: Vec<String> = std::thread::scope(|sc| {
        let handles: Vec<_> = suspects
            .iter()
            .map(|addr| {
                let addr = addr.as_str();
                sc.spawn(move || {
                    (!state.pool.probe_peer(addr, PROBE_TIMEOUT)).then(|| addr.to_string())
                })
            })
            .collect();
        // a panicked probe thread is a failed probe, not a silent pass:
        // swallowing it would keep a half-expired lease alive forever
        handles
            .into_iter()
            .zip(&suspects)
            .filter_map(|(h, addr)| {
                h.join().unwrap_or_else(|_| {
                    crate::log_warn!(
                        "cluster",
                        "keepalive probe of {addr} panicked; treating it as failed"
                    );
                    Some(addr.clone())
                })
            })
            .collect()
    });
    for addr in failed {
        if state.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let removed = state.membership.lock().unwrap().remove(&addr);
        if removed {
            state
                .deps
                .metrics
                .counter("membership.probe_evictions")
                .fetch_add(1, Ordering::Relaxed);
            crate::log_warn!(
                "cluster",
                "worker {addr} failed its keepalive probe; evicted"
            );
            retire_slot(state, &addr);
        }
    }
    let (generation, live) = {
        let mem = state.membership.lock().unwrap();
        (mem.generation(), mem.len())
    };
    update_membership_gauges(state, generation, live);
}

/// Mark the slot for `addr` dead (transport-level bookkeeping only)
/// after a membership departure the caller already recorded — unlike
/// `mark_dead`, no probe runs here: lease expiry has made the verdict,
/// and a wedged-but-alive process answering a probe must still leave.
fn retire_slot(state: &CoordState, addr: &str) {
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.iter_mut().find(|w| w.addr == addr) {
        if w.alive {
            w.alive = false;
            drop(ws);
            state.pool.invalidate(addr);
            state
                .deps
                .metrics
                .counter("cluster.workers_dead")
                .fetch_add(1, Ordering::Relaxed);
            crate::log_warn!("cluster", "worker {addr} retired from the slot table");
        }
    }
}

/// `register {addr}` — add a worker (or revive a known one).
fn register(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let addr = str_param(params, "addr")?;
    if !addr.contains(':') {
        return Err(format!("worker address '{addr}' is not host:port"));
    }
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.iter_mut().find(|w| w.addr == addr) {
        w.alive = true;
    } else {
        ws.push(WorkerSlot { addr: addr.clone(), alive: true });
    }
    let live = ws.iter().filter(|w| w.alive).count();
    drop(ws);
    // a (re)registered worker may be a new process with a new wire
    // config: drop its pooled connections so the next call re-dials and
    // re-negotiates instead of writing into a dead socket
    state.pool.invalidate(&addr);
    // under live membership, a one-shot register grants one lease — the
    // worker must heartbeat (`--discover`) to stay past it
    if state.config.cluster.membership.enabled {
        membership_join(state, &addr);
    }
    crate::log_info!("cluster", "worker {addr} registered ({live} live)");
    let mut m = Map::new();
    m.insert("workers", Value::from(live));
    Ok(Value::Object(m))
}

/// Worker-side session id for one shard *instance*: `epoch` isolates
/// pushes of the same client session, `sid` isolates shard layouts —
/// a rebalance mints fresh sids for changed shards, so a scatter pinned
/// to the previous layout can never read re-planned content through a
/// stale index mapping.
fn shard_session_id(session: &str, epoch: u64, sid: u64) -> String {
    format!("{session}@e{epoch}#s{sid}")
}

/// Identity + content of one shard as the scatter paths need it for
/// selects and (re-)pushes. Snapshotting a session yields these, and a
/// scatter runs entirely against its snapshot — the "pinned generation"
/// guarantee: a concurrent rebalance changes the session's layout but
/// never a scatter already in flight.
#[derive(Clone)]
struct ShardRef {
    /// Position in the layout it was snapshotted from (metrics keys,
    /// reply routing).
    shard: usize,
    /// Stable shard instance id (see [`ShardState::sid`]).
    sid: u64,
    /// Global pool positions this shard covers, ascending.
    indices: Vec<usize>,
    /// Worker slot assigned at snapshot time.
    worker: usize,
    /// Whether this shard's sub-manifest carries the test split.
    carries_test: bool,
}

/// Sub-manifest for one shard: the full init split (every worker
/// fine-tunes the identical head) plus the shard's pool slice. Exactly
/// one shard per session additionally carries the full test split — the
/// agent job evaluates arm accuracy on it (§Agent), and one scanned copy
/// suffices; a re-dispatch or rebalance of the carrier re-pushes the
/// test split with it.
fn sub_manifest(m: &Manifest, indices: &[usize], shard_idx: usize, with_test: bool) -> Manifest {
    Manifest {
        name: format!("{}#shard{shard_idx}", m.name),
        num_classes: m.num_classes,
        img_dim: m.img_dim,
        init: m.init.clone(),
        pool: indices.iter().map(|&i| m.pool[i].clone()).collect(),
        test: if with_test { m.test.clone() } else { vec![] },
    }
}

fn scan_shard_params(
    session: &str,
    epoch: u64,
    sref: &ShardRef,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
) -> Payload {
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, sref.sid)));
    p.insert("shard", Value::from(sref.shard));
    p.insert(
        "manifest",
        sub_manifest(manifest, &sref.indices, sref.shard, sref.carries_test).to_value(),
    );
    if let Some(l) = init_labels {
        // labels stay in the v1 integer-array form: these params are
        // built before the wire mode for the target worker is known, and
        // the JSON-fallback retry of this exact payload must remain
        // parseable by a pre-v2 worker (unlike AlClient, which only uses
        // the tensor form after a successful binary negotiation). Labels
        // are init-split-sized — noise next to the embedding tensors the
        // binary plane exists for.
        p.insert(
            "init_labels",
            Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect()),
        );
    }
    Payload::json(Value::Object(p))
}

/// Send one shard to a worker: the sref's assigned slot first, then any
/// other live worker. Returns the slot that accepted it.
fn dispatch_shard(
    state: &CoordState,
    session: &str,
    epoch: u64,
    sref: &ShardRef,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
) -> Result<usize, String> {
    let params = scan_shard_params(session, epoch, sref, manifest, init_labels);
    let mut last_err = String::from("no live workers");
    let preferred = sref.worker;
    let mut order = vec![preferred];
    order.extend(live_slots(state).into_iter().map(|(i, _)| i).filter(|&i| i != preferred));
    for slot in order {
        let Some(addr) = worker_addr(state, slot) else { continue };
        match call_worker(state, &addr, "scan_shard", &params, FAST_RPC_TIMEOUT) {
            Ok(_) => return Ok(slot),
            // the worker is alive and rejected the push itself (bad
            // manifest, spawn failure): deterministic — retrying the
            // identical params elsewhere would only kill healthy slots
            Err(e) if e.is_application() => {
                return Err(format!("shard {}: {}", sref.shard, e.remote_text()));
            }
            Err(e) => {
                last_err = format!("worker {addr}: {e}");
                mark_dead(state, slot);
            }
        }
    }
    Err(format!("shard {}: no live worker accepted ({last_err})", sref.shard))
}

/// `push_data {session, manifest, init_labels?}` — shard + scatter.
fn push_data(state: &Arc<CoordState>, params: &Body) -> Result<Value, String> {
    let session_id = resolve_session_param(state, &params.value)?;
    // a push auto-registers the session against the tenancy quota if it
    // was not created explicitly (back-compat with the stringly API)
    state.tenants.ensure(&session_id).map_err(|e| e.encode())?;
    let manifest_v = params.value.get("manifest").ok_or("missing param 'manifest'")?;
    let manifest = Manifest::from_value(manifest_v).map_err(|e| e.to_string())?;
    let init_labels = parse_init_labels(params, manifest.init.len())?;

    let live = capped_slots(state, &session_id, live_slots(state));
    if live.is_empty() {
        return Err("no live workers registered".into());
    }
    let epoch = state.push_epoch.fetch_add(1, Ordering::Relaxed);

    // Plan row ownership: the rendezvous planner over the live membership
    // view, or the PR 1 static shard plan when membership is disabled.
    let (view_gen, planned): (u64, Vec<(Vec<usize>, usize)>) =
        if state.config.cluster.membership.enabled {
            let view = state.membership.lock().unwrap().view();
            if view.members.is_empty() {
                return Err("no live workers registered".into());
            }
            let members = capped_members(state, &session_id, &view.members);
            let assignment = membership::assign(manifest.pool.len(), &members);
            let mut planned = Vec::new();
            for (addr, rows) in assignment {
                if rows.is_empty() {
                    continue;
                }
                let slot = ensure_slot(state, &addr).0;
                planned.push((rows, slot));
            }
            (view.generation, planned)
        } else {
            let plan = shard::plan(
                manifest.pool.len(),
                live.len(),
                state.config.cluster.shard_policy,
            );
            (
                0,
                plan.shards
                    .into_iter()
                    .enumerate()
                    .filter(|(_, idx)| !idx.is_empty())
                    .map(|(i, idx)| (idx, live[i].0))
                    .collect(),
            )
        };
    let srefs: Vec<ShardRef> = planned
        .into_iter()
        .enumerate()
        .map(|(i, (indices, slot))| ShardRef {
            shard: i,
            sid: i as u64,
            indices,
            worker: slot,
            carries_test: i == 0,
        })
        .collect();

    // Scatter every shard concurrently; a refused shard walks the
    // remaining live workers before giving up.
    let mut sg = state.tracer.child("scatter");
    sg.annotate("shards", srefs.len());
    let ctx = sg.ctx();
    let outcomes: Vec<Result<usize, String>> = std::thread::scope(|sc| {
        let handles: Vec<_> = srefs
            .iter()
            .map(|sref| {
                let (manifest, init_labels, session) =
                    (&manifest, &init_labels, session_id.as_str());
                sc.spawn(move || {
                    let mut g = state.tracer.child_of(ctx, "shard.push");
                    g.annotate("shard", sref.shard);
                    let r = dispatch_shard(
                        state, session, epoch, sref, manifest, init_labels.as_deref(),
                    );
                    match &r {
                        Ok(slot) => g.annotate("worker", slot),
                        Err(e) => g.annotate("error", e),
                    }
                    r
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("dispatch panicked".into())))
            .collect()
    });
    drop(sg);

    let mut ok: Vec<(ShardRef, usize)> = Vec::new();
    let mut first_err = None;
    for (sref, o) in srefs.into_iter().zip(outcomes) {
        match o {
            Ok(slot) => ok.push((sref, slot)),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    if let Some(e) = first_err {
        // don't leave half a session resident on the workers
        let accepted: Vec<(u64, u64, usize)> =
            ok.iter().map(|(s, slot)| (epoch, s.sid, *slot)).collect();
        drop_shard_sessions(state, &session_id, &accepted);
        return Err(e);
    }
    let mut shards = Vec::new();
    for (sref, slot) in ok {
        debug_assert_eq!(sref.shard, shards.len());
        shards.push(ShardState {
            sid: sref.sid,
            indices: sref.indices,
            worker: slot,
            carries_test: sref.carries_test,
        });
    }
    let n_shards = shards.len();
    let next_sid = n_shards as u64;
    let sizes: Vec<Value> =
        shards.iter().map(|s| Value::from(s.indices.len())).collect();
    // durability: log the session + its layout identifiers BEFORE
    // installing or acknowledging — a crash after this point replays
    // the session; a failed append fails the push (the client retries)
    // and frees the scattered shards
    if let Some(wal) = &state.wal {
        let logged = wal
            .append(&recovery::rec_session(
                &session_id,
                &manifest,
                init_labels.as_deref(),
            ))
            .and_then(|_| {
                wal.append(&recovery::rec_layout(&session_id, epoch, view_gen, next_sid))
            });
        if let Err(e) = logged {
            let accepted: Vec<(u64, u64, usize)> =
                shards.iter().map(|s| (epoch, s.sid, s.worker)).collect();
            drop_shard_sessions(state, &session_id, &accepted);
            return Err(e);
        }
    }
    let new_sess = Arc::new(Mutex::new(ClusterSession {
        manifest: manifest.clone(),
        init_labels,
        epoch,
        view_gen,
        next_sid,
        shards,
        retired: Vec::new(),
        init_emb: None,
        test_emb: None,
    }));
    let previous = state
        .sessions
        .lock()
        .unwrap()
        .insert(session_id.clone(), new_sess.clone());
    let replaced = previous.is_some();
    if let Some(old) = previous {
        // free the old push's shard sessions (including instances its
        // rebalances retired, which carry their own epochs); epoched ids
        // mean they can never collide with the ones this push just
        // created. Drops a down slot couldn't take move into the NEW
        // session's ledger, so a wedged worker's resident copy is still
        // swept once it rejoins.
        let stale: Vec<(u64, u64, usize)> = {
            let o = lock_recover(&old);
            o.shards
                .iter()
                .map(|s| (o.epoch, s.sid, s.worker))
                .chain(o.retired.iter().copied())
                .collect()
        };
        let undelivered = drop_shard_sessions(state, &session_id, &stale);
        retain_undelivered(&new_sess, undelivered);
    }
    state.deps.metrics.meter("cluster.pushed_samples").add(manifest.pool.len() as u64);
    try_compact(state);

    let mut m = Map::new();
    m.insert("session", Value::from(session_id));
    m.insert("pool_samples", Value::from(manifest.pool.len()));
    m.insert("shards", Value::Array(sizes));
    m.insert("workers", Value::from(n_shards));
    m.insert("replaced", Value::Bool(replaced));
    Ok(Value::Object(m))
}

/// Best-effort `drop_session` for `(epoch, shard sid, worker slot)`
/// triples — cleanup after a partial push failure, a session re-push,
/// or a rebalance, so scanned shards don't accumulate in worker memory.
/// Transport errors are ignored (a dead process frees the memory on its
/// own, and an in-flight scatter still pinned to a dropped instance
/// re-pushes it lazily on `unknown session`), but triples whose slot is
/// not alive are **returned undelivered** without any dial: the worker
/// may be wedged-but-resident (lease-evicted, process alive), and
/// ledger-keeping callers must retry once it rejoins and revives the
/// slot.
fn drop_shard_sessions(
    state: &CoordState,
    session: &str,
    triples: &[(u64, u64, usize)],
) -> Vec<(u64, u64, usize)> {
    let mut undelivered = Vec::new();
    for &(epoch, sid, slot) in triples {
        let Some(addr) = worker_addr(state, slot) else {
            undelivered.push((epoch, sid, slot));
            continue;
        };
        let mut p = Map::new();
        p.insert("session", Value::from(shard_session_id(session, epoch, sid)));
        let params = Payload::json(Value::Object(p));
        if call_worker(state, &addr, "drop_session", &params, POLL_RPC_TIMEOUT).is_err() {
            crate::log_debug!(
                "cluster",
                "drop_session for shard instance {sid} on {addr} failed (ignored)"
            );
        }
    }
    undelivered
}

fn get_session(
    state: &CoordState,
    id: &str,
) -> Result<Arc<Mutex<ClusterSession>>, String> {
    state
        .sessions
        .lock()
        .unwrap()
        .get(id)
        .cloned()
        .ok_or_else(|| ServiceError::unknown_session(id).encode())
}

/// Pull the `session` param and translate an opaque `tok-*` handle back
/// to its session name. Plain names pass through unchanged, so the
/// pre-tenancy stringly API keeps working.
fn resolve_session_param(state: &CoordState, params: &Value) -> Result<String, String> {
    let raw = str_param(params, "session")?;
    state.tenants.resolve(&raw).map_err(|e| e.encode())
}

/// Take one scatter permit from the weighted-fair admission gate (a
/// no-op pass-through when tenancy is disabled). A shed verdict becomes
/// the structured `overloaded` error with its `retry_after_ms` hint.
fn admit_scatter(state: &CoordState, session: &str) -> Result<AdmitPermit, String> {
    state
        .gate
        .admit(session, state.tenants.weight_of(session))
        .map_err(|shed| shed.to_service_error().encode())
}

/// Apply the per-session worker cap to a membership view (rendezvous
/// top-k, stable under churn). Uncapped sessions see every member.
fn capped_members(state: &CoordState, session: &str, members: &[String]) -> Vec<String> {
    tenancy::worker_subset(members, state.tenants.max_workers_of(session), session)
}

/// Apply the per-session worker cap to the static live-slot list, keyed
/// by worker address so the kept subset matches [`capped_members`].
fn capped_slots(
    state: &CoordState,
    session: &str,
    live: Vec<(usize, String)>,
) -> Vec<(usize, String)> {
    let k = state.tenants.max_workers_of(session);
    if k == 0 || k >= live.len() {
        return live;
    }
    let addrs: Vec<String> = live.iter().map(|(_, a)| a.clone()).collect();
    let keep = tenancy::worker_subset(&addrs, k, session);
    live.into_iter().filter(|(_, a)| keep.contains(a)).collect()
}

/// `session_create {session, weight?, max_workers?}` — register a
/// tenant under the `max_sessions` quota and mint its opaque `tok-*`
/// handle (DESIGN.md §Tenancy). Idempotent: re-creating a name updates
/// its weight/worker-cap and returns the already-minted token.
fn session_create(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let name = str_param(params, "session")?;
    let weight = params.get("weight").and_then(Value::as_usize).unwrap_or(1) as u64;
    let max_workers = params.get("max_workers").and_then(Value::as_usize).unwrap_or(0);
    let already = state.tenants.get(&name).is_some();
    let info =
        state.tenants.create(&name, weight, max_workers).map_err(|e| e.encode())?;
    // durable before the ack: the handle must survive a restart, or
    // every token the client holds dies with the coordinator
    if let Some(wal) = &state.wal {
        if let Err(e) = wal.append(&recovery::rec_tenant(
            &info.name,
            &info.token,
            info.weight,
            info.max_workers,
            info.explicit,
        )) {
            if !already {
                state.tenants.close(&info.name);
            }
            return Err(e);
        }
    }
    state.deps.metrics.gauge_set("tenancy.sessions", state.tenants.count() as u64);
    let mut m = Map::new();
    m.insert("session", Value::from(info.name));
    m.insert("token", Value::from(info.token));
    m.insert("weight", Value::from(info.weight));
    m.insert("max_workers", Value::from(info.max_workers));
    Ok(Value::Object(m))
}

/// `session_close {session}` (name or token) — release the quota slot
/// and free every shard instance the session holds on the workers.
/// Idempotent: closing an unknown handle replies `closed: false`
/// instead of erroring, so retries after a lost ack are safe.
fn session_close(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let raw = str_param(params, "session")?;
    // an unknown token resolves to nothing: treat it as already closed
    let name = state.tenants.resolve(&raw).unwrap_or(raw);
    let known = state.tenants.get(&name).is_some()
        || state.sessions.lock().unwrap().contains_key(&name);
    if known {
        // durable before any state is torn down: a crash mid-close must
        // replay as closed, not resurrect a half-freed session
        if let Some(wal) = &state.wal {
            wal.append(&recovery::rec_session_close(&name))?;
        }
    }
    let closed = state.tenants.close(&name).is_some();
    let data = state.sessions.lock().unwrap().remove(&name);
    let mut dropped = 0usize;
    if let Some(sess) = data {
        let triples: Vec<(u64, u64, usize)> = {
            let s = lock_recover(&sess);
            s.shards
                .iter()
                .map(|sh| (s.epoch, sh.sid, sh.worker))
                .chain(s.retired.iter().copied())
                .collect()
        };
        dropped = triples.len();
        drop_shard_sessions(state, &name, &triples);
        try_compact(state);
    }
    state.deps.metrics.gauge_set("tenancy.sessions", state.tenants.count() as u64);
    let mut m = Map::new();
    m.insert("closed", Value::Bool(closed || dropped > 0));
    m.insert("dropped_shards", Value::from(dropped));
    Ok(Value::Object(m))
}

/// `service_stats` — the tenancy control-plane snapshot: registry and
/// gate counters plus per-session data footprints. Tokens never appear
/// here — a handle is returned only to its creator.
fn service_stats(state: &Arc<CoordState>) -> Value {
    let gs = state.gate.stats();
    let mut rows_of: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    {
        let map = state.sessions.lock().unwrap();
        for (k, sess) in map.iter() {
            let s = lock_recover(sess);
            rows_of.insert(k.clone(), (s.manifest.pool.len(), s.shards.len()));
        }
    }
    let tenants = state.tenants.list();
    let mut names: BTreeSet<String> = rows_of.keys().cloned().collect();
    names.extend(tenants.iter().map(|t| t.name.clone()));
    let by_name: HashMap<&str, &TenantInfo> =
        tenants.iter().map(|t| (t.name.as_str(), t)).collect();
    let mut sessions = Vec::new();
    let mut active = 0usize;
    for name in &names {
        let (rows, shards) = rows_of.get(name).copied().unwrap_or((0, 0));
        if shards > 0 {
            active += 1;
        }
        let t = by_name.get(name.as_str());
        let (admitted, shed, queued) =
            gs.per_session.get(name).copied().unwrap_or((0, 0, 0));
        let mut m = Map::new();
        m.insert("name", Value::from(name.clone()));
        m.insert("weight", Value::from(t.map(|t| t.weight).unwrap_or(1)));
        m.insert("explicit", Value::Bool(t.map(|t| t.explicit).unwrap_or(false)));
        m.insert("rows", Value::from(rows));
        m.insert("shards", Value::from(shards));
        m.insert("admitted", Value::from(admitted));
        m.insert("shed", Value::from(shed));
        m.insert("queued", Value::from(queued));
        sessions.push(Value::Object(m));
    }
    let cfg = state.tenants.config();
    let mut m = Map::new();
    m.insert("tenancy_enabled", Value::Bool(cfg.enabled));
    m.insert("sessions_total", Value::from(names.len()));
    m.insert("sessions_active", Value::from(active));
    m.insert("running", Value::from(gs.running));
    m.insert("queued", Value::from(gs.queued));
    m.insert("admitted_total", Value::from(gs.admitted_total));
    m.insert("shed_total", Value::from(gs.shed_total));
    m.insert("max_sessions", Value::from(cfg.max_sessions));
    m.insert("sessions", Value::Array(sessions));
    Value::Object(m)
}

/// What one shard's `select_shard` returned (indices already global).
struct ShardReply {
    shard: usize,
    /// Shard instance the reply belongs to — scatter bookkeeping only
    /// writes back into the live layout if it still holds this instance
    /// (a concurrent rebalance may have replaced it).
    sid: u64,
    candidates: Vec<Candidate>,
    failed_global: Vec<usize>,
    scan_ms: f64,
    init_emb: Option<Mat>,
    test_emb: Option<Mat>,
    /// Slot that finally served the shard (differs from the assignment
    /// after a re-dispatch).
    worker: usize,
}

struct ShardJob {
    sref: ShardRef,
    budget: usize,
    with_embeddings: bool,
    with_init_emb: bool,
    with_test_emb: bool,
    /// Agent-path extras (§Agent): absent/empty on the plain query path.
    seed: Option<u64>,
    /// Shard-local indices the arm already labeled.
    exclude: Vec<usize>,
    /// The arm's current head (rides as tensor sections on the v2 wire).
    head: Option<LinearHead>,
    /// The arm's labeled embeddings (extra labeled context for refine).
    labeled_emb: Option<Mat>,
}

impl ShardJob {
    fn plain(
        sref: ShardRef,
        budget: usize,
        with_embeddings: bool,
        with_init_emb: bool,
    ) -> ShardJob {
        ShardJob {
            sref,
            budget,
            with_embeddings,
            with_init_emb,
            with_test_emb: false,
            seed: None,
            exclude: vec![],
            head: None,
            labeled_emb: None,
        }
    }
}

/// Call one worker-facing method for a shard, walking survivors on
/// transport failure and re-pushing the shard (`scan_shard`) on `unknown
/// session` — the shared re-dispatch skeleton for `select_shard` and
/// `fetch_rows`. The sref carries everything a re-push needs (indices,
/// instance id, test-split ownership), which is what lets an in-flight
/// scatter complete against its pinned layout even after a rebalance
/// dropped the instance. Returns the reply plus the slot that finally
/// served it.
#[allow(clippy::too_many_arguments)]
fn call_shard_redispatch(
    state: &CoordState,
    session: &str,
    epoch: u64,
    sref: &ShardRef,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    method: &str,
    params: &Payload,
    read_timeout: Duration,
) -> Result<(Body, usize), String> {
    let shard_idx = sref.shard;
    let mut slot = sref.worker;
    let mut last_err = String::from("no live workers");
    // first attempt on the assigned worker, then walk survivors; a worker
    // that doesn't know the session (never saw the shard, or restarted)
    // gets a fresh scan_shard push before serving.
    for _attempt in 0..=live_slots(state).len() {
        let Some(addr) = worker_addr(state, slot) else {
            match next_live_slot(state, slot) {
                Some(s) => {
                    slot = s;
                    continue;
                }
                None => break,
            }
        };
        let resp = match call_worker(state, &addr, method, params, read_timeout) {
            Err(e) if e.is_unknown_session() => {
                state
                    .deps
                    .metrics
                    .counter("cluster.shard_redispatch")
                    .fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "cluster",
                    "re-dispatching shard {shard_idx} of '{session}' to {addr}"
                );
                call_worker(
                    state,
                    &addr,
                    "scan_shard",
                    &scan_shard_params(session, epoch, sref, manifest, init_labels),
                    FAST_RPC_TIMEOUT,
                )
                .and_then(|_| call_worker(state, &addr, method, params, read_timeout))
            }
            other => other,
        };
        match resp {
            Ok(v) => return Ok((v, slot)),
            Err(e) if e.is_application() => {
                // the worker is alive; the request itself is bad
                return Err(format!("shard {shard_idx}: {}", e.remote_text()));
            }
            Err(e) => {
                last_err = format!("worker {addr}: {e}");
                mark_dead(state, slot);
                match next_live_slot(state, slot) {
                    Some(s) => slot = s,
                    None => break,
                }
            }
        }
    }
    Err(format!("shard {shard_idx}: no live worker served it ({last_err})"))
}

/// Build the `select_shard` request payload for one job — shared by the
/// multiplexed fan-out and the blocking re-dispatch path, so both wires
/// carry byte-identical requests.
fn select_shard_params(
    session: &str,
    epoch: u64,
    job: &ShardJob,
    strategy: &str,
    wait_ms: u64,
) -> Payload {
    let mut params = Payload::default();
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, job.sref.sid)));
    p.insert("budget", Value::from(job.budget));
    if job.budget > 0 {
        p.insert("strategy", Value::from(strategy));
    }
    p.insert("with_embeddings", Value::Bool(job.with_embeddings));
    p.insert("with_init_emb", Value::Bool(job.with_init_emb));
    if job.with_test_emb {
        p.insert("with_test_emb", Value::Bool(true));
    }
    p.insert("wait_ms", Value::from(wait_ms as usize));
    if let Some(seed) = job.seed {
        p.insert("seed", Value::from(seed));
    }
    if !job.exclude.is_empty() {
        p.insert(
            "exclude",
            Value::Array(job.exclude.iter().map(|&i| Value::from(i)).collect()),
        );
    }
    if let Some(h) = &job.head {
        // tensor placeholders: raw f32 sections on the binary wire,
        // inlined {rows, cols, data} objects on a JSON retry
        p.insert("head_w", params.stash_mat(h.w.clone()));
        p.insert("head_b", params.stash_mat(Mat::from_vec(h.b.clone(), 1, h.b.len())));
    }
    if let Some(l) = &job.labeled_emb {
        p.insert("labeled_emb", params.stash_mat(l.clone()));
    }
    params.value = Value::Object(p);
    params
}

/// Run `select_shard` for one shard over the blocking path,
/// re-dispatching to a survivor when the owning worker is unreachable.
#[allow(clippy::too_many_arguments)]
fn select_on_shard(
    state: &CoordState,
    session: &str,
    epoch: u64,
    job: &ShardJob,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    strategy: &str,
    wait_ms: u64,
) -> Result<ShardReply, String> {
    let params = select_shard_params(session, epoch, job, strategy, wait_ms);
    let (reply, slot) = call_shard_redispatch(
        state,
        session,
        epoch,
        &job.sref,
        manifest,
        init_labels,
        "select_shard",
        &params,
        select_rpc_timeout(wait_ms),
    )?;
    decode_shard_reply(reply, job, slot)
}

fn next_live_slot(state: &CoordState, after: usize) -> Option<usize> {
    let live = live_slots(state);
    if live.is_empty() {
        return None;
    }
    live.iter()
        .map(|(i, _)| *i)
        .find(|&i| i > after)
        .or_else(|| live.first().map(|(i, _)| *i))
}

fn decode_shard_reply(
    reply: Body,
    job: &ShardJob,
    worker: usize,
) -> Result<ShardReply, String> {
    // zero-copy consume (DESIGN.md §Wire): the reply's tensor sections
    // stay in the received frame buffer; candidate score/embedding rows
    // are copied exactly once, straight from that buffer into the merge
    // inputs — no intermediate Mat per section.
    let v = &reply.value;
    let to_global = |local: usize| -> Result<usize, String> {
        job.sref
            .indices
            .get(local)
            .copied()
            .ok_or_else(|| {
                format!("shard {}: local index {local} out of range", job.sref.shard)
            })
    };
    let failed_global = v
        .get("failed")
        .and_then(Value::as_array)
        .unwrap_or(&[])
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| "bad failed index".to_string())
                .and_then(|l| to_global(l))
        })
        .collect::<Result<Vec<_>, _>>()?;
    let mut candidates = Vec::new();
    if let Some(arr) = v.get("candidates").and_then(Value::as_array) {
        // refine-protocol matrices arrive packed: one [N, 4] score and one
        // [N, D] embedding tensor whose rows parallel the slim candidate
        // list. A PR1-era worker instead embeds per-candidate float
        // arrays, which Candidate::from_value still decodes.
        let cand_scores = reply.mat_ref("cand_scores")?;
        let cand_emb = reply.mat_ref("cand_emb")?;
        for m in [&cand_scores, &cand_emb].into_iter().flatten() {
            if m.rows() != arr.len() {
                return Err(format!(
                    "shard {}: packed tensor rows {} != {} candidates",
                    job.sref.shard,
                    m.rows(),
                    arr.len()
                ));
            }
        }
        for (i, c) in arr.iter().enumerate() {
            let mut cand = Candidate::from_value(c)?;
            cand.idx = to_global(cand.idx)?;
            if let Some(m) = &cand_scores {
                cand.scores = m.row_vec(i);
            }
            if let Some(m) = &cand_emb {
                cand.emb = m.row_vec(i);
            }
            candidates.push(cand);
        }
    }
    let init_emb = reply.mat("init_emb")?;
    let test_emb = reply.mat("test_emb")?;
    Ok(ShardReply {
        shard: job.sref.shard,
        sid: job.sref.sid,
        candidates,
        failed_global,
        scan_ms: v.get("scan_ms").and_then(Value::as_f64).unwrap_or(0.0),
        init_emb,
        test_emb,
        worker,
    })
}

/// Scatter a set of shard jobs concurrently and absorb the bookkeeping
/// every caller needs: worker reassignment after re-dispatch, caching of
/// fetched init/test embeddings, per-shard scan metrics, and the
/// straggler gauge. Shared by `query` and the agent job's selector.
#[allow(clippy::too_many_arguments)]
fn scatter_jobs(
    state: &CoordState,
    session_id: &str,
    sess: &Arc<Mutex<ClusterSession>>,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    epoch: u64,
    jobs: &[ShardJob],
    strategy: &str,
    wait_ms: u64,
) -> Result<Vec<ShardReply>, String> {
    let mut sg = state.tracer.child("scatter");
    sg.annotate("shards", jobs.len());
    // spawned shard threads don't inherit the thread-local span context:
    // hand each one the scatter span's ctx explicitly
    let ctx = sg.ctx();

    // Phase 1 — multiplexed fan-out, zero threads: every job whose
    // assigned worker speaks (or may speak) the muxed wire gets its
    // request written onto the shared connection and parked as a
    // completion slot. Each request is stamped with its own
    // `shard.select` span: the guard installs the span as this thread's
    // current context for the duration of the write (that is what
    // `send_request_wire` piggybacks), then the context is restored so
    // the next job's span parents under the scatter, not under its
    // sibling — which also makes the guards safe to drop in completion
    // order rather than LIFO.
    let mut pending: Vec<Option<(pool::PendingCall, crate::trace::SpanGuard<'_>)>> =
        Vec::with_capacity(jobs.len());
    let mut fallback: Vec<usize> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        let started = worker_addr(state, job.sref.worker).and_then(|addr| {
            if state.pool.peer_muxes(&addr) == Some(false) {
                return None;
            }
            let saved = crate::trace::current();
            let mut g = state.tracer.child_of(ctx, "shard.select");
            g.annotate("shard", job.sref.shard);
            let params = select_shard_params(session_id, epoch, job, strategy, wait_ms);
            let r = state.pool.start(
                &addr,
                "select_shard",
                &params,
                Some(select_rpc_timeout(wait_ms)),
            );
            crate::trace::set_current(saved);
            match r {
                Ok(Some(call)) => Some((call, g)),
                // Ok(None): the peer refused mux on this dial. Err: the
                // transport is already in trouble — either way the
                // blocking path below owns mark-dead + survivor walking.
                Ok(None) | Err(_) => {
                    g.annotate("fallback", true);
                    None
                }
            }
        });
        if started.is_none() {
            fallback.push(i);
        }
        pending.push(started);
    }

    // Phase 2 — blocking fallback for classic peers (and dead slots):
    // the pre-mux scatter, scoped to exactly the jobs that need it.
    let mut results: Vec<Option<Result<ShardReply, String>>> =
        jobs.iter().map(|_| None).collect();
    if !fallback.is_empty() {
        let classic: Vec<Result<ShardReply, String>> = std::thread::scope(|sc| {
            let handles: Vec<_> = fallback
                .iter()
                .map(|&i| {
                    let job = &jobs[i];
                    sc.spawn(move || {
                        let mut g = state.tracer.child_of(ctx, "shard.select");
                        g.annotate("shard", job.sref.shard);
                        let r = select_on_shard(
                            state, session_id, epoch, job, manifest, init_labels, strategy,
                            wait_ms,
                        );
                        match &r {
                            Ok(rep) => {
                                g.annotate("worker", rep.worker);
                                g.annotate("scan_ms", format!("{:.1}", rep.scan_ms));
                            }
                            Err(e) => g.annotate("error", e),
                        }
                        r
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err("shard query panicked".into())))
                .collect()
        });
        for (&i, r) in fallback.iter().zip(classic) {
            results[i] = Some(r);
        }
    }

    // Phase 3 — drain the mux completions. `pool.wait` parks on the
    // shared connection's demux state; whichever waiter holds the reader
    // pumps frames for everyone, so draining sequentially costs one
    // wall-clock pass regardless of completion order. An `unknown
    // session` (worker restarted, shard instance dropped) or transport
    // failure recovers through the idempotent blocking path, which owns
    // the scan_shard re-push and the survivor walk.
    for (i, slot) in pending.into_iter().enumerate() {
        let Some((call, mut g)) = slot else { continue };
        let job = &jobs[i];
        let r = match state.pool.wait(call) {
            Ok(body) => decode_shard_reply(body, job, job.sref.worker),
            Err(e) if e.is_application() && !e.is_unknown_session() => {
                // the worker is alive; the request itself is bad
                Err(format!("shard {}: {}", job.sref.shard, e.remote_text()))
            }
            Err(_) => select_on_shard(
                state, session_id, epoch, job, manifest, init_labels, strategy, wait_ms,
            ),
        };
        match &r {
            Ok(rep) => {
                g.annotate("worker", rep.worker);
                g.annotate("scan_ms", format!("{:.1}", rep.scan_ms));
            }
            Err(e) => g.annotate("error", e),
        }
        results[i] = Some(r);
    }

    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r.ok_or("shard job produced no result")??);
    }

    // bookkeeping: re-dispatched assignments + fetched embeddings. The
    // worker write-back is keyed by the shard instance id (positions
    // shift across rebalances; sids never do): a rebalance may have
    // replaced this scatter's pinned layout mid-flight, and its replies
    // must then not clobber the new ownership — instead the retired
    // instance is remembered, because serving this reply may have
    // lazily re-pushed it onto the worker after the rebalance freed it.
    {
        let mut s = lock_recover(&sess);
        for r in &out {
            if let Some(sh) = s.shards.iter_mut().find(|sh| sh.sid == r.sid) {
                sh.worker = r.worker;
            } else {
                // entry-keyed: redispatch can re-push one retired
                // instance onto several workers in turn, and every copy
                // must be swept
                ledger_push(&mut s.retired, (epoch, r.sid, r.worker));
            }
            if let Some(m) = &r.init_emb {
                if s.init_emb.is_none() {
                    s.init_emb = Some(m.clone());
                }
            }
            if let Some(m) = &r.test_emb {
                if s.test_emb.is_none() {
                    s.test_emb = Some(m.clone());
                }
            }
        }
    }
    // if the client re-pushed this session id mid-flight, the
    // bookkeeping above went into a replaced (dead) object whose ledger
    // nothing will ever sweep — route every instance this scatter may
    // have lazily re-pushed after push_data's cleanup into the *live*
    // session's ledger instead, so the old-epoch shards are still freed
    let current = state.sessions.lock().unwrap().get(session_id).cloned();
    if let Some(cur) = current {
        if !Arc::ptr_eq(&cur, sess) {
            let mut c = lock_recover(&cur);
            for r in &out {
                ledger_push(&mut c.retired, (epoch, r.sid, r.worker));
            }
        }
    }
    // per-shard scan metrics + straggler spread
    let mut scan_min = f64::INFINITY;
    let mut scan_max: f64 = 0.0;
    for r in &out {
        let d = Duration::from_secs_f64((r.scan_ms / 1e3).max(0.0));
        state.deps.metrics.time("cluster.shard_scan", d);
        state.deps.metrics.time(&format!("cluster.shard{}.scan", r.shard), d);
        scan_min = scan_min.min(r.scan_ms);
        scan_max = scan_max.max(r.scan_ms);
    }
    if !out.is_empty() {
        let straggler_ms = (scan_max - scan_min).max(0.0) as u64;
        state.deps.metrics.gauge_set("cluster.scan.straggler_ms", straggler_ms);
        sg.annotate("straggler_ms", straggler_ms);
    }
    Ok(out)
}

/// `query {session, budget, strategy?, wait_ms?}` — scatter, merge,
/// respond in the exact shape of the single-server `query`.
fn query(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let session_id = resolve_session_param(state, params)?;
    let budget =
        params.get("budget").and_then(Value::as_usize).ok_or("missing usize param 'budget'")?;
    let strategy_name = match params.get("strategy").and_then(Value::as_str) {
        Some(s) => s.to_string(),
        None => state.config.active_learning.strategy.as_str().to_string(),
    };
    if strategy_name == "auto" {
        return Err(
            "strategy 'auto' requires the agent workflow (CLI `alaas agent`): the PSHEA \
             loop needs per-round oracle labels, which the one-shot query protocol does \
             not carry"
                .into(),
        );
    }
    let kind = merge::merge_kind(&strategy_name)
        .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
    let wait_ms =
        params.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;

    let sess = get_session(state, &session_id)?;
    // hold a fair-share permit for the whole scatter; an overloaded
    // gate sheds here with a structured retry_after_ms instead of
    // letting the request time out in a queue
    let _permit = admit_scatter(state, &session_id)?;
    // catch the shard layout up with the membership view, then snapshot:
    // the whole scatter below runs against this pinned layout even if
    // the view moves again mid-flight
    maybe_rebalance(state, &session_id, &sess)?;
    let (manifest, init_labels, epoch, shard_specs) = snapshot_shards(&sess);
    let have_init_emb = lock_recover(&sess).init_emb.is_some();
    let n_shards = shard_specs.iter().filter(|s| !s.indices.is_empty()).count().max(1);

    // per-shard candidate budget by merge protocol
    let oversample = state.config.cluster.oversample_factor;
    let (local_budget, with_embeddings) = match kind {
        MergeKind::ExactTopK { .. } => (budget, false),
        MergeKind::Refine => ((oversample * budget).div_ceil(n_shards).max(1), true),
        MergeKind::Random => (0, false),
    };
    let need_init_emb = matches!(kind, MergeKind::Refine)
        && !have_init_emb
        && !manifest.init.is_empty();

    let jobs: Vec<ShardJob> = shard_specs
        .into_iter()
        .filter(|s| !s.indices.is_empty())
        .enumerate()
        .map(|(pos, sref)| {
            ShardJob::plain(sref, local_budget, with_embeddings, need_init_emb && pos == 0)
        })
        .collect();

    let t_query = Instant::now();
    let shard_replies = scatter_jobs(
        state,
        &session_id,
        &sess,
        &manifest,
        init_labels.as_deref(),
        epoch,
        &jobs,
        &strategy_name,
        wait_ms,
    )?;
    let scan_max = shard_replies.iter().fold(0.0f64, |a, r| a.max(r.scan_ms));

    // merge
    let t0 = Instant::now();
    let mut mg = state.tracer.child("merge");
    mg.annotate("strategy", &strategy_name);
    mg.annotate("budget", budget);
    let picked_global: Vec<usize> = match kind {
        MergeKind::ExactTopK { ascending, .. } => {
            let cands: Vec<(usize, f32)> = shard_replies
                .iter()
                .flat_map(|r| r.candidates.iter().map(|c| (c.idx, c.score)))
                .collect();
            merge::merge_exact_topk(&cands, budget.min(cands.len()), ascending)
        }
        MergeKind::Random => {
            let mut failed = vec![false; manifest.pool.len()];
            for r in &shard_replies {
                for &g in &r.failed_global {
                    failed[g] = true;
                }
            }
            let ok_rows: Vec<usize> =
                (0..manifest.pool.len()).filter(|&i| !failed[i]).collect();
            let mut rng = Rng::new(SELECT_SEED);
            rng.sample_indices(ok_rows.len(), budget.min(ok_rows.len()))
                .into_iter()
                .map(|rel| ok_rows[rel])
                .collect()
        }
        MergeKind::Refine => {
            let all: Vec<&Candidate> =
                shard_replies.iter().flat_map(|r| r.candidates.iter()).collect();
            if all.is_empty() {
                vec![]
            } else {
                let (scores, emb) = merge::refine_inputs(&all);
                let labeled = {
                    let s = lock_recover(&sess);
                    s.init_emb.clone().unwrap_or_else(|| Mat::zeros(0, emb.cols()))
                };
                let strat = strategies::by_name(&strategy_name)
                    .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
                let ctx = SelectCtx {
                    scores: &scores,
                    embeddings: &emb,
                    labeled: &labeled,
                    backend: state.deps.backend.as_ref(),
                    seed: SELECT_SEED,
                };
                strat
                    .select(&ctx, budget)
                    .map_err(|e| e.to_string())?
                    .into_iter()
                    .map(|rel| all[rel].idx)
                    .collect()
            }
        }
    };
    mg.annotate("selected", picked_global.len());
    drop(mg);
    let select_elapsed = t0.elapsed();
    state.deps.metrics.time("al.select", select_elapsed);
    state.deps.metrics.meter("al.selected").add(picked_global.len() as u64);
    state.deps.metrics.time("cluster.query", t_query.elapsed());

    let selected: Vec<Value> = picked_global
        .iter()
        .map(|&g| {
            let sr: &SampleRef = &manifest.pool[g];
            let mut m = Map::new();
            m.insert("id", Value::from(sr.id as u64));
            m.insert("uri", Value::from(sr.uri.clone()));
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("strategy", Value::from(strategy_name));
    m.insert("selected", Value::Array(selected));
    m.insert("select_ms", Value::Number(select_elapsed.as_secs_f64() * 1e3));
    m.insert("scan_ms", Value::Number(scan_max));
    Ok(Value::Object(m))
}

/// Shard-spec snapshot of a session: one [`ShardRef`] per shard of the
/// current layout. Scatters run entirely against a snapshot — the
/// pinned-generation guarantee.
type ShardSpecs = Vec<ShardRef>;

fn snapshot_shards(
    sess: &Arc<Mutex<ClusterSession>>,
) -> (Manifest, Option<Vec<u8>>, u64, ShardSpecs) {
    let s = lock_recover(&sess);
    let specs: ShardSpecs = s
        .shards
        .iter()
        .enumerate()
        .map(|(i, sh)| ShardRef {
            shard: i,
            sid: sh.sid,
            indices: sh.indices.clone(),
            worker: sh.worker,
            carries_test: sh.carries_test,
        })
        .collect();
    (s.manifest.clone(), s.init_labels.clone(), s.epoch, specs)
}

/// Retired-instance ledger bound per session (`ClusterSession::retired`).
const RETIRED_CAP: usize = 64;

/// Enforce [`RETIRED_CAP`] on a ledger by evicting the **oldest**
/// entries (front) — newest obligations are the ones most likely to
/// still be deliverable.
fn ledger_cap(retired: &mut Vec<(u64, u64, usize)>) {
    if retired.len() > RETIRED_CAP {
        let excess = retired.len() - RETIRED_CAP;
        retired.drain(..excess);
    }
}

/// Append one drop obligation to a retired ledger: dedup + cap.
fn ledger_push(retired: &mut Vec<(u64, u64, usize)>, entry: (u64, u64, usize)) {
    if retired.contains(&entry) {
        return;
    }
    retired.push(entry);
    ledger_cap(retired);
}

/// Append undelivered drop triples to the session's retired ledger so a
/// later sweep can retry them (e.g. once a wedged worker rejoins and
/// its slot is revived).
fn retain_undelivered(
    sess: &Arc<Mutex<ClusterSession>>,
    undelivered: Vec<(u64, u64, usize)>,
) {
    if undelivered.is_empty() {
        return;
    }
    let mut s = lock_recover(&sess);
    let mut retired = std::mem::take(&mut s.retired);
    for p in undelivered {
        ledger_push(&mut retired, p);
    }
    s.retired = retired;
}

/// Everything a rebalance attempt computes under the session lock, so
/// the eager shard scatter can run with the lock *released*.
struct RebalancePlan {
    /// Generation the plan was computed from — install only if the
    /// session is still on it.
    base_gen: u64,
    epoch: u64,
    manifest: Manifest,
    init_labels: Option<Vec<u8>>,
    new_shards: Vec<ShardState>,
    /// Positions in `new_shards` whose content changed (need a scan).
    to_push: Vec<usize>,
    /// Old instances not carried over, as `(epoch, sid, slot)`.
    stale: Vec<(u64, u64, usize)>,
    moved: usize,
    reused_count: usize,
}

/// Re-plan a session's shard ownership when the membership view has
/// moved past the generation its layout was scattered under — the
/// tentpole of the live-membership subsystem (DESIGN.md §Cluster). The
/// rendezvous planner keeps moves minimal: a joining worker takes its
/// slice from every incumbent, a departed worker's rows scatter across
/// *all* survivors (never dumped on one), and any (owner, rows) pair
/// that did not change keeps its scanned shard session untouched — no
/// rescan. Changed shards are scanned eagerly under fresh instance ids;
/// a scatter already in flight keeps resolving its pinned ids (lazily
/// re-pushed on `unknown session` if their content was dropped), so
/// in-flight queries and agent rounds complete bit-identically against
/// the generation they started on. No-op when membership is disabled or
/// the generation is current.
///
/// Locking: the plan is computed under the session lock (cheap, no
/// I/O), the `scan_shard` scatter runs with the lock **released** —
/// status polls and in-flight scatter bookkeeping stay responsive
/// through a multi-second rescan — and the new layout is installed only
/// if the session is still on the generation the plan started from; a
/// lost race frees this attempt's scans and retries.
fn maybe_rebalance(
    state: &Arc<CoordState>,
    session_id: &str,
    sess: &Arc<Mutex<ClusterSession>>,
) -> Result<(), String> {
    if !state.config.cluster.membership.enabled {
        // the static-config counterpart of the rebalance below: a
        // WAL-restored session comes back with an empty layout, and the
        // first scatter re-homes it over the static worker table
        return rehome_static(state, session_id, sess);
    }
    for _attempt in 0..3 {
        let view = state.membership.lock().unwrap().view();
        let Some(plan) = plan_rebalance(state, session_id, &view, sess)? else {
            return Ok(()); // already current (retired sweep done inside)
        };

        // eagerly scan the changed shards on their new owners
        // (concurrent, like push_data); reused shards are untouched —
        // no rescan, and no session lock held across the network
        let pushes: Vec<(usize, ShardRef)> = plan
            .to_push
            .iter()
            .map(|&pos| {
                let sh = &plan.new_shards[pos];
                (
                    pos,
                    ShardRef {
                        shard: pos,
                        sid: sh.sid,
                        indices: sh.indices.clone(),
                        worker: sh.worker,
                        carries_test: sh.carries_test,
                    },
                )
            })
            .collect();
        let mut rg = state.tracer.child("rebalance");
        rg.annotate("pushes", pushes.len());
        let ctx = rg.ctx();
        let outcomes: Vec<Result<(usize, usize), String>> = std::thread::scope(|sc| {
            let handles: Vec<_> = pushes
                .iter()
                .map(|(pos, sref)| {
                    let (pos, manifest, init_labels) =
                        (*pos, &plan.manifest, &plan.init_labels);
                    sc.spawn(move || {
                        let mut g = state.tracer.child_of(ctx, "shard.rescan");
                        g.annotate("shard", pos);
                        let r = dispatch_shard(
                            state,
                            session_id,
                            plan.epoch,
                            sref,
                            manifest,
                            init_labels.as_deref(),
                        )
                        .map(|slot| (pos, slot));
                        match &r {
                            Ok((_, slot)) => g.annotate("worker", slot),
                            Err(e) => g.annotate("error", e),
                        }
                        r
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err("rebalance dispatch panicked".into()))
                })
                .collect()
        });
        let mut new_shards = plan.new_shards;
        let mut pushed_ok: Vec<(u64, u64, usize)> = Vec::new();
        let mut first_err = None;
        for o in outcomes {
            match o {
                Ok((pos, slot)) => {
                    new_shards[pos].worker = slot;
                    pushed_ok.push((plan.epoch, new_shards[pos].sid, slot));
                }
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        if let Some(e) = first_err {
            // keep the old (still fully consistent) layout; the next
            // scatter retries. Free what this attempt scanned (ledgering
            // anything a down slot couldn't take).
            let und = drop_shard_sessions(state, session_id, &pushed_ok);
            retain_undelivered(sess, und);
            return Err(format!("rebalance of '{session_id}' failed: {e}"));
        }

        // install — only if nothing moved underneath while unlocked.
        // The sessions-map guard is held across the swap so a concurrent
        // re-push of the same session id cannot interleave: either it
        // already replaced the entry (this layout belongs to a dead
        // object — free the scans and stop), or it waits behind the
        // guard and then frees the layout installed here as part of its
        // own replacement cleanup.
        let drops;
        let installed_next_sid;
        {
            let sessions = state.sessions.lock().unwrap();
            let still_current = sessions
                .get(session_id)
                .map(|cur| Arc::ptr_eq(cur, sess))
                .unwrap_or(false);
            if !still_current {
                // a client re-push replaced the session: free this
                // attempt's scans, routing anything a down slot couldn't
                // take into the live session's ledger (same guarantee as
                // every other undelivered path)
                let live = sessions.get(session_id).cloned();
                drop(sessions);
                let und = drop_shard_sessions(state, session_id, &pushed_ok);
                if let Some(live) = live {
                    retain_undelivered(&live, und);
                }
                return Ok(());
            }
            let mut s = lock_recover(&sess);
            if s.view_gen != plan.base_gen {
                // a concurrent rebalance won the race: this attempt's
                // scans are orphans — free them, retry
                drop(s);
                drop(sessions);
                let und = drop_shard_sessions(state, session_id, &pushed_ok);
                retain_undelivered(sess, und);
                continue;
            }
            // oldest obligations first, this rebalance's stale instances
            // last; the drop list below stays uncapped (every obligation
            // gets its delivery attempt now) while the retained ledger
            // is deduped + capped keeping the newest entries
            let mut d = std::mem::take(&mut s.retired);
            for e in plan.stale {
                if !d.contains(&e) {
                    d.push(e);
                }
            }
            // remember the drops: a scatter pinned to the old layout may
            // lazily re-push one of these instances after the free
            // below; the next sweep re-frees it (no worker-memory leak)
            let mut retained = d.clone();
            ledger_cap(&mut retained);
            s.retired = retained;
            s.shards = new_shards;
            s.view_gen = view.generation;
            installed_next_sid = s.next_sid;
            drops = d;
        }
        // best-effort: a lost layout record only means recovery re-homes
        // from the previous generation's identifiers (sid floor included
        // in every earlier layout record, minted monotonically)
        if let Some(wal) = &state.wal {
            wal.append_best_effort(&recovery::rec_layout(
                session_id,
                plan.epoch,
                view.generation,
                installed_next_sid,
            ));
        }
        drop_shard_sessions(state, session_id, &drops);
        state.deps.metrics.counter("membership.rebalances").fetch_add(1, Ordering::Relaxed);
        state
            .deps
            .metrics
            .counter("membership.moved_rows")
            .fetch_add(plan.moved as u64, Ordering::Relaxed);
        crate::log_info!(
            "cluster",
            "rebalanced '{session_id}' to generation {} ({} shards, {} reused, {} rows moved)",
            view.generation,
            plan.to_push.len() + plan.reused_count,
            plan.reused_count,
            plan.moved
        );
        return Ok(());
    }
    Err(format!(
        "rebalance of '{session_id}' kept racing membership changes; retry the request"
    ))
}

/// Re-home a session that has no shard layout onto the static worker
/// table — the restart-recovery path when `[cluster.membership]` is
/// disabled. (Under live membership the restored generation floor makes
/// `plan_rebalance` rebuild the layout instead; this function no-ops on
/// any session that already has shards.) Shard instance ids are minted
/// from the restored `next_sid`, so pre-crash instances — possibly
/// still resident in worker memory — are never read through a reused
/// id.
fn rehome_static(
    state: &Arc<CoordState>,
    session_id: &str,
    sess: &Arc<Mutex<ClusterSession>>,
) -> Result<(), String> {
    let (manifest, init_labels, epoch, base_sid) = {
        let s = lock_recover(&sess);
        if !s.shards.is_empty() || s.manifest.pool.is_empty() {
            return Ok(());
        }
        (s.manifest.clone(), s.init_labels.clone(), s.epoch, s.next_sid)
    };
    let live = capped_slots(state, session_id, live_slots(state));
    if live.is_empty() {
        return Err("no live workers registered".into());
    }
    let plan =
        shard::plan(manifest.pool.len(), live.len(), state.config.cluster.shard_policy);
    let srefs: Vec<ShardRef> = plan
        .shards
        .into_iter()
        .enumerate()
        .filter(|(_, idx)| !idx.is_empty())
        .enumerate()
        .map(|(pos, (i, indices))| ShardRef {
            shard: pos,
            sid: base_sid + pos as u64,
            indices,
            worker: live[i].0,
            carries_test: pos == 0,
        })
        .collect();
    let outcomes: Vec<Result<usize, String>> = std::thread::scope(|sc| {
        let handles: Vec<_> = srefs
            .iter()
            .map(|sref| {
                let (manifest, init_labels) = (&manifest, &init_labels);
                sc.spawn(move || {
                    dispatch_shard(
                        state,
                        session_id,
                        epoch,
                        sref,
                        manifest,
                        init_labels.as_deref(),
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("dispatch panicked".into())))
            .collect()
    });
    let mut new_shards: Vec<ShardState> = Vec::new();
    let mut pushed_ok: Vec<(u64, u64, usize)> = Vec::new();
    let mut first_err = None;
    for (sref, o) in srefs.into_iter().zip(outcomes) {
        match o {
            Ok(slot) => {
                pushed_ok.push((epoch, sref.sid, slot));
                new_shards.push(ShardState {
                    sid: sref.sid,
                    indices: sref.indices,
                    worker: slot,
                    carries_test: sref.carries_test,
                });
            }
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    if let Some(e) = first_err {
        let und = drop_shard_sessions(state, session_id, &pushed_ok);
        retain_undelivered(sess, und);
        return Err(format!("re-homing '{session_id}' failed: {e}"));
    }
    let next_sid = base_sid + new_shards.len() as u64;
    let n_shards = new_shards.len();
    {
        // install only if nothing raced us here (a concurrent re-home
        // from another request thread, or a client re-push replacing the
        // session object); a lost race frees this attempt's scans
        let sessions = state.sessions.lock().unwrap();
        let still_current = sessions
            .get(session_id)
            .map(|cur| Arc::ptr_eq(cur, sess))
            .unwrap_or(false);
        let mut s = lock_recover(&sess);
        if !still_current || !s.shards.is_empty() {
            drop(s);
            let live_sess = sessions.get(session_id).cloned();
            drop(sessions);
            let und = drop_shard_sessions(state, session_id, &pushed_ok);
            if let Some(target) = live_sess {
                retain_undelivered(&target, und);
            }
            return Ok(());
        }
        s.shards = new_shards;
        s.next_sid = next_sid;
    }
    if let Some(wal) = &state.wal {
        wal.append_best_effort(&recovery::rec_layout(session_id, epoch, 0, next_sid));
    }
    state
        .deps
        .metrics
        .counter("recovery.rehomed_sessions")
        .fetch_add(1, Ordering::Relaxed);
    crate::log_info!(
        "cluster",
        "re-homed recovered session '{session_id}' onto {n_shards} shard(s)"
    );
    Ok(())
}

/// The plan phase of [`maybe_rebalance`], entirely under the session
/// lock and free of I/O. Returns `None` when the layout is already on
/// the view's generation (after sweeping any retired instances that an
/// in-flight scatter may have re-pushed since the last rebalance).
fn plan_rebalance(
    state: &Arc<CoordState>,
    session_id: &str,
    view: &membership::View,
    sess: &Arc<Mutex<ClusterSession>>,
) -> Result<Option<RebalancePlan>, String> {
    let mut s = lock_recover(&sess);
    if s.view_gen == view.generation {
        // current — sweep any instances retired by earlier rebalances
        // that an in-flight scatter may have lazily re-pushed since.
        // Pairs whose worker slot is down stay in the ledger (no dial
        // paid): a wedged process may still hold them, and its rejoin
        // revives the slot so a later sweep can deliver the drop.
        let retired = std::mem::take(&mut s.retired);
        drop(s);
        if !retired.is_empty() {
            let undelivered = drop_shard_sessions(state, session_id, &retired);
            retain_undelivered(sess, undelivered);
        }
        return Ok(None);
    }
    if view.members.is_empty() {
        return Err("no live workers registered".into());
    }
    let members = capped_members(state, session_id, &view.members);
    let assignment = membership::assign(s.manifest.pool.len(), &members);

    // address each old shard currently lives on (reuse check + move count)
    let addr_of_old: Vec<Option<String>> = {
        let ws = state.workers.lock().unwrap();
        s.shards.iter().map(|sh| ws.get(sh.worker).map(|w| w.addr.clone())).collect()
    };
    let mut old_shard_of_row: HashMap<usize, usize> = HashMap::new();
    for (i, sh) in s.shards.iter().enumerate() {
        for &g in &sh.indices {
            old_shard_of_row.insert(g, i);
        }
    }

    // build the new layout, reusing untouched (owner, rows) pairs
    let mut new_shards: Vec<ShardState> = Vec::new();
    let mut to_push: Vec<usize> = Vec::new(); // positions in new_shards
    let mut reused_old: Vec<bool> = vec![false; s.shards.len()];
    let mut next_sid = s.next_sid;
    let mut moved = 0usize;
    for (addr, rows) in assignment {
        if rows.is_empty() {
            continue;
        }
        moved += rows
            .iter()
            .filter(|&&g| {
                old_shard_of_row
                    .get(&g)
                    .map(|&i| addr_of_old[i].as_deref() != Some(addr.as_str()))
                    .unwrap_or(true)
            })
            .count();
        let slot = ensure_slot(state, &addr).0;
        let reused = s.shards.iter().enumerate().find_map(|(i, sh)| {
            (!reused_old[i]
                && addr_of_old[i].as_deref() == Some(addr.as_str())
                && sh.indices == rows)
                .then_some((i, sh.sid, sh.carries_test))
        });
        match reused {
            Some((i, sid, carries_test)) => {
                reused_old[i] = true;
                new_shards.push(ShardState { sid, indices: rows, worker: slot, carries_test });
            }
            None => {
                let sid = next_sid;
                next_sid += 1;
                to_push.push(new_shards.len());
                new_shards.push(ShardState {
                    sid,
                    indices: rows,
                    worker: slot,
                    carries_test: false,
                });
            }
        }
    }
    // reserve the minted sids now, so a racing attempt cannot collide
    s.next_sid = next_sid;
    // exactly one shard must carry the test split (agent evaluation,
    // §Agent); if its previous carrier did not survive the re-plan,
    // re-home it on a shard that is being scanned anyway
    if !new_shards.is_empty()
        && !s.manifest.test.is_empty()
        && !new_shards.iter().any(|sh| sh.carries_test)
    {
        let pos = to_push.first().copied().unwrap_or(0);
        new_shards[pos].carries_test = true;
        if !to_push.contains(&pos) {
            to_push.push(pos);
        }
    }
    let stale: Vec<(u64, u64, usize)> = s
        .shards
        .iter()
        .enumerate()
        .filter(|(i, _)| !reused_old[*i])
        .map(|(_, sh)| (s.epoch, sh.sid, sh.worker))
        .collect();
    Ok(Some(RebalancePlan {
        base_gen: s.view_gen,
        epoch: s.epoch,
        manifest: s.manifest.clone(),
        init_labels: s.init_labels.clone(),
        new_shards,
        to_push,
        stale,
        moved,
        reused_count: reused_old.iter().filter(|&&r| r).count(),
    }))
}

/// Distributed [`ArmSelect`]: one PSHEA arm's selection scattered over the
/// session's worker shards through the same `select_shard` wire the plain
/// query uses, merged per the strategy's protocol (DESIGN.md §Agent).
struct ClusterArmSelect {
    state: Arc<CoordState>,
    session_id: String,
    sess: Arc<Mutex<ClusterSession>>,
    /// Init-split embeddings (labeled-context base for the refine merge).
    init_emb: Mat,
    wait_ms: u64,
    /// Durability plane for arm-round spend records: `(log, job slot)`
    /// on the agent path, `None` when durability is disabled. The slot
    /// carries the job id plus the WAL mirror and push-event buffer the
    /// spend record also feeds.
    wal_job: Option<(Arc<SharedLog>, Arc<job::JobSlot>)>,
}

impl ClusterArmSelect {
    /// Append the arm-round spend record — one per `select_arm` call,
    /// empty rounds included, because replay counts these to find an
    /// arm's resume point. Best-effort: a sealed or failing WAL never
    /// blocks the round. The record is mirrored, published to
    /// subscribers, and — this being the only per-round durability hook
    /// — used as the byte-cap compaction trip point, so a multi-hour
    /// job forces its own snapshots instead of growing the WAL forever.
    fn log_spend(&self, strategy: &str, picked: &[Picked]) {
        if let Some((wal, slot)) = &self.wal_job {
            let idxs: Vec<usize> = picked.iter().map(|p| p.0).collect();
            let rec = recovery::rec_job_spend(&slot.id, strategy, &idxs);
            wal.append_best_effort_with(&rec, || slot.wal_mirror(&rec));
            slot.events.publish(rec);
            try_compact(&self.state);
        }
    }

    /// Build one agent-path job per non-empty shard, mapping the arm's
    /// global exclusions onto shard-local indices.
    fn jobs_for(
        specs: ShardSpecs,
        budget: usize,
        with_embeddings: bool,
        seed: u64,
        excl: &HashSet<usize>,
        head: Option<&LinearHead>,
        labeled_emb: Option<&Mat>,
    ) -> Vec<ShardJob> {
        specs
            .into_iter()
            .filter(|sref| !sref.indices.is_empty())
            .map(|sref| {
                let exclude: Vec<usize> = sref
                    .indices
                    .iter()
                    .enumerate()
                    .filter_map(|(l, g)| excl.contains(g).then_some(l))
                    .collect();
                ShardJob {
                    sref,
                    budget,
                    with_embeddings,
                    with_init_emb: false,
                    with_test_emb: false,
                    seed: Some(seed),
                    exclude,
                    head: head.cloned(),
                    labeled_emb: labeled_emb.cloned(),
                }
            })
            .collect()
    }

    /// Fetch embeddings of specific global pool indices from their
    /// owning shards (`fetch_rows`), in `picked` order — the agent path
    /// of the coordinator-side `random` merge needs the rows it sampled.
    fn fetch_embeddings(
        &self,
        manifest: &Manifest,
        init_labels: Option<&[u8]>,
        epoch: u64,
        specs: &ShardSpecs,
        picked: &[usize],
    ) -> Result<Vec<Picked>, String> {
        if picked.is_empty() {
            return Ok(vec![]);
        }
        let mut g = self.state.tracer.child("fetch_embeddings");
        g.annotate("rows", picked.len());
        let mut where_of: HashMap<usize, (usize, usize)> = HashMap::new();
        for (si, sref) in specs.iter().enumerate() {
            for (l, g) in sref.indices.iter().enumerate() {
                where_of.insert(*g, (si, l));
            }
        }
        let mut per_shard: HashMap<usize, Vec<(usize, usize)>> = HashMap::new();
        for &g in picked {
            let &(si, l) = where_of
                .get(&g)
                .ok_or_else(|| format!("index {g} not covered by any shard"))?;
            per_shard.entry(si).or_default().push((g, l));
        }
        let mut emb_of: HashMap<usize, Vec<f32>> = HashMap::new();
        for (si, items) in per_shard {
            let sref = &specs[si];
            let mut p = Map::new();
            p.insert(
                "session",
                Value::from(shard_session_id(&self.session_id, epoch, sref.sid)),
            );
            p.insert(
                "rows",
                Value::Array(items.iter().map(|&(_, l)| Value::from(l)).collect()),
            );
            p.insert("wait_ms", Value::from(self.wait_ms as usize));
            let params = Payload::json(Value::Object(p));
            let (reply, slot) = call_shard_redispatch(
                &self.state,
                &self.session_id,
                epoch,
                sref,
                manifest,
                init_labels,
                "fetch_rows",
                &params,
                select_rpc_timeout(self.wait_ms),
            )?;
            // stale-instance bookkeeping (mirrors scatter_jobs): if a
            // rebalance retired this pinned instance mid-flight — or the
            // whole session was re-pushed and this object is dead —
            // serving the call may have lazily re-pushed the instance;
            // record the obligation in the *live* session's ledger so it
            // cannot leak in worker memory
            {
                let live = self
                    .state
                    .sessions
                    .lock()
                    .unwrap()
                    .get(&self.session_id)
                    .cloned();
                let target = live.unwrap_or_else(|| self.sess.clone());
                let replaced = !Arc::ptr_eq(&target, &self.sess);
                let mut s = lock_recover(&target);
                if replaced || !s.shards.iter().any(|sh| sh.sid == sref.sid) {
                    ledger_push(&mut s.retired, (epoch, sref.sid, slot));
                }
            }
            // zero-copy: each requested row is copied once, straight out
            // of the reply's frame buffer
            let m = reply.mat_ref("emb")?.ok_or("fetch_rows reply missing emb")?;
            if m.rows() != items.len() {
                return Err(format!(
                    "fetch_rows returned {} rows, wanted {}",
                    m.rows(),
                    items.len()
                ));
            }
            for (row, &(g, _)) in items.iter().enumerate() {
                emb_of.insert(g, m.row_vec(row));
            }
        }
        picked
            .iter()
            .map(|&g| {
                emb_of
                    .remove(&g)
                    .map(|e| (g, e))
                    .ok_or_else(|| format!("missing embedding for index {g}"))
            })
            .collect()
    }
}

impl ArmSelect for ClusterArmSelect {
    fn select_arm(
        &mut self,
        strategy: &str,
        budget: usize,
        head: &LinearHead,
        exclude: &[usize],
        arm_labeled: &Mat,
        seed: u64,
    ) -> Result<Vec<Picked>, String> {
        let kind = merge::merge_kind(strategy)
            .ok_or_else(|| format!("unknown strategy '{strategy}'"))?;
        let excl: HashSet<usize> = exclude.iter().copied().collect();
        // every arm round is one scatter: take a fair-share permit so a
        // heavy agent job cannot starve other tenants' queries
        let _permit = admit_scatter(&self.state, &self.session_id)?;
        // each arm round catches up with the membership view before
        // snapshotting — exact-merge arms are layout-independent, so a
        // mid-job rebalance cannot change their selections (§Agent)
        maybe_rebalance(&self.state, &self.session_id, &self.sess)?;
        let (manifest, init_labels, epoch, specs) = snapshot_shards(&self.sess);
        let n_shards = specs.iter().filter(|s| !s.indices.is_empty()).count().max(1);
        let picked: Vec<Picked> = match kind {
            MergeKind::ExactTopK { ascending, .. } => {
                // local top-k under the arm's head with its exclusions;
                // the union provably contains the global top-k, and the
                // shared total order makes the merge exact (§Cluster).
                // Candidates stay slim (scalars only) — the arm needs the
                // embeddings of the `budget` winners, not of every
                // shard's whole candidate list, so those are fetched
                // afterwards via fetch_rows (k× less tensor traffic).
                let jobs = Self::jobs_for(
                    specs.clone(),
                    budget,
                    false,
                    seed,
                    &excl,
                    Some(head),
                    None,
                );
                let replies = scatter_jobs(
                    &self.state,
                    &self.session_id,
                    &self.sess,
                    &manifest,
                    init_labels.as_deref(),
                    epoch,
                    &jobs,
                    strategy,
                    self.wait_ms,
                )?;
                let pairs: Vec<(usize, f32)> = replies
                    .iter()
                    .flat_map(|r| r.candidates.iter().map(|c| (c.idx, c.score)))
                    .collect();
                let picked =
                    merge::merge_exact_topk(&pairs, budget.min(pairs.len()), ascending);
                self.fetch_embeddings(&manifest, init_labels.as_deref(), epoch, &specs, &picked)?
            }
            MergeKind::Random => {
                // probe for failure lists; sampling is a pure function of
                // (ok-row count, seed) — identical to the single server
                let jobs = Self::jobs_for(specs.clone(), 0, false, seed, &excl, None, None);
                let replies = scatter_jobs(
                    &self.state,
                    &self.session_id,
                    &self.sess,
                    &manifest,
                    init_labels.as_deref(),
                    epoch,
                    &jobs,
                    strategy,
                    self.wait_ms,
                )?;
                let failed: HashSet<usize> = replies
                    .iter()
                    .flat_map(|r| r.failed_global.iter().copied())
                    .collect();
                let ok: Vec<usize> = (0..manifest.pool.len())
                    .filter(|g| !failed.contains(g) && !excl.contains(g))
                    .collect();
                let mut rng = Rng::new(seed);
                let picked: Vec<usize> = rng
                    .sample_indices(ok.len(), budget.min(ok.len()))
                    .into_iter()
                    .map(|rel| ok[rel])
                    .collect();
                self.fetch_embeddings(&manifest, init_labels.as_deref(), epoch, &specs, &picked)?
            }
            MergeKind::Refine => {
                let oversample = self.state.config.cluster.oversample_factor;
                let local = (oversample * budget).div_ceil(n_shards).max(1);
                let arm_ctx = (arm_labeled.rows() > 0).then_some(arm_labeled);
                let jobs =
                    Self::jobs_for(specs, local, true, seed, &excl, Some(head), arm_ctx);
                let replies = scatter_jobs(
                    &self.state,
                    &self.session_id,
                    &self.sess,
                    &manifest,
                    init_labels.as_deref(),
                    epoch,
                    &jobs,
                    strategy,
                    self.wait_ms,
                )?;
                let all: Vec<&Candidate> =
                    replies.iter().flat_map(|r| r.candidates.iter()).collect();
                if all.is_empty() {
                    self.log_spend(strategy, &[]);
                    return Ok(vec![]);
                }
                let (scores, emb) = merge::refine_inputs(&all);
                let labeled = if arm_labeled.rows() == 0 {
                    self.init_emb.clone()
                } else {
                    self.init_emb.vstack(arm_labeled)
                };
                let strat = strategies::by_name(strategy)
                    .ok_or_else(|| format!("unknown strategy '{strategy}'"))?;
                let ctx = SelectCtx {
                    scores: &scores,
                    embeddings: &emb,
                    labeled: &labeled,
                    backend: self.state.deps.backend.as_ref(),
                    seed,
                };
                let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
                picked
                    .into_iter()
                    .map(|rel| (all[rel].idx, all[rel].emb.clone()))
                    .collect()
            }
        };
        self.log_spend(strategy, &picked);
        Ok(picked)
    }
}

/// Probe every shard (waiting out scans), cache init/test embeddings on
/// the session, and return `(init_emb, test_emb, selectable_pool)` — the
/// agent job's bootstrap step on the coordinator.
fn agent_bootstrap(
    state: &Arc<CoordState>,
    session_id: &str,
    sess: &Arc<Mutex<ClusterSession>>,
    wait_ms: u64,
) -> Result<(Mat, Mat, usize), String> {
    // the bootstrap probe is one scatter: gate it like a query round
    let _permit = admit_scatter(state, session_id)?;
    maybe_rebalance(state, session_id, sess)?;
    let (manifest, init_labels, epoch, specs) = snapshot_shards(sess);
    let (have_init, have_test) = {
        let s = lock_recover(&sess);
        (s.init_emb.is_some(), s.test_emb.is_some())
    };
    let jobs: Vec<ShardJob> = specs
        .into_iter()
        .filter(|sref| !sref.indices.is_empty())
        .enumerate()
        .map(|(pos, sref)| {
            // the test split lives on its carrier shard (see sub_manifest)
            let want_test = !have_test && sref.carries_test;
            let mut j = ShardJob::plain(sref, 0, false, !have_init && pos == 0);
            j.with_test_emb = want_test;
            j
        })
        .collect();
    let replies = scatter_jobs(
        state,
        session_id,
        sess,
        &manifest,
        init_labels.as_deref(),
        epoch,
        &jobs,
        "",
        wait_ms,
    )?;
    let failed: HashSet<usize> = replies
        .iter()
        .flat_map(|r| r.failed_global.iter().copied())
        .collect();
    let selectable = manifest.pool.len() - failed.len();
    let s = lock_recover(&sess);
    let init_emb =
        s.init_emb.clone().ok_or("agent bootstrap did not yield init embeddings")?;
    let test_emb =
        s.test_emb.clone().ok_or("agent bootstrap did not yield test embeddings")?;
    Ok((init_emb, test_emb, selectable))
}

/// `agent_start {session, strategies, config?, seed?, pool_labels,
/// test_labels, wait_ms?}` — spawn a background PSHEA job whose arms
/// evaluate across the session's worker shards (DESIGN.md §Agent).
fn agent_start(state: &Arc<CoordState>, params: &Body) -> Result<Value, String> {
    let session_id = resolve_session_param(state, &params.value)?;
    let sess = get_session(state, &session_id)?;
    let (manifest, init_labels) = {
        let s = lock_recover(&sess);
        (s.manifest.clone(), s.init_labels.clone())
    };
    let p = parse_agent_start(
        params,
        state.config.active_learning.agent.to_pshea(),
        &manifest,
        init_labels.is_some(),
    )?;
    let num_classes = manifest.num_classes;
    let n_arms = p.strategies.len();
    let (job_id, job_slot) = state.jobs.create(&p.strategies);
    // Durability: the job must be on disk before any work happens (and
    // before the reply carries its id) — a crash right after the ack
    // must find it resumable.
    if let Some(wal) = &state.wal {
        let start = recovery::rec_job_start(
            &job_id,
            &session_id,
            &p.strategies,
            job::config_to_value(&p.cfg),
            p.seed,
            &p.pool_labels,
            &p.test_labels,
            p.wait_ms,
        );
        // the mirror leads with `job_start` so a forced mid-job snapshot
        // embeds a foldable stream (push under the log lock — see
        // `SharedLog::append_with`)
        if let Err(e) = wal.append_with(&start, || job_slot.wal_mirror(&start)) {
            state.jobs.fail_orphan(&job_id, &state.deps.metrics, &e);
            return Err(e);
        }
    }
    let bg = state.clone();
    let jid = job_id.clone();
    std::thread::Builder::new()
        .name(format!("alaas-agent-{job_id}"))
        .spawn(move || {
            let (init_emb, test_emb, selectable) =
                match agent_bootstrap(&bg, &session_id, &sess, p.wait_ms) {
                    Ok(x) => x,
                    Err(e) => {
                        fail_logged(&bg, &job_slot, &jid, e);
                        return;
                    }
                };
            let init_labels = match init_labels {
                Some(l) => l,
                None => {
                    fail_logged(&bg, &job_slot, &jid, "missing init labels".into());
                    return;
                }
            };
            let sel = ClusterArmSelect {
                state: bg.clone(),
                session_id: session_id.clone(),
                sess,
                init_emb: init_emb.clone(),
                wait_ms: p.wait_ms,
                wal_job: bg.wal.as_ref().map(|w| (w.clone(), job_slot.clone())),
            };
            let task = AgentTask::new(
                sel,
                bg.deps.backend.clone(),
                selectable,
                init_emb,
                init_labels,
                p.pool_labels,
                test_emb,
                p.test_labels,
                num_classes,
                p.seed,
                Some(job_slot.cancel.clone()),
            )
            .with_tracer(bg.tracer.clone());
            crate::log_info!(
                "cluster",
                "agent job {jid} started on '{session_id}' ({} arms across shards)",
                p.strategies.len()
            );
            drive_and_log_done(&bg, &job_slot, task, &p.strategies, &p.cfg, &[], &jid);
        })
        .map_err(|e| {
            // no thread will ever finish this slot: mark it failed so it
            // doesn't sit in the registry as a ghost "running" job
            state.jobs.fail_orphan(&job_id, &state.deps.metrics, &e.to_string());
            e.to_string()
        })?;

    let mut m = Map::new();
    m.insert("job", Value::from(job_id));
    m.insert("strategies", Value::from(n_arms));
    Ok(Value::Object(m))
}

/// The `status` poll request for one shard instance.
fn shard_status_params(session: &str, epoch: u64, sid: u64) -> Payload {
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, sid)));
    Payload::json(Value::Object(p))
}

/// Fold one shard-status RPC outcome into the status string the
/// aggregator understands — shared by the multiplexed and blocking polls.
fn shard_status_of(state: &CoordState, slot: usize, resp: Result<Body, RpcError>) -> String {
    match resp {
        Ok(v) => v
            .value
            .get("status")
            .and_then(Value::as_str)
            .unwrap_or("unknown")
            .to_string(),
        // the worker is reachable but lost the shard (e.g.
        // restart): a query will re-dispatch — do NOT kill
        // the slot over an application-level error
        Err(e) if e.is_application() => {
            format!("needs-redispatch: {}", e.remote_text())
        }
        Err(e) => {
            mark_dead(state, slot);
            format!("unreachable: {e}")
        }
    }
}

/// Poll one shard's worker for its status string (blocking path).
fn poll_shard_status(
    state: &CoordState,
    session: &str,
    epoch: u64,
    sid: u64,
    slot: usize,
) -> String {
    match worker_addr(state, slot) {
        Some(addr) => {
            let params = shard_status_params(session, epoch, sid);
            let resp = call_worker(state, &addr, "status", &params, POLL_RPC_TIMEOUT);
            shard_status_of(state, slot, resp)
        }
        None => "unreachable: worker dead".into(),
    }
}

/// `status {session}` — aggregate shard statuses from the workers
/// (polled concurrently so one stuck worker costs one timeout, not N).
fn status(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let session_id = resolve_session_param(state, params)?;
    let sess = get_session(state, &session_id)?;
    // passive view: no rebalance here — status must never mutate the
    // cluster (a query will catch the layout up when it runs)
    let (epoch, specs): (u64, Vec<(usize, u64, usize, usize)>) = {
        let s = lock_recover(&sess);
        (
            s.epoch,
            s.shards
                .iter()
                .enumerate()
                .map(|(i, sh)| (i, sh.sid, sh.worker, sh.indices.len()))
                .collect(),
        )
    };
    // multiplexed polls ride the shared per-worker connection as parked
    // completion slots (no thread per shard); only classic peers get the
    // pre-mux one-thread-per-poll treatment
    let mut statuses: Vec<Option<String>> = specs.iter().map(|_| None).collect();
    let mut pending: Vec<(usize, pool::PendingCall)> = Vec::new();
    let mut fallback: Vec<usize> = Vec::new();
    for (i, &(_, sid, slot, _)) in specs.iter().enumerate() {
        let started = worker_addr(state, slot).and_then(|addr| {
            if state.pool.peer_muxes(&addr) == Some(false) {
                return None;
            }
            let params = shard_status_params(&session_id, epoch, sid);
            state.pool.start(&addr, "status", &params, Some(POLL_RPC_TIMEOUT)).ok().flatten()
        });
        match started {
            Some(call) => pending.push((i, call)),
            None => fallback.push(i),
        }
    }
    if !fallback.is_empty() {
        let classic: Vec<String> = std::thread::scope(|sc| {
            let handles: Vec<_> = fallback
                .iter()
                .map(|&i| {
                    let (_, sid, slot, _) = specs[i];
                    let session = session_id.as_str();
                    sc.spawn(move || poll_shard_status(state, session, epoch, sid, slot))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| "unknown: poll panicked".into()))
                .collect()
        });
        for (&i, st) in fallback.iter().zip(classic) {
            statuses[i] = Some(st);
        }
    }
    for (i, call) in pending {
        let slot = specs[i].2;
        statuses[i] = Some(shard_status_of(state, slot, state.pool.wait(call)));
    }
    let statuses: Vec<String> =
        statuses.into_iter().map(|s| s.unwrap_or_else(|| "unknown".into())).collect();
    let mut shard_statuses = Vec::new();
    let mut processing = 0usize;
    let mut failed = 0usize;
    let mut unreachable = 0usize;
    for ((shard, _, _, size), st) in specs.iter().zip(statuses) {
        if st == "processing" {
            processing += 1;
        } else if st.starts_with("failed") {
            failed += 1;
        } else if st.starts_with("unreachable") || st.starts_with("needs-redispatch") {
            unreachable += 1;
        }
        let mut sm = Map::new();
        sm.insert("shard", Value::from(*shard));
        sm.insert("pool_samples", Value::from(*size));
        sm.insert("status", Value::from(st));
        shard_statuses.push(Value::Object(sm));
    }
    let overall = if failed > 0 {
        "failed: one or more shards failed".to_string()
    } else if processing > 0 {
        "processing".to_string()
    } else if unreachable > 0 {
        // a query would re-dispatch; report degraded rather than lying
        format!("degraded: {unreachable} shard(s) need re-dispatch")
    } else {
        "ready".to_string()
    };
    let mut m = Map::new();
    m.insert("status", Value::from(overall));
    m.insert("shards", Value::Array(shard_statuses));
    Ok(Value::Object(m))
}

/// Aggregate data-cache statistics across live workers (polled
/// concurrently, like `status`).
fn cache_stats(state: &Arc<CoordState>) -> Result<Value, String> {
    let slots = live_slots(state);
    let fold = |slot: usize, resp: Result<Body, RpcError>| match resp {
        Ok(v) => Some(v.value),
        Err(_) => {
            mark_dead(state, slot);
            None
        }
    };
    // mux-capable workers are polled as parked completion slots on the
    // shared connection; classic peers keep the one-thread-per-poll path
    let mut replies: Vec<Option<Value>> = slots.iter().map(|_| None).collect();
    let mut pending: Vec<(usize, usize, pool::PendingCall)> = Vec::new();
    let mut fallback: Vec<usize> = Vec::new();
    for (i, (slot, addr)) in slots.iter().enumerate() {
        let started = if state.pool.peer_muxes(addr) == Some(false) {
            None
        } else {
            let params = Payload::json(Value::Null);
            state.pool.start(addr, "cache_stats", &params, Some(POLL_RPC_TIMEOUT)).ok().flatten()
        };
        match started {
            Some(call) => pending.push((i, *slot, call)),
            None => fallback.push(i),
        }
    }
    if !fallback.is_empty() {
        let classic: Vec<Option<Value>> = std::thread::scope(|sc| {
            let fold = &fold;
            let handles: Vec<_> = fallback
                .iter()
                .map(|&i| {
                    let (slot, addr) = (slots[i].0, slots[i].1.as_str());
                    sc.spawn(move || {
                        let params = Payload::json(Value::Null);
                        fold(slot, call_worker(state, addr, "cache_stats", &params, POLL_RPC_TIMEOUT))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        // don't silently fold a crashed poll thread into
                        // "no stats" without a trace of it
                        crate::log_warn!("cluster", "cache_stats poll thread panicked");
                        None
                    })
                })
                .collect()
        });
        for (&i, v) in fallback.iter().zip(classic) {
            replies[i] = v;
        }
    }
    for (i, slot, call) in pending {
        replies[i] = fold(slot, state.pool.wait(call));
    }
    let (mut hits, mut misses, mut bytes, mut entries) = (0u64, 0u64, 0u64, 0u64);
    let (mut sessions, mut session_bytes) = (0u64, 0u64);
    for v in replies.into_iter().flatten() {
        let g = |k: &str| v.get(k).and_then(Value::as_i64).unwrap_or(0) as u64;
        hits += g("hits");
        misses += g("misses");
        bytes += g("bytes");
        entries += g("entries");
        sessions += g("sessions");
        session_bytes += g("session_bytes");
    }
    let mut m = Map::new();
    m.insert("hits", Value::from(hits));
    m.insert("misses", Value::from(misses));
    m.insert("bytes", Value::from(bytes));
    m.insert("entries", Value::from(entries));
    // resident shard-session footprint across workers: lets a caller
    // verify that `session_close` actually freed worker memory
    m.insert("sessions", Value::from(sessions));
    m.insert("session_bytes", Value::from(session_bytes));
    Ok(Value::Object(m))
}

/// `cluster_status` — worker membership + session shard assignments.
fn cluster_status(state: &Arc<CoordState>) -> Value {
    let workers: Vec<Value> = state
        .workers
        .lock()
        .unwrap()
        .iter()
        .map(|w| {
            let mut m = Map::new();
            m.insert("addr", Value::from(w.addr.clone()));
            m.insert("alive", Value::Bool(w.alive));
            Value::Object(m)
        })
        .collect();
    let sessions: Vec<Value> = state
        .sessions
        .lock()
        .unwrap()
        .iter()
        .map(|(name, sess)| {
            let s = lock_recover(&sess);
            let mut m = Map::new();
            m.insert("session", Value::from(name.clone()));
            m.insert("pool_samples", Value::from(s.manifest.pool.len()));
            m.insert(
                "shards",
                Value::Array(
                    s.shards
                        .iter()
                        .map(|sh| {
                            let mut sm = Map::new();
                            sm.insert("worker", Value::from(sh.worker));
                            sm.insert("pool_samples", Value::from(sh.indices.len()));
                            sm.insert("sid", Value::from(sh.sid));
                            Value::Object(sm)
                        })
                        .collect(),
                ),
            );
            m.insert("view_generation", Value::from(s.view_gen));
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("workers", Value::Array(workers));
    m.insert("sessions", Value::Array(sessions));
    m.insert("shard_policy", Value::from(state.config.cluster.shard_policy.as_str()));
    let mut mm = Map::new();
    mm.insert("enabled", Value::Bool(state.config.cluster.membership.enabled));
    if state.config.cluster.membership.enabled {
        let mem = state.membership.lock().unwrap();
        mm.insert("generation", Value::from(mem.generation()));
        mm.insert("live", Value::from(mem.len()));
    }
    m.insert("membership", Value::Object(mm));
    Value::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A holder that panics while mutating under the session lock must
    /// not brick the session: later lockers recover the inner state.
    #[test]
    fn lock_recover_survives_a_poisoned_session_lock() {
        let m = Arc::new(Mutex::new(vec![1u64, 2, 3]));
        let m2 = m.clone();
        let _ = std::panic::catch_unwind(move || {
            let mut g = m2.lock().unwrap();
            g.push(4);
            panic!("scatter thread died mid-update");
        });
        assert!(m.lock().is_err(), "the panic above must have poisoned the lock");
        {
            let mut g = lock_recover(&m);
            assert_eq!(*g, vec![1, 2, 3, 4], "inner state survives the poisoning");
            g.push(5);
        }
        // and the lock stays usable on every later acquisition
        assert_eq!(*lock_recover(&m), vec![1, 2, 3, 4, 5]);
    }
}

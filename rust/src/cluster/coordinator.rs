//! Cluster coordinator: one `AlClient`-compatible endpoint that scales a
//! session across N workers (DESIGN.md §Cluster).
//!
//! The coordinator accepts the unchanged client API (`push_data`,
//! `query`, `status`, `metrics`, ...) plus `register` for dynamic worker
//! membership. On `push_data` it shards the manifest's pool across the
//! live workers (each worker also receives the full init split so every
//! replica fine-tunes the identical head) and scatters `scan_shard`
//! calls; each worker then pipelines its own shard concurrently. On
//! `query` it scatters `select_shard`, re-dispatching a dead worker's
//! shard to a survivor, and merges:
//!
//! * exact top-k for the uncertainty strategies,
//! * coordinator-side sampling for `random`,
//! * a candidate-then-refine pass (oversampled, embedding-carrying
//!   candidates; full KCG/Core-Set/DBAL over the union) for the
//!   diversity/hybrid strategies.
//!
//! Per-shard scan timings land in `cluster.shard{i}.scan` and the
//! max-minus-min spread in the `cluster.scan.straggler_ms` gauge.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::AlaasConfig;
use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::runtime::backend::ComputeBackend;
use crate::server::rpc::{self, RpcError};
use crate::server::server::{parse_init_labels, str_param};
use crate::server::wire::{self, Payload, WireMode};
use crate::server::SELECT_SEED;
use crate::store::{Manifest, SampleRef};
use crate::strategies::{self, SelectCtx};
use crate::util::mat::Mat;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

use super::merge::{self, Candidate, MergeKind};
use super::shard;

/// Coordinator dependencies. The backend only runs the refine pass over
/// candidate unions (tiny next to a pool scan), so the host backend is a
/// fine default even when workers serve PJRT.
pub struct CoordinatorDeps {
    pub backend: Arc<dyn ComputeBackend>,
    pub metrics: Arc<Registry>,
}

struct WorkerSlot {
    addr: String,
    alive: bool,
}

/// One shard of a cluster session: which global pool positions it covers
/// and which worker slot currently owns it.
struct ShardState {
    indices: Vec<usize>,
    worker: usize,
}

struct ClusterSession {
    manifest: Manifest,
    /// Kept verbatim for shard re-dispatch after a worker death.
    init_labels: Option<Vec<u8>>,
    /// Push epoch baked into the worker-side shard session ids, so a
    /// re-pushed session never collides with (or reads through) shard
    /// data from an earlier push.
    epoch: u64,
    shards: Vec<ShardState>,
    /// Labeled-set embeddings, fetched once from a worker for the refine
    /// protocol.
    init_emb: Option<Mat>,
}

struct CoordState {
    config: AlaasConfig,
    deps: CoordinatorDeps,
    workers: Mutex<Vec<WorkerSlot>>,
    sessions: Mutex<HashMap<String, Arc<Mutex<ClusterSession>>>>,
    /// Monotonic push counter feeding `ClusterSession::epoch`.
    push_epoch: std::sync::atomic::AtomicU64,
    /// Negotiated wire encoding per worker address (DESIGN.md §Wire):
    /// absent = optimistic binary; `Json` after a peer refused or garbled
    /// a v2 frame. Cleared when the address (re-)registers.
    wire_modes: Mutex<HashMap<String, WireMode>>,
    shutdown: AtomicBool,
}

/// A running cluster coordinator.
pub struct Coordinator {
    addr: SocketAddr,
    state: Arc<CoordState>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Bind `config.al_worker.host:port` (0 = ephemeral) and start
    /// serving. Workers listed under `[cluster]` are pre-registered;
    /// more can join via the `register` RPC.
    pub fn start(config: AlaasConfig, deps: CoordinatorDeps) -> std::io::Result<Coordinator> {
        let listener =
            TcpListener::bind((config.al_worker.host.as_str(), config.al_worker.port))?;
        let addr = listener.local_addr()?;
        let workers = config
            .cluster
            .workers
            .iter()
            .map(|a| WorkerSlot { addr: a.clone(), alive: true })
            .collect();
        let state = Arc::new(CoordState {
            config,
            deps,
            workers: Mutex::new(workers),
            sessions: Mutex::new(HashMap::new()),
            push_epoch: std::sync::atomic::AtomicU64::new(0),
            wire_modes: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
        });
        let accept_state = state.clone();
        let accept_thread = std::thread::Builder::new()
            .name("alaas-coord-accept".into())
            .spawn(move || accept_loop(listener, accept_state))?;
        crate::log_info!("cluster", "coordinator listening on {addr}");
        Ok(Coordinator { addr, state, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of currently-live registered workers.
    pub fn live_workers(&self) -> usize {
        self.state.workers.lock().unwrap().iter().filter(|w| w.alive).count()
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.state.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<CoordState>) {
    let pool = ThreadPool::new("alaas-coord-conn", 16, 64);
    for conn in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let state = state.clone();
                pool.execute(move || handle_conn(stream, state));
            }
            Err(e) => {
                crate::log_warn!("cluster", "accept error: {e}");
            }
        }
    }
    pool.shutdown();
}

fn handle_conn(mut stream: TcpStream, state: Arc<CoordState>) {
    rpc::serve_conn(
        &mut stream,
        "cluster",
        &state.shutdown,
        &state.deps.metrics,
        state.config.server.wire,
        |method, params, _mode| dispatch(&state, method, params),
    );
}

fn dispatch(
    state: &Arc<CoordState>,
    method: &str,
    params: &Payload,
) -> Result<Payload, String> {
    match method {
        "hello" => Ok(Payload::json(wire::hello_reply(
            &params.value,
            state.config.server.wire,
        ))),
        "ping" => Ok(Payload::json(Value::from("pong"))),
        "register" => register(state, &params.value).map(Payload::json),
        "push_data" => push_data(state, params).map(Payload::json),
        "status" => status(state, &params.value).map(Payload::json),
        "query" => query(state, &params.value).map(Payload::json),
        "metrics" => Ok(Payload::json(state.deps.metrics.snapshot())),
        "strategies" => Ok(Payload::json(Value::Array(
            strategies::zoo_names().into_iter().map(Value::from).collect(),
        ))),
        "cache_stats" => cache_stats(state).map(Payload::json),
        "cluster_status" => Ok(Payload::json(cluster_status(state))),
        other => Err(format!("unknown method '{other}'")),
    }
}


/// RPCs that answer promptly (`scan_shard` registers the session and
/// returns; processing is backgrounded).
const FAST_RPC_TIMEOUT: Duration = Duration::from_secs(30);
/// Monitoring polls (`status`, `cache_stats`) must never hang the
/// coordinator on one stuck worker.
const POLL_RPC_TIMEOUT: Duration = Duration::from_secs(10);

/// Read deadline for a `select_shard` call: the worker may legitimately
/// block for the client-requested `wait_ms` while its scan finishes, so
/// the transport deadline must exceed it or a slow scan would cascade
/// into mark-dead + re-dispatch on every worker in turn.
fn select_rpc_timeout(wait_ms: u64) -> Duration {
    Duration::from_millis(wait_ms) + Duration::from_secs(60)
}

/// One blocking RPC to a worker over a fresh connection, in `mode`.
fn call_worker_once(
    state: &CoordState,
    addr: &str,
    method: &str,
    params: &Payload,
    read_timeout: Duration,
    mode: WireMode,
) -> Result<Payload, RpcError> {
    let sock = addr
        .to_socket_addrs()
        .ok()
        .and_then(|mut it| it.next())
        .ok_or_else(|| RpcError::Malformed(format!("bad worker addr '{addr}'")))?;
    let mut stream = TcpStream::connect_timeout(&sock, Duration::from_secs(2))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(read_timeout)).ok();
    let metrics = Some(state.deps.metrics.as_ref());
    rpc::send_request_wire(&mut stream, 1, method, params, mode, metrics)?;
    rpc::recv_response_wire(&mut stream, 1, metrics)
}

/// Does this failure look like "the peer cannot speak the binary wire"
/// rather than a dead worker or an application error? `Some(true)` means
/// the peer said so explicitly (`ERR_BINARY_DISABLED` from a JSON-forced
/// v2 server) — safe to cache the downgrade. `Some(false)` means the
/// transport died the way a pre-v2 peer garbling a v2 frame would
/// (`Closed`/`Malformed`) — worth one JSON retry, but NOT a cached
/// downgrade, since a transient connection drop looks identical and must
/// not strand a healthy binary worker on the slow path.
fn wire_refusal(e: &RpcError) -> Option<bool> {
    match e {
        RpcError::Remote(msg) if msg.contains(wire::ERR_BINARY_DISABLED) => Some(true),
        RpcError::Closed | RpcError::Malformed(_) => Some(false),
        _ => None,
    }
}

/// Record that `addr` speaks JSON only (until it re-`register`s).
fn cache_json_downgrade(state: &CoordState, addr: &str) {
    state
        .deps
        .metrics
        .counter("wire.json_fallbacks")
        .fetch_add(1, Ordering::Relaxed);
    state
        .wire_modes
        .lock()
        .unwrap()
        .insert(addr.to_string(), WireMode::Json);
}

/// One v1 `hello` round trip asking `addr` for the binary wire.
/// `Some(true)` = peer agreed; `Some(false)` = peer answered but cannot
/// or will not speak v2 (including pre-v2 "unknown method"); `None` =
/// transport failure, nothing learned — stay optimistic rather than
/// stranding a flaky-but-binary worker on the slow path.
fn probe_binary(state: &CoordState, addr: &str) -> Option<bool> {
    let mut p = Map::new();
    p.insert("wire", Value::from(WireMode::Binary.as_str()));
    p.insert("version", Value::from(wire::WIRE_VERSION as u64));
    let params = Payload::json(Value::Object(p));
    match call_worker_once(state, addr, "hello", &params, POLL_RPC_TIMEOUT, WireMode::Json) {
        Ok(r) => Some(r.value.get("wire").and_then(Value::as_str) == Some("binary")),
        Err(RpcError::Remote(_)) => Some(false),
        Err(_) => None,
    }
}

/// One blocking RPC to a worker: optimistic binary (unless this process
/// is configured `wire = "json"` or the address is cached as JSON-only),
/// with a one-shot JSON retry when the peer refuses the v2 frame; the
/// address is downgraded to JSON-only on an explicit refusal, or when a
/// follow-up `hello` probe confirms the peer cannot speak v2.
fn call_worker(
    state: &CoordState,
    addr: &str,
    method: &str,
    params: &Payload,
    read_timeout: Duration,
) -> Result<Payload, RpcError> {
    let mode = if state.config.server.wire == WireMode::Json {
        WireMode::Json
    } else {
        *state
            .wire_modes
            .lock()
            .unwrap()
            .get(addr)
            .unwrap_or(&WireMode::Binary)
    };
    match call_worker_once(state, addr, method, params, read_timeout, mode) {
        Err(e) if mode == WireMode::Binary => match wire_refusal(&e) {
            Some(cache_downgrade) => {
                crate::log_debug!(
                    "cluster",
                    "worker {addr} refused binary wire ({e}); retrying as JSON"
                );
                let retry = call_worker_once(
                    state,
                    addr,
                    method,
                    params,
                    read_timeout,
                    WireMode::Json,
                );
                if retry.is_ok() {
                    if cache_downgrade {
                        // explicit refusal: downgrade sticks immediately
                        cache_json_downgrade(state, addr);
                    } else {
                        // ambiguous (Closed/Malformed): a pre-v2 peer and
                        // a transient drop look identical from the failed
                        // call alone. One cheap hello probe decides, so a
                        // pre-v2 worker doesn't pay a doubled bulk send on
                        // every future RPC and a healthy binary worker
                        // isn't stranded on the slow path.
                        state
                            .deps
                            .metrics
                            .counter("wire.json_retries")
                            .fetch_add(1, Ordering::Relaxed);
                        if probe_binary(state, addr) == Some(false) {
                            cache_json_downgrade(state, addr);
                        }
                    }
                }
                retry
            }
            None => Err(e),
        },
        other => other,
    }
}

/// Snapshot of live worker slots as (slot index, addr).
fn live_slots(state: &CoordState) -> Vec<(usize, String)> {
    state
        .workers
        .lock()
        .unwrap()
        .iter()
        .enumerate()
        .filter(|(_, w)| w.alive)
        .map(|(i, w)| (i, w.addr.clone()))
        .collect()
}

fn worker_addr(state: &CoordState, slot: usize) -> Option<String> {
    let ws = state.workers.lock().unwrap();
    ws.get(slot).filter(|w| w.alive).map(|w| w.addr.clone())
}

fn mark_dead(state: &CoordState, slot: usize) {
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.get_mut(slot) {
        if w.alive {
            w.alive = false;
            crate::log_warn!("cluster", "worker {} ({}) marked dead", slot, w.addr);
            drop(ws);
            // count actual transitions, not every observation of a dead slot
            state
                .deps
                .metrics
                .counter("cluster.workers_dead")
                .fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// `register {addr}` — add a worker (or revive a known one).
fn register(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let addr = str_param(params, "addr")?;
    if !addr.contains(':') {
        return Err(format!("worker address '{addr}' is not host:port"));
    }
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.iter_mut().find(|w| w.addr == addr) {
        w.alive = true;
    } else {
        ws.push(WorkerSlot { addr: addr.clone(), alive: true });
    }
    let live = ws.iter().filter(|w| w.alive).count();
    drop(ws);
    // a (re)registered worker may have a new wire config; renegotiate
    state.wire_modes.lock().unwrap().remove(&addr);
    crate::log_info!("cluster", "worker {addr} registered ({live} live)");
    let mut m = Map::new();
    m.insert("workers", Value::from(live));
    Ok(Value::Object(m))
}

fn shard_session_id(session: &str, epoch: u64, shard: usize) -> String {
    format!("{session}@e{epoch}#shard{shard}")
}

/// Sub-manifest for one shard: the full init split (every worker
/// fine-tunes the identical head) plus the shard's pool slice.
fn sub_manifest(m: &Manifest, indices: &[usize], shard_idx: usize) -> Manifest {
    Manifest {
        name: format!("{}#shard{shard_idx}", m.name),
        num_classes: m.num_classes,
        img_dim: m.img_dim,
        init: m.init.clone(),
        pool: indices.iter().map(|&i| m.pool[i].clone()).collect(),
        test: vec![],
    }
}

fn scan_shard_params(
    session: &str,
    epoch: u64,
    shard_idx: usize,
    manifest: &Manifest,
    indices: &[usize],
    init_labels: Option<&[u8]>,
) -> Payload {
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, shard_idx)));
    p.insert("shard", Value::from(shard_idx));
    p.insert("manifest", sub_manifest(manifest, indices, shard_idx).to_value());
    if let Some(l) = init_labels {
        // labels stay in the v1 integer-array form: these params are
        // built before the wire mode for the target worker is known, and
        // the JSON-fallback retry of this exact payload must remain
        // parseable by a pre-v2 worker (unlike AlClient, which only uses
        // the tensor form after a successful binary negotiation). Labels
        // are init-split-sized — noise next to the embedding tensors the
        // binary plane exists for.
        p.insert(
            "init_labels",
            Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect()),
        );
    }
    Payload::json(Value::Object(p))
}

/// Send one shard to a worker: the preferred slot first, then any other
/// live worker. Returns the slot that accepted it.
#[allow(clippy::too_many_arguments)]
fn dispatch_shard(
    state: &CoordState,
    session: &str,
    epoch: u64,
    shard_idx: usize,
    manifest: &Manifest,
    indices: &[usize],
    init_labels: Option<&[u8]>,
    preferred: usize,
) -> Result<usize, String> {
    let params = scan_shard_params(session, epoch, shard_idx, manifest, indices, init_labels);
    let mut last_err = String::from("no live workers");
    let mut order = vec![preferred];
    order.extend(live_slots(state).into_iter().map(|(i, _)| i).filter(|&i| i != preferred));
    for slot in order {
        let Some(addr) = worker_addr(state, slot) else { continue };
        match call_worker(state, &addr, "scan_shard", &params, FAST_RPC_TIMEOUT) {
            Ok(_) => return Ok(slot),
            // the worker is alive and rejected the push itself (bad
            // manifest, spawn failure): deterministic — retrying the
            // identical params elsewhere would only kill healthy slots
            Err(RpcError::Remote(e)) => {
                return Err(format!("shard {shard_idx}: {e}"));
            }
            Err(e) => {
                last_err = format!("worker {addr}: {e}");
                mark_dead(state, slot);
            }
        }
    }
    Err(format!("shard {shard_idx}: no live worker accepted ({last_err})"))
}

/// `push_data {session, manifest, init_labels?}` — shard + scatter.
fn push_data(state: &Arc<CoordState>, params: &Payload) -> Result<Value, String> {
    let session_id = str_param(&params.value, "session")?;
    let manifest_v = params.value.get("manifest").ok_or("missing param 'manifest'")?;
    let manifest = Manifest::from_value(manifest_v).map_err(|e| e.to_string())?;
    let init_labels = parse_init_labels(params, manifest.init.len())?;

    let live = live_slots(state);
    if live.is_empty() {
        return Err("no live workers registered".into());
    }
    let epoch = state.push_epoch.fetch_add(1, Ordering::Relaxed);
    let plan =
        shard::plan(manifest.pool.len(), live.len(), state.config.cluster.shard_policy);

    // Scatter every non-empty shard concurrently; a refused shard walks
    // the remaining live workers before giving up.
    let jobs: Vec<(usize, Vec<usize>, usize)> = plan
        .shards
        .iter()
        .enumerate()
        .filter(|(_, idx)| !idx.is_empty())
        .map(|(i, idx)| (i, idx.clone(), live[i].0))
        .collect();
    let outcomes: Vec<Result<(usize, Vec<usize>, usize), String>> =
        std::thread::scope(|sc| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|job| {
                    let (shard_idx, indices, preferred) = (job.0, &job.1, job.2);
                    let (manifest, init_labels, session) =
                        (&manifest, &init_labels, session_id.as_str());
                    sc.spawn(move || {
                        dispatch_shard(
                            state,
                            session,
                            epoch,
                            shard_idx,
                            manifest,
                            indices,
                            init_labels.as_deref(),
                            preferred,
                        )
                        .map(|slot| (shard_idx, indices.clone(), slot))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err("dispatch panicked".into())))
                .collect()
        });

    let mut ok = Vec::new();
    let mut first_err = None;
    for o in outcomes {
        match o {
            Ok(x) => ok.push(x),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    if let Some(e) = first_err {
        // don't leave half a session resident on the workers
        let accepted: Vec<(usize, usize)> =
            ok.iter().map(|(i, _, slot)| (*i, *slot)).collect();
        drop_shard_sessions(state, &session_id, epoch, &accepted);
        return Err(e);
    }
    let mut shards = Vec::new();
    for (shard_idx, indices, slot) in ok {
        debug_assert_eq!(shard_idx, shards.len());
        shards.push(ShardState { indices, worker: slot });
    }
    let n_shards = shards.len();
    let sizes: Vec<Value> =
        shards.iter().map(|s| Value::from(s.indices.len())).collect();
    let previous = state.sessions.lock().unwrap().insert(
        session_id.clone(),
        Arc::new(Mutex::new(ClusterSession {
            manifest: manifest.clone(),
            init_labels,
            epoch,
            shards,
            init_emb: None,
        })),
    );
    let replaced = previous.is_some();
    if let Some(old) = previous {
        // free the old push's shard sessions; epoched ids mean they can
        // never collide with the ones this push just created
        let (old_epoch, stale): (u64, Vec<(usize, usize)>) = {
            let o = old.lock().unwrap();
            (
                o.epoch,
                o.shards.iter().enumerate().map(|(i, s)| (i, s.worker)).collect(),
            )
        };
        drop_shard_sessions(state, &session_id, old_epoch, &stale);
    }
    state.deps.metrics.meter("cluster.pushed_samples").add(manifest.pool.len() as u64);

    let mut m = Map::new();
    m.insert("session", Value::from(session_id));
    m.insert("pool_samples", Value::from(manifest.pool.len()));
    m.insert("shards", Value::Array(sizes));
    m.insert("workers", Value::from(n_shards));
    m.insert("replaced", Value::Bool(replaced));
    Ok(Value::Object(m))
}

/// Best-effort `drop_session` for `(shard id, worker slot)` pairs —
/// cleanup after a partial push failure or a session re-push, so scanned
/// shards don't accumulate in worker memory. Errors are ignored: a dead
/// worker frees the memory on its own.
fn drop_shard_sessions(
    state: &CoordState,
    session: &str,
    epoch: u64,
    pairs: &[(usize, usize)],
) {
    for &(shard_idx, slot) in pairs {
        let Some(addr) = worker_addr(state, slot) else { continue };
        let mut p = Map::new();
        p.insert("session", Value::from(shard_session_id(session, epoch, shard_idx)));
        let params = Payload::json(Value::Object(p));
        if call_worker(state, &addr, "drop_session", &params, POLL_RPC_TIMEOUT).is_err() {
            crate::log_debug!(
                "cluster",
                "drop_session for shard {shard_idx} on {addr} failed (ignored)"
            );
        }
    }
}

fn get_session(
    state: &CoordState,
    id: &str,
) -> Result<Arc<Mutex<ClusterSession>>, String> {
    state
        .sessions
        .lock()
        .unwrap()
        .get(id)
        .cloned()
        .ok_or_else(|| format!("unknown session '{id}'"))
}

/// What one shard's `select_shard` returned (indices already global).
struct ShardReply {
    shard: usize,
    candidates: Vec<Candidate>,
    failed_global: Vec<usize>,
    scan_ms: f64,
    init_emb: Option<Mat>,
    /// Slot that finally served the shard (differs from the assignment
    /// after a re-dispatch).
    worker: usize,
}

struct ShardJob {
    shard: usize,
    indices: Vec<usize>,
    worker: usize,
    budget: usize,
    with_embeddings: bool,
    with_init_emb: bool,
}

/// Run `select_shard` for one shard, re-dispatching to a survivor (fresh
/// `scan_shard` + `select_shard`) when the owning worker is unreachable.
#[allow(clippy::too_many_arguments)]
fn select_on_shard(
    state: &CoordState,
    session: &str,
    epoch: u64,
    job: &ShardJob,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    strategy: &str,
    wait_ms: u64,
) -> Result<ShardReply, String> {
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, job.shard)));
    p.insert("budget", Value::from(job.budget));
    if job.budget > 0 {
        p.insert("strategy", Value::from(strategy));
    }
    p.insert("with_embeddings", Value::Bool(job.with_embeddings));
    p.insert("with_init_emb", Value::Bool(job.with_init_emb));
    p.insert("wait_ms", Value::from(wait_ms as usize));
    let params = Payload::json(Value::Object(p));

    let mut slot = job.worker;
    let mut last_err = String::from("no live workers");
    // first attempt on the assigned worker, then walk survivors; a worker
    // that doesn't know the session (never saw the shard, or restarted)
    // gets a fresh scan_shard push before selecting.
    for _attempt in 0..=live_slots(state).len() {
        let Some(addr) = worker_addr(state, slot) else {
            match next_live_slot(state, slot) {
                Some(s) => {
                    slot = s;
                    continue;
                }
                None => break,
            }
        };
        let select_timeout = select_rpc_timeout(wait_ms);
        let resp = match call_worker(state, &addr, "select_shard", &params, select_timeout) {
            Err(RpcError::Remote(e)) if e.contains("unknown session") => {
                state
                    .deps
                    .metrics
                    .counter("cluster.shard_redispatch")
                    .fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "cluster",
                    "re-dispatching shard {} of '{session}' to {addr}",
                    job.shard
                );
                call_worker(
                    state,
                    &addr,
                    "scan_shard",
                    &scan_shard_params(
                        session,
                        epoch,
                        job.shard,
                        manifest,
                        &job.indices,
                        init_labels,
                    ),
                    FAST_RPC_TIMEOUT,
                )
                .and_then(|_| {
                    call_worker(state, &addr, "select_shard", &params, select_timeout)
                })
            }
            other => other,
        };
        match resp {
            Ok(v) => return decode_shard_reply(v, job, slot),
            Err(RpcError::Remote(e)) => {
                // the worker is alive; the request itself is bad
                return Err(format!("shard {}: {e}", job.shard));
            }
            Err(e) => {
                last_err = format!("worker {addr}: {e}");
                mark_dead(state, slot);
                match next_live_slot(state, slot) {
                    Some(s) => slot = s,
                    None => break,
                }
            }
        }
    }
    Err(format!("shard {}: no live worker served it ({last_err})", job.shard))
}

fn next_live_slot(state: &CoordState, after: usize) -> Option<usize> {
    let live = live_slots(state);
    if live.is_empty() {
        return None;
    }
    live.iter()
        .map(|(i, _)| *i)
        .find(|&i| i > after)
        .or_else(|| live.first().map(|(i, _)| *i))
}

fn decode_shard_reply(
    reply: Payload,
    job: &ShardJob,
    worker: usize,
) -> Result<ShardReply, String> {
    // consumed by value: each tensor section is used exactly once, so
    // the bulk matrices are moved out rather than cloned
    let Payload { value: v, mut tensors } = reply;
    let to_global = |local: usize| -> Result<usize, String> {
        job.indices
            .get(local)
            .copied()
            .ok_or_else(|| format!("shard {}: local index {local} out of range", job.shard))
    };
    let failed_global = v
        .get("failed")
        .and_then(Value::as_array)
        .unwrap_or(&[])
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| "bad failed index".to_string())
                .and_then(|l| to_global(l))
        })
        .collect::<Result<Vec<_>, _>>()?;
    let mut candidates = Vec::new();
    if let Some(arr) = v.get("candidates").and_then(Value::as_array) {
        // refine-protocol matrices arrive packed: one [N, 4] score and one
        // [N, D] embedding tensor whose rows parallel the slim candidate
        // list. A PR1-era worker instead embeds per-candidate float
        // arrays, which Candidate::from_value still decodes.
        let cand_scores = wire::take_mat(&v, &mut tensors, "cand_scores")?;
        let cand_emb = wire::take_mat(&v, &mut tensors, "cand_emb")?;
        for m in [&cand_scores, &cand_emb].into_iter().flatten() {
            if m.rows() != arr.len() {
                return Err(format!(
                    "shard {}: packed tensor rows {} != {} candidates",
                    job.shard,
                    m.rows(),
                    arr.len()
                ));
            }
        }
        for (i, c) in arr.iter().enumerate() {
            let mut cand = Candidate::from_value(c)?;
            cand.idx = to_global(cand.idx)?;
            if let Some(m) = &cand_scores {
                cand.scores = m.row(i).to_vec();
            }
            if let Some(m) = &cand_emb {
                cand.emb = m.row(i).to_vec();
            }
            candidates.push(cand);
        }
    }
    let init_emb = wire::take_mat(&v, &mut tensors, "init_emb")?;
    Ok(ShardReply {
        shard: job.shard,
        candidates,
        failed_global,
        scan_ms: v.get("scan_ms").and_then(Value::as_f64).unwrap_or(0.0),
        init_emb,
        worker,
    })
}

/// `query {session, budget, strategy?, wait_ms?}` — scatter, merge,
/// respond in the exact shape of the single-server `query`.
fn query(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let session_id = str_param(params, "session")?;
    let budget =
        params.get("budget").and_then(Value::as_usize).ok_or("missing usize param 'budget'")?;
    let strategy_name = match params.get("strategy").and_then(Value::as_str) {
        Some(s) => s.to_string(),
        None => state.config.active_learning.strategy.as_str().to_string(),
    };
    if strategy_name == "auto" {
        return Err(
            "strategy 'auto' requires the agent workflow (CLI `alaas agent`): the PSHEA \
             loop needs per-round oracle labels, which the one-shot query protocol does \
             not carry"
                .into(),
        );
    }
    let kind = merge::merge_kind(&strategy_name)
        .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
    let wait_ms =
        params.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;

    let sess = get_session(state, &session_id)?;
    let (manifest, init_labels, epoch, shard_specs, have_init_emb) = {
        let s = sess.lock().unwrap();
        let specs: Vec<(usize, Vec<usize>, usize)> = s
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| (i, sh.indices.clone(), sh.worker))
            .collect();
        (
            s.manifest.clone(),
            s.init_labels.clone(),
            s.epoch,
            specs,
            s.init_emb.is_some(),
        )
    };
    let n_shards = shard_specs.iter().filter(|(_, idx, _)| !idx.is_empty()).count().max(1);

    // per-shard candidate budget by merge protocol
    let oversample = state.config.cluster.oversample_factor;
    let (local_budget, with_embeddings) = match kind {
        MergeKind::ExactTopK { .. } => (budget, false),
        MergeKind::Refine => ((oversample * budget).div_ceil(n_shards).max(1), true),
        MergeKind::Random => (0, false),
    };
    let need_init_emb = matches!(kind, MergeKind::Refine)
        && !have_init_emb
        && !manifest.init.is_empty();

    let jobs: Vec<ShardJob> = shard_specs
        .into_iter()
        .filter(|(_, idx, _)| !idx.is_empty())
        .enumerate()
        .map(|(pos, (shard, indices, worker))| ShardJob {
            shard,
            indices,
            worker,
            budget: local_budget,
            with_embeddings,
            with_init_emb: need_init_emb && pos == 0,
        })
        .collect();

    let t_query = Instant::now();
    let replies: Vec<Result<ShardReply, String>> = std::thread::scope(|sc| {
        let handles: Vec<_> = jobs
            .iter()
            .map(|job| {
                let (manifest, init_labels, session, strategy) = (
                    &manifest,
                    &init_labels,
                    session_id.as_str(),
                    strategy_name.as_str(),
                );
                sc.spawn(move || {
                    select_on_shard(
                        state,
                        session,
                        epoch,
                        job,
                        manifest,
                        init_labels.as_deref(),
                        strategy,
                        wait_ms,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("shard query panicked".into())))
            .collect()
    });
    let mut shard_replies = Vec::with_capacity(replies.len());
    for r in replies {
        shard_replies.push(r?);
    }

    // bookkeeping: re-dispatched assignments, fetched init embeddings,
    // per-shard scan metrics + straggler spread
    {
        let mut s = sess.lock().unwrap();
        for r in &shard_replies {
            s.shards[r.shard].worker = r.worker;
            if let Some(m) = &r.init_emb {
                if s.init_emb.is_none() {
                    s.init_emb = Some(m.clone());
                }
            }
        }
    }
    let mut scan_min = f64::INFINITY;
    let mut scan_max: f64 = 0.0;
    for r in &shard_replies {
        let d = Duration::from_secs_f64((r.scan_ms / 1e3).max(0.0));
        state.deps.metrics.time("cluster.shard_scan", d);
        state.deps.metrics.time(&format!("cluster.shard{}.scan", r.shard), d);
        scan_min = scan_min.min(r.scan_ms);
        scan_max = scan_max.max(r.scan_ms);
    }
    if !shard_replies.is_empty() {
        let straggler_ms = (scan_max - scan_min).max(0.0) as u64;
        state
            .deps
            .metrics
            .counter("cluster.scan.straggler_ms")
            .store(straggler_ms, Ordering::Relaxed);
    }

    // merge
    let t0 = Instant::now();
    let picked_global: Vec<usize> = match kind {
        MergeKind::ExactTopK { ascending, .. } => {
            let cands: Vec<(usize, f32)> = shard_replies
                .iter()
                .flat_map(|r| r.candidates.iter().map(|c| (c.idx, c.score)))
                .collect();
            merge::merge_exact_topk(&cands, budget.min(cands.len()), ascending)
        }
        MergeKind::Random => {
            let mut failed = vec![false; manifest.pool.len()];
            for r in &shard_replies {
                for &g in &r.failed_global {
                    failed[g] = true;
                }
            }
            let ok_rows: Vec<usize> =
                (0..manifest.pool.len()).filter(|&i| !failed[i]).collect();
            let mut rng = Rng::new(SELECT_SEED);
            rng.sample_indices(ok_rows.len(), budget.min(ok_rows.len()))
                .into_iter()
                .map(|rel| ok_rows[rel])
                .collect()
        }
        MergeKind::Refine => {
            let all: Vec<&Candidate> =
                shard_replies.iter().flat_map(|r| r.candidates.iter()).collect();
            if all.is_empty() {
                vec![]
            } else {
                let emb =
                    Mat::from_rows(all.iter().map(|c| c.emb.as_slice()));
                let scores =
                    Mat::from_rows(all.iter().map(|c| c.scores.as_slice()));
                let labeled = {
                    let s = sess.lock().unwrap();
                    s.init_emb.clone().unwrap_or_else(|| Mat::zeros(0, emb.cols()))
                };
                let strat = strategies::by_name(&strategy_name)
                    .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
                let ctx = SelectCtx {
                    scores: &scores,
                    embeddings: &emb,
                    labeled: &labeled,
                    backend: state.deps.backend.as_ref(),
                    seed: SELECT_SEED,
                };
                strat
                    .select(&ctx, budget)
                    .map_err(|e| e.to_string())?
                    .into_iter()
                    .map(|rel| all[rel].idx)
                    .collect()
            }
        }
    };
    let select_elapsed = t0.elapsed();
    state.deps.metrics.time("al.select", select_elapsed);
    state.deps.metrics.meter("al.selected").add(picked_global.len() as u64);
    state.deps.metrics.time("cluster.query", t_query.elapsed());

    let selected: Vec<Value> = picked_global
        .iter()
        .map(|&g| {
            let sr: &SampleRef = &manifest.pool[g];
            let mut m = Map::new();
            m.insert("id", Value::from(sr.id as u64));
            m.insert("uri", Value::from(sr.uri.clone()));
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("strategy", Value::from(strategy_name));
    m.insert("selected", Value::Array(selected));
    m.insert("select_ms", Value::Number(select_elapsed.as_secs_f64() * 1e3));
    m.insert(
        "scan_ms",
        Value::Number(if scan_max.is_finite() { scan_max } else { 0.0 }),
    );
    Ok(Value::Object(m))
}

/// Poll one shard's worker for its status string.
fn poll_shard_status(
    state: &CoordState,
    session: &str,
    epoch: u64,
    shard: usize,
    slot: usize,
) -> String {
    match worker_addr(state, slot) {
        Some(addr) => {
            let mut p = Map::new();
            p.insert("session", Value::from(shard_session_id(session, epoch, shard)));
            let params = Payload::json(Value::Object(p));
            match call_worker(state, &addr, "status", &params, POLL_RPC_TIMEOUT) {
                Ok(v) => v
                    .value
                    .get("status")
                    .and_then(Value::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                // the worker is reachable but lost the shard (e.g.
                // restart): a query will re-dispatch — do NOT kill
                // the slot over an application-level error
                Err(RpcError::Remote(e)) => format!("needs-redispatch: {e}"),
                Err(e) => {
                    mark_dead(state, slot);
                    format!("unreachable: {e}")
                }
            }
        }
        None => "unreachable: worker dead".into(),
    }
}

/// `status {session}` — aggregate shard statuses from the workers
/// (polled concurrently so one stuck worker costs one timeout, not N).
fn status(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let session_id = str_param(params, "session")?;
    let sess = get_session(state, &session_id)?;
    let (epoch, specs): (u64, Vec<(usize, usize, usize)>) = {
        let s = sess.lock().unwrap();
        (
            s.epoch,
            s.shards
                .iter()
                .enumerate()
                .map(|(i, sh)| (i, sh.worker, sh.indices.len()))
                .collect(),
        )
    };
    let statuses: Vec<String> = std::thread::scope(|sc| {
        let handles: Vec<_> = specs
            .iter()
            .map(|&(shard, slot, _)| {
                let session = session_id.as_str();
                sc.spawn(move || poll_shard_status(state, session, epoch, shard, slot))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| "unknown: poll panicked".into()))
            .collect()
    });
    let mut shard_statuses = Vec::new();
    let mut processing = 0usize;
    let mut failed = 0usize;
    let mut unreachable = 0usize;
    for ((shard, _, size), st) in specs.iter().zip(statuses) {
        if st == "processing" {
            processing += 1;
        } else if st.starts_with("failed") {
            failed += 1;
        } else if st.starts_with("unreachable") || st.starts_with("needs-redispatch") {
            unreachable += 1;
        }
        let mut sm = Map::new();
        sm.insert("shard", Value::from(*shard));
        sm.insert("pool_samples", Value::from(*size));
        sm.insert("status", Value::from(st));
        shard_statuses.push(Value::Object(sm));
    }
    let overall = if failed > 0 {
        "failed: one or more shards failed".to_string()
    } else if processing > 0 {
        "processing".to_string()
    } else if unreachable > 0 {
        // a query would re-dispatch; report degraded rather than lying
        format!("degraded: {unreachable} shard(s) need re-dispatch")
    } else {
        "ready".to_string()
    };
    let mut m = Map::new();
    m.insert("status", Value::from(overall));
    m.insert("shards", Value::Array(shard_statuses));
    Ok(Value::Object(m))
}

/// Aggregate data-cache statistics across live workers (polled
/// concurrently, like `status`).
fn cache_stats(state: &Arc<CoordState>) -> Result<Value, String> {
    let slots = live_slots(state);
    let replies: Vec<Option<Value>> = std::thread::scope(|sc| {
        let handles: Vec<_> = slots
            .iter()
            .map(|(slot, addr)| {
                let (slot, addr) = (*slot, addr.as_str());
                sc.spawn(move || {
                    let params = Payload::json(Value::Null);
                    match call_worker(state, addr, "cache_stats", &params, POLL_RPC_TIMEOUT) {
                        Ok(v) => Some(v.value),
                        Err(_) => {
                            mark_dead(state, slot);
                            None
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(None)).collect()
    });
    let (mut hits, mut misses, mut bytes, mut entries) = (0u64, 0u64, 0u64, 0u64);
    for v in replies.into_iter().flatten() {
        let g = |k: &str| v.get(k).and_then(Value::as_i64).unwrap_or(0) as u64;
        hits += g("hits");
        misses += g("misses");
        bytes += g("bytes");
        entries += g("entries");
    }
    let mut m = Map::new();
    m.insert("hits", Value::from(hits));
    m.insert("misses", Value::from(misses));
    m.insert("bytes", Value::from(bytes));
    m.insert("entries", Value::from(entries));
    Ok(Value::Object(m))
}

/// `cluster_status` — worker membership + session shard assignments.
fn cluster_status(state: &Arc<CoordState>) -> Value {
    let workers: Vec<Value> = state
        .workers
        .lock()
        .unwrap()
        .iter()
        .map(|w| {
            let mut m = Map::new();
            m.insert("addr", Value::from(w.addr.clone()));
            m.insert("alive", Value::Bool(w.alive));
            Value::Object(m)
        })
        .collect();
    let sessions: Vec<Value> = state
        .sessions
        .lock()
        .unwrap()
        .iter()
        .map(|(name, sess)| {
            let s = sess.lock().unwrap();
            let mut m = Map::new();
            m.insert("session", Value::from(name.clone()));
            m.insert("pool_samples", Value::from(s.manifest.pool.len()));
            m.insert(
                "shards",
                Value::Array(
                    s.shards
                        .iter()
                        .map(|sh| {
                            let mut sm = Map::new();
                            sm.insert("worker", Value::from(sh.worker));
                            sm.insert("pool_samples", Value::from(sh.indices.len()));
                            Value::Object(sm)
                        })
                        .collect(),
                ),
            );
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("workers", Value::Array(workers));
    m.insert("sessions", Value::Array(sessions));
    m.insert("shard_policy", Value::from(state.config.cluster.shard_policy.as_str()));
    Value::Object(m)
}

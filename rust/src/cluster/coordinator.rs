//! Cluster coordinator: one `AlClient`-compatible endpoint that scales a
//! session across N workers (DESIGN.md §Cluster).
//!
//! The coordinator accepts the unchanged client API (`push_data`,
//! `query`, `status`, `metrics`, ...) plus `register` for dynamic worker
//! membership. On `push_data` it shards the manifest's pool across the
//! live workers (each worker also receives the full init split so every
//! replica fine-tunes the identical head) and scatters `scan_shard`
//! calls; each worker then pipelines its own shard concurrently. On
//! `query` it scatters `select_shard`, re-dispatching a dead worker's
//! shard to a survivor, and merges:
//!
//! * exact top-k for the uncertainty strategies,
//! * coordinator-side sampling for `random`,
//! * a candidate-then-refine pass (oversampled, embedding-carrying
//!   candidates; full KCG/Core-Set/DBAL over the union) for the
//!   diversity/hybrid strategies.
//!
//! Per-shard scan timings land in `cluster.shard{i}.scan` and the
//! max-minus-min spread in the `cluster.scan.straggler_ms` gauge.

use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::agent::job::{self, AgentTask, ArmSelect, JobRegistry, Picked};
use crate::config::AlaasConfig;
use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::runtime::backend::ComputeBackend;
use crate::server::pool::{self, ConnPool};
use crate::server::rpc::{self, RpcError};
use crate::server::server::{parse_agent_start, parse_init_labels, str_param};
use crate::server::wire::{self, Body, Payload};
use crate::server::SELECT_SEED;
use crate::store::{Manifest, SampleRef};
use crate::strategies::{self, SelectCtx};
use crate::trainer::LinearHead;
use crate::util::mat::Mat;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

use super::merge::{self, Candidate, MergeKind};
use super::shard;

/// Coordinator dependencies. The backend only runs the refine pass over
/// candidate unions (tiny next to a pool scan), so the host backend is a
/// fine default even when workers serve PJRT.
pub struct CoordinatorDeps {
    pub backend: Arc<dyn ComputeBackend>,
    pub metrics: Arc<Registry>,
}

struct WorkerSlot {
    addr: String,
    alive: bool,
}

/// One shard of a cluster session: which global pool positions it covers
/// and which worker slot currently owns it.
struct ShardState {
    indices: Vec<usize>,
    worker: usize,
}

struct ClusterSession {
    manifest: Manifest,
    /// Kept verbatim for shard re-dispatch after a worker death.
    init_labels: Option<Vec<u8>>,
    /// Push epoch baked into the worker-side shard session ids, so a
    /// re-pushed session never collides with (or reads through) shard
    /// data from an earlier push.
    epoch: u64,
    shards: Vec<ShardState>,
    /// Labeled-set embeddings, fetched once from a worker for the refine
    /// protocol.
    init_emb: Option<Mat>,
    /// Test-split embeddings, fetched once from a worker for agent-job
    /// evaluation (the test split is replicated to every shard).
    test_emb: Option<Mat>,
}

struct CoordState {
    config: AlaasConfig,
    deps: CoordinatorDeps,
    workers: Mutex<Vec<WorkerSlot>>,
    sessions: Mutex<HashMap<String, Arc<Mutex<ClusterSession>>>>,
    /// Monotonic push counter feeding `ClusterSession::epoch`.
    push_epoch: std::sync::atomic::AtomicU64,
    /// Persistent, per-worker negotiated connections (DESIGN.md §Wire):
    /// every worker RPC checks one out instead of dialing, so an
    /// N-shard scatter costs at most one dial per worker, not one per
    /// call. Invalidated per address on re-registration and on observed
    /// death.
    pool: ConnPool,
    /// Background PSHEA jobs fanning out over worker shards (§Agent).
    jobs: JobRegistry,
    shutdown: AtomicBool,
}

/// A running cluster coordinator.
pub struct Coordinator {
    addr: SocketAddr,
    state: Arc<CoordState>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Bind `config.al_worker.host:port` (0 = ephemeral) and start
    /// serving. Workers listed under `[cluster]` are pre-registered;
    /// more can join via the `register` RPC.
    pub fn start(config: AlaasConfig, deps: CoordinatorDeps) -> std::io::Result<Coordinator> {
        let listener =
            TcpListener::bind((config.al_worker.host.as_str(), config.al_worker.port))?;
        let addr = listener.local_addr()?;
        let workers = config
            .cluster
            .workers
            .iter()
            .map(|a| WorkerSlot { addr: a.clone(), alive: true })
            .collect();
        // worker connections: dial + negotiate once per worker, reuse
        // across every scatter (connect timeout matches the old per-call
        // dial so dead-worker detection latency is unchanged)
        let conn_pool = ConnPool::new(
            config.server.pool.clone(),
            config.server.wire,
            Some(deps.metrics.clone()),
        )
        .with_timeouts(WORKER_DIAL_TIMEOUT, POLL_RPC_TIMEOUT);
        let state = Arc::new(CoordState {
            config,
            deps,
            workers: Mutex::new(workers),
            sessions: Mutex::new(HashMap::new()),
            push_epoch: std::sync::atomic::AtomicU64::new(0),
            pool: conn_pool,
            jobs: JobRegistry::new(),
            shutdown: AtomicBool::new(false),
        });
        let accept_state = state.clone();
        let accept_thread = std::thread::Builder::new()
            .name("alaas-coord-accept".into())
            .spawn(move || accept_loop(listener, accept_state))?;
        crate::log_info!("cluster", "coordinator listening on {addr}");
        Ok(Coordinator { addr, state, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of currently-live registered workers.
    pub fn live_workers(&self) -> usize {
        self.state.workers.lock().unwrap().iter().filter(|w| w.alive).count()
    }

    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.state.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake the accept loop through the shared dialing path (the
        // pool's `dial`), not an ad-hoc `TcpStream::connect`, so liveness
        // checks and real RPCs cannot diverge
        let _ = pool::dial(&self.addr.to_string(), Duration::from_millis(500));
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<CoordState>) {
    let pool = ThreadPool::new("alaas-coord-conn", 16, 64);
    for conn in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let state = state.clone();
                pool.execute(move || handle_conn(stream, state));
            }
            Err(e) => {
                crate::log_warn!("cluster", "accept error: {e}");
            }
        }
    }
    pool.shutdown();
}

fn handle_conn(mut stream: TcpStream, state: Arc<CoordState>) {
    rpc::serve_conn(
        &mut stream,
        "cluster",
        &state.shutdown,
        &state.deps.metrics,
        state.config.server.wire,
        |method, params, _mode| dispatch(&state, method, params),
    );
}

fn dispatch(
    state: &Arc<CoordState>,
    method: &str,
    params: &Body,
) -> Result<Payload, String> {
    match method {
        "hello" => Ok(Payload::json(wire::hello_reply(
            &params.value,
            state.config.server.wire,
        ))),
        "ping" => Ok(Payload::json(Value::from("pong"))),
        "register" => register(state, &params.value).map(Payload::json),
        "push_data" => push_data(state, params).map(Payload::json),
        "status" => status(state, &params.value).map(Payload::json),
        "query" => query(state, &params.value).map(Payload::json),
        "metrics" => Ok(Payload::json(state.deps.metrics.snapshot())),
        "strategies" => Ok(Payload::json(Value::Array(
            strategies::zoo_names().into_iter().map(Value::from).collect(),
        ))),
        "cache_stats" => cache_stats(state).map(Payload::json),
        "cluster_status" => Ok(Payload::json(cluster_status(state))),
        // agent-as-a-service job family (DESIGN.md §Agent): same surface
        // as the single server, arms fan out over the worker shards
        "agent_start" => agent_start(state, params).map(Payload::json),
        "agent_status" => job::rpc_status(&state.jobs, &params.value).map(Payload::json),
        "agent_result" => job::rpc_result(&state.jobs, &params.value).map(Payload::json),
        "agent_cancel" => job::rpc_cancel(&state.jobs, &params.value).map(Payload::json),
        other => Err(format!("unknown method '{other}'")),
    }
}


/// RPCs that answer promptly (`scan_shard` registers the session and
/// returns; processing is backgrounded).
const FAST_RPC_TIMEOUT: Duration = Duration::from_secs(30);
/// Monitoring polls (`status`, `cache_stats`) must never hang the
/// coordinator on one stuck worker.
const POLL_RPC_TIMEOUT: Duration = Duration::from_secs(10);
/// Connect timeout for worker dials (the pre-pool per-call value, kept
/// so dead-worker detection latency is unchanged).
const WORKER_DIAL_TIMEOUT: Duration = Duration::from_secs(2);

/// Read deadline for a `select_shard` call: the worker may legitimately
/// block for the client-requested `wait_ms` while its scan finishes, so
/// the transport deadline must exceed it or a slow scan would cascade
/// into mark-dead + re-dispatch on every worker in turn.
fn select_rpc_timeout(wait_ms: u64) -> Duration {
    Duration::from_millis(wait_ms) + Duration::from_secs(60)
}

/// One blocking RPC to a worker over a pooled, wire-negotiated
/// connection (DESIGN.md §Wire). The pool dials + `hello`-negotiates at
/// most once per connection, reuses it across calls, evicts stale
/// sockets, and retries a dead *parked* connection once on a fresh dial —
/// so transport errors surfacing here mean the worker itself is
/// unreachable, exactly as with the old per-call dial.
fn call_worker(
    state: &CoordState,
    addr: &str,
    method: &str,
    params: &Payload,
    read_timeout: Duration,
) -> Result<Body, RpcError> {
    state.pool.call(addr, method, params, Some(read_timeout))
}

/// Snapshot of live worker slots as (slot index, addr).
fn live_slots(state: &CoordState) -> Vec<(usize, String)> {
    state
        .workers
        .lock()
        .unwrap()
        .iter()
        .enumerate()
        .filter(|(_, w)| w.alive)
        .map(|(i, w)| (i, w.addr.clone()))
        .collect()
}

fn worker_addr(state: &CoordState, slot: usize) -> Option<String> {
    let ws = state.workers.lock().unwrap();
    ws.get(slot).filter(|w| w.alive).map(|w| w.addr.clone())
}

fn mark_dead(state: &CoordState, slot: usize) {
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.get_mut(slot) {
        if w.alive {
            w.alive = false;
            let addr = w.addr.clone();
            crate::log_warn!("cluster", "worker {} ({}) marked dead", slot, addr);
            drop(ws);
            // its pooled connections are junk now; free the sockets
            state.pool.invalidate(&addr);
            // count actual transitions, not every observation of a dead slot
            state
                .deps
                .metrics
                .counter("cluster.workers_dead")
                .fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// `register {addr}` — add a worker (or revive a known one).
fn register(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let addr = str_param(params, "addr")?;
    if !addr.contains(':') {
        return Err(format!("worker address '{addr}' is not host:port"));
    }
    let mut ws = state.workers.lock().unwrap();
    if let Some(w) = ws.iter_mut().find(|w| w.addr == addr) {
        w.alive = true;
    } else {
        ws.push(WorkerSlot { addr: addr.clone(), alive: true });
    }
    let live = ws.iter().filter(|w| w.alive).count();
    drop(ws);
    // a (re)registered worker may be a new process with a new wire
    // config: drop its pooled connections so the next call re-dials and
    // re-negotiates instead of writing into a dead socket
    state.pool.invalidate(&addr);
    crate::log_info!("cluster", "worker {addr} registered ({live} live)");
    let mut m = Map::new();
    m.insert("workers", Value::from(live));
    Ok(Value::Object(m))
}

fn shard_session_id(session: &str, epoch: u64, shard: usize) -> String {
    format!("{session}@e{epoch}#shard{shard}")
}

/// Sub-manifest for one shard: the full init split (every worker
/// fine-tunes the identical head) plus the shard's pool slice. Shard 0
/// additionally carries the full test split — the agent job evaluates
/// arm accuracy on it (§Agent), and one scanned copy suffices; both
/// shard policies put pool index 0 on shard 0, so shard 0 is non-empty
/// whenever the pool is, and a re-dispatch of shard 0 re-pushes the test
/// split with it.
fn sub_manifest(m: &Manifest, indices: &[usize], shard_idx: usize) -> Manifest {
    Manifest {
        name: format!("{}#shard{shard_idx}", m.name),
        num_classes: m.num_classes,
        img_dim: m.img_dim,
        init: m.init.clone(),
        pool: indices.iter().map(|&i| m.pool[i].clone()).collect(),
        test: if shard_idx == 0 { m.test.clone() } else { vec![] },
    }
}

fn scan_shard_params(
    session: &str,
    epoch: u64,
    shard_idx: usize,
    manifest: &Manifest,
    indices: &[usize],
    init_labels: Option<&[u8]>,
) -> Payload {
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, shard_idx)));
    p.insert("shard", Value::from(shard_idx));
    p.insert("manifest", sub_manifest(manifest, indices, shard_idx).to_value());
    if let Some(l) = init_labels {
        // labels stay in the v1 integer-array form: these params are
        // built before the wire mode for the target worker is known, and
        // the JSON-fallback retry of this exact payload must remain
        // parseable by a pre-v2 worker (unlike AlClient, which only uses
        // the tensor form after a successful binary negotiation). Labels
        // are init-split-sized — noise next to the embedding tensors the
        // binary plane exists for.
        p.insert(
            "init_labels",
            Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect()),
        );
    }
    Payload::json(Value::Object(p))
}

/// Send one shard to a worker: the preferred slot first, then any other
/// live worker. Returns the slot that accepted it.
#[allow(clippy::too_many_arguments)]
fn dispatch_shard(
    state: &CoordState,
    session: &str,
    epoch: u64,
    shard_idx: usize,
    manifest: &Manifest,
    indices: &[usize],
    init_labels: Option<&[u8]>,
    preferred: usize,
) -> Result<usize, String> {
    let params = scan_shard_params(session, epoch, shard_idx, manifest, indices, init_labels);
    let mut last_err = String::from("no live workers");
    let mut order = vec![preferred];
    order.extend(live_slots(state).into_iter().map(|(i, _)| i).filter(|&i| i != preferred));
    for slot in order {
        let Some(addr) = worker_addr(state, slot) else { continue };
        match call_worker(state, &addr, "scan_shard", &params, FAST_RPC_TIMEOUT) {
            Ok(_) => return Ok(slot),
            // the worker is alive and rejected the push itself (bad
            // manifest, spawn failure): deterministic — retrying the
            // identical params elsewhere would only kill healthy slots
            Err(RpcError::Remote(e)) => {
                return Err(format!("shard {shard_idx}: {e}"));
            }
            Err(e) => {
                last_err = format!("worker {addr}: {e}");
                mark_dead(state, slot);
            }
        }
    }
    Err(format!("shard {shard_idx}: no live worker accepted ({last_err})"))
}

/// `push_data {session, manifest, init_labels?}` — shard + scatter.
fn push_data(state: &Arc<CoordState>, params: &Body) -> Result<Value, String> {
    let session_id = str_param(&params.value, "session")?;
    let manifest_v = params.value.get("manifest").ok_or("missing param 'manifest'")?;
    let manifest = Manifest::from_value(manifest_v).map_err(|e| e.to_string())?;
    let init_labels = parse_init_labels(params, manifest.init.len())?;

    let live = live_slots(state);
    if live.is_empty() {
        return Err("no live workers registered".into());
    }
    let epoch = state.push_epoch.fetch_add(1, Ordering::Relaxed);
    let plan =
        shard::plan(manifest.pool.len(), live.len(), state.config.cluster.shard_policy);

    // Scatter every non-empty shard concurrently; a refused shard walks
    // the remaining live workers before giving up.
    let jobs: Vec<(usize, Vec<usize>, usize)> = plan
        .shards
        .iter()
        .enumerate()
        .filter(|(_, idx)| !idx.is_empty())
        .map(|(i, idx)| (i, idx.clone(), live[i].0))
        .collect();
    let outcomes: Vec<Result<(usize, Vec<usize>, usize), String>> =
        std::thread::scope(|sc| {
            let handles: Vec<_> = jobs
                .iter()
                .map(|job| {
                    let (shard_idx, indices, preferred) = (job.0, &job.1, job.2);
                    let (manifest, init_labels, session) =
                        (&manifest, &init_labels, session_id.as_str());
                    sc.spawn(move || {
                        dispatch_shard(
                            state,
                            session,
                            epoch,
                            shard_idx,
                            manifest,
                            indices,
                            init_labels.as_deref(),
                            preferred,
                        )
                        .map(|slot| (shard_idx, indices.clone(), slot))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err("dispatch panicked".into())))
                .collect()
        });

    let mut ok = Vec::new();
    let mut first_err = None;
    for o in outcomes {
        match o {
            Ok(x) => ok.push(x),
            Err(e) => first_err = first_err.or(Some(e)),
        }
    }
    if let Some(e) = first_err {
        // don't leave half a session resident on the workers
        let accepted: Vec<(usize, usize)> =
            ok.iter().map(|(i, _, slot)| (*i, *slot)).collect();
        drop_shard_sessions(state, &session_id, epoch, &accepted);
        return Err(e);
    }
    let mut shards = Vec::new();
    for (shard_idx, indices, slot) in ok {
        debug_assert_eq!(shard_idx, shards.len());
        shards.push(ShardState { indices, worker: slot });
    }
    let n_shards = shards.len();
    let sizes: Vec<Value> =
        shards.iter().map(|s| Value::from(s.indices.len())).collect();
    let previous = state.sessions.lock().unwrap().insert(
        session_id.clone(),
        Arc::new(Mutex::new(ClusterSession {
            manifest: manifest.clone(),
            init_labels,
            epoch,
            shards,
            init_emb: None,
            test_emb: None,
        })),
    );
    let replaced = previous.is_some();
    if let Some(old) = previous {
        // free the old push's shard sessions; epoched ids mean they can
        // never collide with the ones this push just created
        let (old_epoch, stale): (u64, Vec<(usize, usize)>) = {
            let o = old.lock().unwrap();
            (
                o.epoch,
                o.shards.iter().enumerate().map(|(i, s)| (i, s.worker)).collect(),
            )
        };
        drop_shard_sessions(state, &session_id, old_epoch, &stale);
    }
    state.deps.metrics.meter("cluster.pushed_samples").add(manifest.pool.len() as u64);

    let mut m = Map::new();
    m.insert("session", Value::from(session_id));
    m.insert("pool_samples", Value::from(manifest.pool.len()));
    m.insert("shards", Value::Array(sizes));
    m.insert("workers", Value::from(n_shards));
    m.insert("replaced", Value::Bool(replaced));
    Ok(Value::Object(m))
}

/// Best-effort `drop_session` for `(shard id, worker slot)` pairs —
/// cleanup after a partial push failure or a session re-push, so scanned
/// shards don't accumulate in worker memory. Errors are ignored: a dead
/// worker frees the memory on its own.
fn drop_shard_sessions(
    state: &CoordState,
    session: &str,
    epoch: u64,
    pairs: &[(usize, usize)],
) {
    for &(shard_idx, slot) in pairs {
        let Some(addr) = worker_addr(state, slot) else { continue };
        let mut p = Map::new();
        p.insert("session", Value::from(shard_session_id(session, epoch, shard_idx)));
        let params = Payload::json(Value::Object(p));
        if call_worker(state, &addr, "drop_session", &params, POLL_RPC_TIMEOUT).is_err() {
            crate::log_debug!(
                "cluster",
                "drop_session for shard {shard_idx} on {addr} failed (ignored)"
            );
        }
    }
}

fn get_session(
    state: &CoordState,
    id: &str,
) -> Result<Arc<Mutex<ClusterSession>>, String> {
    state
        .sessions
        .lock()
        .unwrap()
        .get(id)
        .cloned()
        .ok_or_else(|| format!("unknown session '{id}'"))
}

/// What one shard's `select_shard` returned (indices already global).
struct ShardReply {
    shard: usize,
    candidates: Vec<Candidate>,
    failed_global: Vec<usize>,
    scan_ms: f64,
    init_emb: Option<Mat>,
    test_emb: Option<Mat>,
    /// Slot that finally served the shard (differs from the assignment
    /// after a re-dispatch).
    worker: usize,
}

struct ShardJob {
    shard: usize,
    indices: Vec<usize>,
    worker: usize,
    budget: usize,
    with_embeddings: bool,
    with_init_emb: bool,
    with_test_emb: bool,
    /// Agent-path extras (§Agent): absent/empty on the plain query path.
    seed: Option<u64>,
    /// Shard-local indices the arm already labeled.
    exclude: Vec<usize>,
    /// The arm's current head (rides as tensor sections on the v2 wire).
    head: Option<LinearHead>,
    /// The arm's labeled embeddings (extra labeled context for refine).
    labeled_emb: Option<Mat>,
}

impl ShardJob {
    fn plain(
        shard: usize,
        indices: Vec<usize>,
        worker: usize,
        budget: usize,
        with_embeddings: bool,
        with_init_emb: bool,
    ) -> ShardJob {
        ShardJob {
            shard,
            indices,
            worker,
            budget,
            with_embeddings,
            with_init_emb,
            with_test_emb: false,
            seed: None,
            exclude: vec![],
            head: None,
            labeled_emb: None,
        }
    }
}

/// Call one worker-facing method for a shard, walking survivors on
/// transport failure and re-pushing the shard (`scan_shard`) on `unknown
/// session` — the shared re-dispatch skeleton for `select_shard` and
/// `fetch_rows`. Returns the reply plus the slot that finally served it.
#[allow(clippy::too_many_arguments)]
fn call_shard_redispatch(
    state: &CoordState,
    session: &str,
    epoch: u64,
    shard_idx: usize,
    indices: &[usize],
    start_slot: usize,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    method: &str,
    params: &Payload,
    read_timeout: Duration,
) -> Result<(Body, usize), String> {
    let mut slot = start_slot;
    let mut last_err = String::from("no live workers");
    // first attempt on the assigned worker, then walk survivors; a worker
    // that doesn't know the session (never saw the shard, or restarted)
    // gets a fresh scan_shard push before serving.
    for _attempt in 0..=live_slots(state).len() {
        let Some(addr) = worker_addr(state, slot) else {
            match next_live_slot(state, slot) {
                Some(s) => {
                    slot = s;
                    continue;
                }
                None => break,
            }
        };
        let resp = match call_worker(state, &addr, method, params, read_timeout) {
            Err(RpcError::Remote(e)) if e.contains("unknown session") => {
                state
                    .deps
                    .metrics
                    .counter("cluster.shard_redispatch")
                    .fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "cluster",
                    "re-dispatching shard {shard_idx} of '{session}' to {addr}"
                );
                call_worker(
                    state,
                    &addr,
                    "scan_shard",
                    &scan_shard_params(session, epoch, shard_idx, manifest, indices, init_labels),
                    FAST_RPC_TIMEOUT,
                )
                .and_then(|_| call_worker(state, &addr, method, params, read_timeout))
            }
            other => other,
        };
        match resp {
            Ok(v) => return Ok((v, slot)),
            Err(RpcError::Remote(e)) => {
                // the worker is alive; the request itself is bad
                return Err(format!("shard {shard_idx}: {e}"));
            }
            Err(e) => {
                last_err = format!("worker {addr}: {e}");
                mark_dead(state, slot);
                match next_live_slot(state, slot) {
                    Some(s) => slot = s,
                    None => break,
                }
            }
        }
    }
    Err(format!("shard {shard_idx}: no live worker served it ({last_err})"))
}

/// Run `select_shard` for one shard, re-dispatching to a survivor when
/// the owning worker is unreachable.
#[allow(clippy::too_many_arguments)]
fn select_on_shard(
    state: &CoordState,
    session: &str,
    epoch: u64,
    job: &ShardJob,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    strategy: &str,
    wait_ms: u64,
) -> Result<ShardReply, String> {
    let mut params = Payload::default();
    let mut p = Map::new();
    p.insert("session", Value::from(shard_session_id(session, epoch, job.shard)));
    p.insert("budget", Value::from(job.budget));
    if job.budget > 0 {
        p.insert("strategy", Value::from(strategy));
    }
    p.insert("with_embeddings", Value::Bool(job.with_embeddings));
    p.insert("with_init_emb", Value::Bool(job.with_init_emb));
    if job.with_test_emb {
        p.insert("with_test_emb", Value::Bool(true));
    }
    p.insert("wait_ms", Value::from(wait_ms as usize));
    if let Some(seed) = job.seed {
        p.insert("seed", Value::from(seed));
    }
    if !job.exclude.is_empty() {
        p.insert(
            "exclude",
            Value::Array(job.exclude.iter().map(|&i| Value::from(i)).collect()),
        );
    }
    if let Some(h) = &job.head {
        // tensor placeholders: raw f32 sections on the binary wire,
        // inlined {rows, cols, data} objects on a JSON retry
        p.insert("head_w", params.stash_mat(h.w.clone()));
        p.insert("head_b", params.stash_mat(Mat::from_vec(h.b.clone(), 1, h.b.len())));
    }
    if let Some(l) = &job.labeled_emb {
        p.insert("labeled_emb", params.stash_mat(l.clone()));
    }
    params.value = Value::Object(p);

    let (reply, slot) = call_shard_redispatch(
        state,
        session,
        epoch,
        job.shard,
        &job.indices,
        job.worker,
        manifest,
        init_labels,
        "select_shard",
        &params,
        select_rpc_timeout(wait_ms),
    )?;
    decode_shard_reply(reply, job, slot)
}

fn next_live_slot(state: &CoordState, after: usize) -> Option<usize> {
    let live = live_slots(state);
    if live.is_empty() {
        return None;
    }
    live.iter()
        .map(|(i, _)| *i)
        .find(|&i| i > after)
        .or_else(|| live.first().map(|(i, _)| *i))
}

fn decode_shard_reply(
    reply: Body,
    job: &ShardJob,
    worker: usize,
) -> Result<ShardReply, String> {
    // zero-copy consume (DESIGN.md §Wire): the reply's tensor sections
    // stay in the received frame buffer; candidate score/embedding rows
    // are copied exactly once, straight from that buffer into the merge
    // inputs — no intermediate Mat per section.
    let v = &reply.value;
    let to_global = |local: usize| -> Result<usize, String> {
        job.indices
            .get(local)
            .copied()
            .ok_or_else(|| format!("shard {}: local index {local} out of range", job.shard))
    };
    let failed_global = v
        .get("failed")
        .and_then(Value::as_array)
        .unwrap_or(&[])
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| "bad failed index".to_string())
                .and_then(|l| to_global(l))
        })
        .collect::<Result<Vec<_>, _>>()?;
    let mut candidates = Vec::new();
    if let Some(arr) = v.get("candidates").and_then(Value::as_array) {
        // refine-protocol matrices arrive packed: one [N, 4] score and one
        // [N, D] embedding tensor whose rows parallel the slim candidate
        // list. A PR1-era worker instead embeds per-candidate float
        // arrays, which Candidate::from_value still decodes.
        let cand_scores = reply.mat_ref("cand_scores")?;
        let cand_emb = reply.mat_ref("cand_emb")?;
        for m in [&cand_scores, &cand_emb].into_iter().flatten() {
            if m.rows() != arr.len() {
                return Err(format!(
                    "shard {}: packed tensor rows {} != {} candidates",
                    job.shard,
                    m.rows(),
                    arr.len()
                ));
            }
        }
        for (i, c) in arr.iter().enumerate() {
            let mut cand = Candidate::from_value(c)?;
            cand.idx = to_global(cand.idx)?;
            if let Some(m) = &cand_scores {
                cand.scores = m.row_vec(i);
            }
            if let Some(m) = &cand_emb {
                cand.emb = m.row_vec(i);
            }
            candidates.push(cand);
        }
    }
    let init_emb = reply.mat("init_emb")?;
    let test_emb = reply.mat("test_emb")?;
    Ok(ShardReply {
        shard: job.shard,
        candidates,
        failed_global,
        scan_ms: v.get("scan_ms").and_then(Value::as_f64).unwrap_or(0.0),
        init_emb,
        test_emb,
        worker,
    })
}

/// Scatter a set of shard jobs concurrently and absorb the bookkeeping
/// every caller needs: worker reassignment after re-dispatch, caching of
/// fetched init/test embeddings, per-shard scan metrics, and the
/// straggler gauge. Shared by `query` and the agent job's selector.
#[allow(clippy::too_many_arguments)]
fn scatter_jobs(
    state: &CoordState,
    session_id: &str,
    sess: &Arc<Mutex<ClusterSession>>,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
    epoch: u64,
    jobs: &[ShardJob],
    strategy: &str,
    wait_ms: u64,
) -> Result<Vec<ShardReply>, String> {
    let replies: Vec<Result<ShardReply, String>> = std::thread::scope(|sc| {
        let handles: Vec<_> = jobs
            .iter()
            .map(|job| {
                sc.spawn(move || {
                    select_on_shard(
                        state, session_id, epoch, job, manifest, init_labels, strategy,
                        wait_ms,
                    )
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err("shard query panicked".into())))
            .collect()
    });
    let mut out = Vec::with_capacity(replies.len());
    for r in replies {
        out.push(r?);
    }

    // bookkeeping: re-dispatched assignments + fetched embeddings
    {
        let mut s = sess.lock().unwrap();
        for r in &out {
            s.shards[r.shard].worker = r.worker;
            if let Some(m) = &r.init_emb {
                if s.init_emb.is_none() {
                    s.init_emb = Some(m.clone());
                }
            }
            if let Some(m) = &r.test_emb {
                if s.test_emb.is_none() {
                    s.test_emb = Some(m.clone());
                }
            }
        }
    }
    // per-shard scan metrics + straggler spread
    let mut scan_min = f64::INFINITY;
    let mut scan_max: f64 = 0.0;
    for r in &out {
        let d = Duration::from_secs_f64((r.scan_ms / 1e3).max(0.0));
        state.deps.metrics.time("cluster.shard_scan", d);
        state.deps.metrics.time(&format!("cluster.shard{}.scan", r.shard), d);
        scan_min = scan_min.min(r.scan_ms);
        scan_max = scan_max.max(r.scan_ms);
    }
    if !out.is_empty() {
        let straggler_ms = (scan_max - scan_min).max(0.0) as u64;
        state
            .deps
            .metrics
            .counter("cluster.scan.straggler_ms")
            .store(straggler_ms, Ordering::Relaxed);
    }
    Ok(out)
}

/// `query {session, budget, strategy?, wait_ms?}` — scatter, merge,
/// respond in the exact shape of the single-server `query`.
fn query(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let session_id = str_param(params, "session")?;
    let budget =
        params.get("budget").and_then(Value::as_usize).ok_or("missing usize param 'budget'")?;
    let strategy_name = match params.get("strategy").and_then(Value::as_str) {
        Some(s) => s.to_string(),
        None => state.config.active_learning.strategy.as_str().to_string(),
    };
    if strategy_name == "auto" {
        return Err(
            "strategy 'auto' requires the agent workflow (CLI `alaas agent`): the PSHEA \
             loop needs per-round oracle labels, which the one-shot query protocol does \
             not carry"
                .into(),
        );
    }
    let kind = merge::merge_kind(&strategy_name)
        .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
    let wait_ms =
        params.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;

    let sess = get_session(state, &session_id)?;
    let (manifest, init_labels, epoch, shard_specs, have_init_emb) = {
        let s = sess.lock().unwrap();
        let specs: Vec<(usize, Vec<usize>, usize)> = s
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| (i, sh.indices.clone(), sh.worker))
            .collect();
        (
            s.manifest.clone(),
            s.init_labels.clone(),
            s.epoch,
            specs,
            s.init_emb.is_some(),
        )
    };
    let n_shards = shard_specs.iter().filter(|(_, idx, _)| !idx.is_empty()).count().max(1);

    // per-shard candidate budget by merge protocol
    let oversample = state.config.cluster.oversample_factor;
    let (local_budget, with_embeddings) = match kind {
        MergeKind::ExactTopK { .. } => (budget, false),
        MergeKind::Refine => ((oversample * budget).div_ceil(n_shards).max(1), true),
        MergeKind::Random => (0, false),
    };
    let need_init_emb = matches!(kind, MergeKind::Refine)
        && !have_init_emb
        && !manifest.init.is_empty();

    let jobs: Vec<ShardJob> = shard_specs
        .into_iter()
        .filter(|(_, idx, _)| !idx.is_empty())
        .enumerate()
        .map(|(pos, (shard, indices, worker))| {
            ShardJob::plain(
                shard,
                indices,
                worker,
                local_budget,
                with_embeddings,
                need_init_emb && pos == 0,
            )
        })
        .collect();

    let t_query = Instant::now();
    let shard_replies = scatter_jobs(
        state,
        &session_id,
        &sess,
        &manifest,
        init_labels.as_deref(),
        epoch,
        &jobs,
        &strategy_name,
        wait_ms,
    )?;
    let scan_max = shard_replies.iter().fold(0.0f64, |a, r| a.max(r.scan_ms));

    // merge
    let t0 = Instant::now();
    let picked_global: Vec<usize> = match kind {
        MergeKind::ExactTopK { ascending, .. } => {
            let cands: Vec<(usize, f32)> = shard_replies
                .iter()
                .flat_map(|r| r.candidates.iter().map(|c| (c.idx, c.score)))
                .collect();
            merge::merge_exact_topk(&cands, budget.min(cands.len()), ascending)
        }
        MergeKind::Random => {
            let mut failed = vec![false; manifest.pool.len()];
            for r in &shard_replies {
                for &g in &r.failed_global {
                    failed[g] = true;
                }
            }
            let ok_rows: Vec<usize> =
                (0..manifest.pool.len()).filter(|&i| !failed[i]).collect();
            let mut rng = Rng::new(SELECT_SEED);
            rng.sample_indices(ok_rows.len(), budget.min(ok_rows.len()))
                .into_iter()
                .map(|rel| ok_rows[rel])
                .collect()
        }
        MergeKind::Refine => {
            let all: Vec<&Candidate> =
                shard_replies.iter().flat_map(|r| r.candidates.iter()).collect();
            if all.is_empty() {
                vec![]
            } else {
                let (scores, emb) = merge::refine_inputs(&all);
                let labeled = {
                    let s = sess.lock().unwrap();
                    s.init_emb.clone().unwrap_or_else(|| Mat::zeros(0, emb.cols()))
                };
                let strat = strategies::by_name(&strategy_name)
                    .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
                let ctx = SelectCtx {
                    scores: &scores,
                    embeddings: &emb,
                    labeled: &labeled,
                    backend: state.deps.backend.as_ref(),
                    seed: SELECT_SEED,
                };
                strat
                    .select(&ctx, budget)
                    .map_err(|e| e.to_string())?
                    .into_iter()
                    .map(|rel| all[rel].idx)
                    .collect()
            }
        }
    };
    let select_elapsed = t0.elapsed();
    state.deps.metrics.time("al.select", select_elapsed);
    state.deps.metrics.meter("al.selected").add(picked_global.len() as u64);
    state.deps.metrics.time("cluster.query", t_query.elapsed());

    let selected: Vec<Value> = picked_global
        .iter()
        .map(|&g| {
            let sr: &SampleRef = &manifest.pool[g];
            let mut m = Map::new();
            m.insert("id", Value::from(sr.id as u64));
            m.insert("uri", Value::from(sr.uri.clone()));
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("strategy", Value::from(strategy_name));
    m.insert("selected", Value::Array(selected));
    m.insert("select_ms", Value::Number(select_elapsed.as_secs_f64() * 1e3));
    m.insert("scan_ms", Value::Number(scan_max));
    Ok(Value::Object(m))
}

/// Shard-spec snapshot of a session: (shard index, global indices, worker).
type ShardSpecs = Vec<(usize, Vec<usize>, usize)>;

fn snapshot_shards(sess: &Arc<Mutex<ClusterSession>>) -> (Manifest, Option<Vec<u8>>, u64, ShardSpecs) {
    let s = sess.lock().unwrap();
    let specs: ShardSpecs = s
        .shards
        .iter()
        .enumerate()
        .map(|(i, sh)| (i, sh.indices.clone(), sh.worker))
        .collect();
    (s.manifest.clone(), s.init_labels.clone(), s.epoch, specs)
}

/// Distributed [`ArmSelect`]: one PSHEA arm's selection scattered over the
/// session's worker shards through the same `select_shard` wire the plain
/// query uses, merged per the strategy's protocol (DESIGN.md §Agent).
struct ClusterArmSelect {
    state: Arc<CoordState>,
    session_id: String,
    sess: Arc<Mutex<ClusterSession>>,
    /// Init-split embeddings (labeled-context base for the refine merge).
    init_emb: Mat,
    wait_ms: u64,
}

impl ClusterArmSelect {
    /// Build one agent-path job per non-empty shard, mapping the arm's
    /// global exclusions onto shard-local indices.
    fn jobs_for(
        specs: ShardSpecs,
        budget: usize,
        with_embeddings: bool,
        seed: u64,
        excl: &HashSet<usize>,
        head: Option<&LinearHead>,
        labeled_emb: Option<&Mat>,
    ) -> Vec<ShardJob> {
        specs
            .into_iter()
            .filter(|(_, idx, _)| !idx.is_empty())
            .map(|(shard, indices, worker)| {
                let exclude: Vec<usize> = indices
                    .iter()
                    .enumerate()
                    .filter_map(|(l, g)| excl.contains(g).then_some(l))
                    .collect();
                ShardJob {
                    shard,
                    indices,
                    worker,
                    budget,
                    with_embeddings,
                    with_init_emb: false,
                    with_test_emb: false,
                    seed: Some(seed),
                    exclude,
                    head: head.cloned(),
                    labeled_emb: labeled_emb.cloned(),
                }
            })
            .collect()
    }

    /// Fetch embeddings of specific global pool indices from their
    /// owning shards (`fetch_rows`), in `picked` order — the agent path
    /// of the coordinator-side `random` merge needs the rows it sampled.
    fn fetch_embeddings(
        &self,
        manifest: &Manifest,
        init_labels: Option<&[u8]>,
        epoch: u64,
        specs: &ShardSpecs,
        picked: &[usize],
    ) -> Result<Vec<Picked>, String> {
        if picked.is_empty() {
            return Ok(vec![]);
        }
        let mut where_of: HashMap<usize, (usize, usize)> = HashMap::new();
        for (si, (_, indices, _)) in specs.iter().enumerate() {
            for (l, g) in indices.iter().enumerate() {
                where_of.insert(*g, (si, l));
            }
        }
        let mut per_shard: HashMap<usize, Vec<(usize, usize)>> = HashMap::new();
        for &g in picked {
            let &(si, l) = where_of
                .get(&g)
                .ok_or_else(|| format!("index {g} not covered by any shard"))?;
            per_shard.entry(si).or_default().push((g, l));
        }
        let mut emb_of: HashMap<usize, Vec<f32>> = HashMap::new();
        for (si, items) in per_shard {
            let (shard_idx, indices, worker) = &specs[si];
            let mut p = Map::new();
            p.insert(
                "session",
                Value::from(shard_session_id(&self.session_id, epoch, *shard_idx)),
            );
            p.insert(
                "rows",
                Value::Array(items.iter().map(|&(_, l)| Value::from(l)).collect()),
            );
            p.insert("wait_ms", Value::from(self.wait_ms as usize));
            let params = Payload::json(Value::Object(p));
            let (reply, _slot) = call_shard_redispatch(
                &self.state,
                &self.session_id,
                epoch,
                *shard_idx,
                indices,
                *worker,
                manifest,
                init_labels,
                "fetch_rows",
                &params,
                select_rpc_timeout(self.wait_ms),
            )?;
            // zero-copy: each requested row is copied once, straight out
            // of the reply's frame buffer
            let m = reply.mat_ref("emb")?.ok_or("fetch_rows reply missing emb")?;
            if m.rows() != items.len() {
                return Err(format!(
                    "fetch_rows returned {} rows, wanted {}",
                    m.rows(),
                    items.len()
                ));
            }
            for (row, &(g, _)) in items.iter().enumerate() {
                emb_of.insert(g, m.row_vec(row));
            }
        }
        picked
            .iter()
            .map(|&g| {
                emb_of
                    .remove(&g)
                    .map(|e| (g, e))
                    .ok_or_else(|| format!("missing embedding for index {g}"))
            })
            .collect()
    }
}

impl ArmSelect for ClusterArmSelect {
    fn select_arm(
        &mut self,
        strategy: &str,
        budget: usize,
        head: &LinearHead,
        exclude: &[usize],
        arm_labeled: &Mat,
        seed: u64,
    ) -> Result<Vec<Picked>, String> {
        let kind = merge::merge_kind(strategy)
            .ok_or_else(|| format!("unknown strategy '{strategy}'"))?;
        let excl: HashSet<usize> = exclude.iter().copied().collect();
        let (manifest, init_labels, epoch, specs) = snapshot_shards(&self.sess);
        let n_shards = specs.iter().filter(|(_, idx, _)| !idx.is_empty()).count().max(1);
        match kind {
            MergeKind::ExactTopK { ascending, .. } => {
                // local top-k under the arm's head with its exclusions;
                // the union provably contains the global top-k, and the
                // shared total order makes the merge exact (§Cluster).
                // Candidates stay slim (scalars only) — the arm needs the
                // embeddings of the `budget` winners, not of every
                // shard's whole candidate list, so those are fetched
                // afterwards via fetch_rows (k× less tensor traffic).
                let jobs = Self::jobs_for(
                    specs.clone(),
                    budget,
                    false,
                    seed,
                    &excl,
                    Some(head),
                    None,
                );
                let replies = scatter_jobs(
                    &self.state,
                    &self.session_id,
                    &self.sess,
                    &manifest,
                    init_labels.as_deref(),
                    epoch,
                    &jobs,
                    strategy,
                    self.wait_ms,
                )?;
                let pairs: Vec<(usize, f32)> = replies
                    .iter()
                    .flat_map(|r| r.candidates.iter().map(|c| (c.idx, c.score)))
                    .collect();
                let picked =
                    merge::merge_exact_topk(&pairs, budget.min(pairs.len()), ascending);
                self.fetch_embeddings(&manifest, init_labels.as_deref(), epoch, &specs, &picked)
            }
            MergeKind::Random => {
                // probe for failure lists; sampling is a pure function of
                // (ok-row count, seed) — identical to the single server
                let jobs = Self::jobs_for(specs.clone(), 0, false, seed, &excl, None, None);
                let replies = scatter_jobs(
                    &self.state,
                    &self.session_id,
                    &self.sess,
                    &manifest,
                    init_labels.as_deref(),
                    epoch,
                    &jobs,
                    strategy,
                    self.wait_ms,
                )?;
                let failed: HashSet<usize> = replies
                    .iter()
                    .flat_map(|r| r.failed_global.iter().copied())
                    .collect();
                let ok: Vec<usize> = (0..manifest.pool.len())
                    .filter(|g| !failed.contains(g) && !excl.contains(g))
                    .collect();
                let mut rng = Rng::new(seed);
                let picked: Vec<usize> = rng
                    .sample_indices(ok.len(), budget.min(ok.len()))
                    .into_iter()
                    .map(|rel| ok[rel])
                    .collect();
                self.fetch_embeddings(&manifest, init_labels.as_deref(), epoch, &specs, &picked)
            }
            MergeKind::Refine => {
                let oversample = self.state.config.cluster.oversample_factor;
                let local = (oversample * budget).div_ceil(n_shards).max(1);
                let arm_ctx = (arm_labeled.rows() > 0).then_some(arm_labeled);
                let jobs =
                    Self::jobs_for(specs, local, true, seed, &excl, Some(head), arm_ctx);
                let replies = scatter_jobs(
                    &self.state,
                    &self.session_id,
                    &self.sess,
                    &manifest,
                    init_labels.as_deref(),
                    epoch,
                    &jobs,
                    strategy,
                    self.wait_ms,
                )?;
                let all: Vec<&Candidate> =
                    replies.iter().flat_map(|r| r.candidates.iter()).collect();
                if all.is_empty() {
                    return Ok(vec![]);
                }
                let (scores, emb) = merge::refine_inputs(&all);
                let labeled = if arm_labeled.rows() == 0 {
                    self.init_emb.clone()
                } else {
                    self.init_emb.vstack(arm_labeled)
                };
                let strat = strategies::by_name(strategy)
                    .ok_or_else(|| format!("unknown strategy '{strategy}'"))?;
                let ctx = SelectCtx {
                    scores: &scores,
                    embeddings: &emb,
                    labeled: &labeled,
                    backend: self.state.deps.backend.as_ref(),
                    seed,
                };
                let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
                Ok(picked
                    .into_iter()
                    .map(|rel| (all[rel].idx, all[rel].emb.clone()))
                    .collect())
            }
        }
    }
}

/// Probe every shard (waiting out scans), cache init/test embeddings on
/// the session, and return `(init_emb, test_emb, selectable_pool)` — the
/// agent job's bootstrap step on the coordinator.
fn agent_bootstrap(
    state: &Arc<CoordState>,
    session_id: &str,
    sess: &Arc<Mutex<ClusterSession>>,
    wait_ms: u64,
) -> Result<(Mat, Mat, usize), String> {
    let (manifest, init_labels, epoch, specs) = snapshot_shards(sess);
    let (have_init, have_test) = {
        let s = sess.lock().unwrap();
        (s.init_emb.is_some(), s.test_emb.is_some())
    };
    let jobs: Vec<ShardJob> = specs
        .into_iter()
        .filter(|(_, idx, _)| !idx.is_empty())
        .enumerate()
        .map(|(pos, (shard, indices, worker))| {
            // the test split lives on shard 0 only (see sub_manifest)
            let want_test = !have_test && shard == 0;
            let mut j =
                ShardJob::plain(shard, indices, worker, 0, false, !have_init && pos == 0);
            j.with_test_emb = want_test;
            j
        })
        .collect();
    let replies = scatter_jobs(
        state,
        session_id,
        sess,
        &manifest,
        init_labels.as_deref(),
        epoch,
        &jobs,
        "",
        wait_ms,
    )?;
    let failed: HashSet<usize> = replies
        .iter()
        .flat_map(|r| r.failed_global.iter().copied())
        .collect();
    let selectable = manifest.pool.len() - failed.len();
    let s = sess.lock().unwrap();
    let init_emb =
        s.init_emb.clone().ok_or("agent bootstrap did not yield init embeddings")?;
    let test_emb =
        s.test_emb.clone().ok_or("agent bootstrap did not yield test embeddings")?;
    Ok((init_emb, test_emb, selectable))
}

/// `agent_start {session, strategies, config?, seed?, pool_labels,
/// test_labels, wait_ms?}` — spawn a background PSHEA job whose arms
/// evaluate across the session's worker shards (DESIGN.md §Agent).
fn agent_start(state: &Arc<CoordState>, params: &Body) -> Result<Value, String> {
    let session_id = str_param(&params.value, "session")?;
    let sess = get_session(state, &session_id)?;
    let (manifest, init_labels) = {
        let s = sess.lock().unwrap();
        (s.manifest.clone(), s.init_labels.clone())
    };
    let p = parse_agent_start(
        params,
        state.config.active_learning.agent.to_pshea(),
        &manifest,
        init_labels.is_some(),
    )?;
    let num_classes = manifest.num_classes;
    let n_arms = p.strategies.len();
    let (job_id, job_slot) = state.jobs.create(&p.strategies);
    let bg = state.clone();
    let jid = job_id.clone();
    std::thread::Builder::new()
        .name(format!("alaas-agent-{job_id}"))
        .spawn(move || {
            let (init_emb, test_emb, selectable) =
                match agent_bootstrap(&bg, &session_id, &sess, p.wait_ms) {
                    Ok(x) => x,
                    Err(e) => {
                        job::fail(&job_slot, &bg.deps.metrics, e);
                        return;
                    }
                };
            let init_labels = match init_labels {
                Some(l) => l,
                None => {
                    job::fail(&job_slot, &bg.deps.metrics, "missing init labels".into());
                    return;
                }
            };
            let sel = ClusterArmSelect {
                state: bg.clone(),
                session_id: session_id.clone(),
                sess,
                init_emb: init_emb.clone(),
                wait_ms: p.wait_ms,
            };
            let task = AgentTask::new(
                sel,
                bg.deps.backend.clone(),
                selectable,
                init_emb,
                init_labels,
                p.pool_labels,
                test_emb,
                p.test_labels,
                num_classes,
                p.seed,
                Some(job_slot.cancel.clone()),
            );
            crate::log_info!(
                "cluster",
                "agent job {jid} started on '{session_id}' ({} arms across shards)",
                p.strategies.len()
            );
            job::drive(&job_slot, task, &p.strategies, &p.cfg, &bg.deps.metrics);
        })
        .map_err(|e| {
            // no thread will ever finish this slot: mark it failed so it
            // doesn't sit in the registry as a ghost "running" job
            state.jobs.fail_orphan(&job_id, &state.deps.metrics, &e.to_string());
            e.to_string()
        })?;

    let mut m = Map::new();
    m.insert("job", Value::from(job_id));
    m.insert("strategies", Value::from(n_arms));
    Ok(Value::Object(m))
}

/// Poll one shard's worker for its status string.
fn poll_shard_status(
    state: &CoordState,
    session: &str,
    epoch: u64,
    shard: usize,
    slot: usize,
) -> String {
    match worker_addr(state, slot) {
        Some(addr) => {
            let mut p = Map::new();
            p.insert("session", Value::from(shard_session_id(session, epoch, shard)));
            let params = Payload::json(Value::Object(p));
            match call_worker(state, &addr, "status", &params, POLL_RPC_TIMEOUT) {
                Ok(v) => v
                    .value
                    .get("status")
                    .and_then(Value::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                // the worker is reachable but lost the shard (e.g.
                // restart): a query will re-dispatch — do NOT kill
                // the slot over an application-level error
                Err(RpcError::Remote(e)) => format!("needs-redispatch: {e}"),
                Err(e) => {
                    mark_dead(state, slot);
                    format!("unreachable: {e}")
                }
            }
        }
        None => "unreachable: worker dead".into(),
    }
}

/// `status {session}` — aggregate shard statuses from the workers
/// (polled concurrently so one stuck worker costs one timeout, not N).
fn status(state: &Arc<CoordState>, params: &Value) -> Result<Value, String> {
    let session_id = str_param(params, "session")?;
    let sess = get_session(state, &session_id)?;
    let (epoch, specs): (u64, Vec<(usize, usize, usize)>) = {
        let s = sess.lock().unwrap();
        (
            s.epoch,
            s.shards
                .iter()
                .enumerate()
                .map(|(i, sh)| (i, sh.worker, sh.indices.len()))
                .collect(),
        )
    };
    let statuses: Vec<String> = std::thread::scope(|sc| {
        let handles: Vec<_> = specs
            .iter()
            .map(|&(shard, slot, _)| {
                let session = session_id.as_str();
                sc.spawn(move || poll_shard_status(state, session, epoch, shard, slot))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| "unknown: poll panicked".into()))
            .collect()
    });
    let mut shard_statuses = Vec::new();
    let mut processing = 0usize;
    let mut failed = 0usize;
    let mut unreachable = 0usize;
    for ((shard, _, size), st) in specs.iter().zip(statuses) {
        if st == "processing" {
            processing += 1;
        } else if st.starts_with("failed") {
            failed += 1;
        } else if st.starts_with("unreachable") || st.starts_with("needs-redispatch") {
            unreachable += 1;
        }
        let mut sm = Map::new();
        sm.insert("shard", Value::from(*shard));
        sm.insert("pool_samples", Value::from(*size));
        sm.insert("status", Value::from(st));
        shard_statuses.push(Value::Object(sm));
    }
    let overall = if failed > 0 {
        "failed: one or more shards failed".to_string()
    } else if processing > 0 {
        "processing".to_string()
    } else if unreachable > 0 {
        // a query would re-dispatch; report degraded rather than lying
        format!("degraded: {unreachable} shard(s) need re-dispatch")
    } else {
        "ready".to_string()
    };
    let mut m = Map::new();
    m.insert("status", Value::from(overall));
    m.insert("shards", Value::Array(shard_statuses));
    Ok(Value::Object(m))
}

/// Aggregate data-cache statistics across live workers (polled
/// concurrently, like `status`).
fn cache_stats(state: &Arc<CoordState>) -> Result<Value, String> {
    let slots = live_slots(state);
    let replies: Vec<Option<Value>> = std::thread::scope(|sc| {
        let handles: Vec<_> = slots
            .iter()
            .map(|(slot, addr)| {
                let (slot, addr) = (*slot, addr.as_str());
                sc.spawn(move || {
                    let params = Payload::json(Value::Null);
                    match call_worker(state, addr, "cache_stats", &params, POLL_RPC_TIMEOUT) {
                        Ok(v) => Some(v.value),
                        Err(_) => {
                            mark_dead(state, slot);
                            None
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or(None)).collect()
    });
    let (mut hits, mut misses, mut bytes, mut entries) = (0u64, 0u64, 0u64, 0u64);
    for v in replies.into_iter().flatten() {
        let g = |k: &str| v.get(k).and_then(Value::as_i64).unwrap_or(0) as u64;
        hits += g("hits");
        misses += g("misses");
        bytes += g("bytes");
        entries += g("entries");
    }
    let mut m = Map::new();
    m.insert("hits", Value::from(hits));
    m.insert("misses", Value::from(misses));
    m.insert("bytes", Value::from(bytes));
    m.insert("entries", Value::from(entries));
    Ok(Value::Object(m))
}

/// `cluster_status` — worker membership + session shard assignments.
fn cluster_status(state: &Arc<CoordState>) -> Value {
    let workers: Vec<Value> = state
        .workers
        .lock()
        .unwrap()
        .iter()
        .map(|w| {
            let mut m = Map::new();
            m.insert("addr", Value::from(w.addr.clone()));
            m.insert("alive", Value::Bool(w.alive));
            Value::Object(m)
        })
        .collect();
    let sessions: Vec<Value> = state
        .sessions
        .lock()
        .unwrap()
        .iter()
        .map(|(name, sess)| {
            let s = sess.lock().unwrap();
            let mut m = Map::new();
            m.insert("session", Value::from(name.clone()));
            m.insert("pool_samples", Value::from(s.manifest.pool.len()));
            m.insert(
                "shards",
                Value::Array(
                    s.shards
                        .iter()
                        .map(|sh| {
                            let mut sm = Map::new();
                            sm.insert("worker", Value::from(sh.worker));
                            sm.insert("pool_samples", Value::from(sh.indices.len()));
                            Value::Object(sm)
                        })
                        .collect(),
                ),
            );
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("workers", Value::Array(workers));
    m.insert("sessions", Value::Array(sessions));
    m.insert("shard_policy", Value::from(state.config.cluster.shard_policy.as_str()));
    Value::Object(m)
}

//! Cluster worker role (DESIGN.md §Cluster).
//!
//! A worker *is* an `AlServer` — the worker-facing RPC methods
//! (`scan_shard`, `select_shard`, `drop_session`) live in the server
//! dispatch and reuse the same session/pipeline/strategy code paths as
//! `push_data`/`query`, so `serve --role worker` starts a plain server.
//! This module adds what the role needs on top: registration with a
//! coordinator — one-shot (`register_with`) or live via the
//! [`Heartbeater`] lease loop (`serve --role worker --discover`) — and
//! the candidate-building logic `select_shard` serves.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::runtime::backend::ComputeBackend;
use crate::server::pool::{ConnPool, PoolConfig};
use crate::server::rpc::RpcError;
use crate::server::wire::{Payload, WireMode};
use crate::server::AlClient;
use crate::strategies::{self, SelectCtx};
use crate::util::mat::Mat;

use super::merge::{merge_kind, Candidate, MergeKind};

/// Register `worker_addr` ("host:port" as the *coordinator* should dial
/// it — a bind address of 0.0.0.0 is not routable) with the coordinator
/// at `coordinator`. Idempotent: re-registering a known address revives
/// it.
pub fn register_with(worker_addr: &str, coordinator: &str) -> Result<(), RpcError> {
    let mut c = AlClient::connect(coordinator)?;
    let mut p = Map::new();
    p.insert("addr", Value::from(worker_addr));
    c.call("register", Value::Object(p))?;
    Ok(())
}

/// Background heartbeat/lease loop — the worker side of live membership
/// (DESIGN.md §Cluster; `serve --role worker --discover <coordinator>`).
///
/// Every `heartbeat_ms` the loop renews this worker's lease with the
/// coordinator over one pooled connection (re-dialed transparently after
/// a coordinator restart, so workers re-register on reconnect with no
/// operator action). When the coordinator has been unreachable for
/// longer than the lease, the worker knows it has been expired from the
/// view and flags itself deregistered (`membership.self_deregistered`);
/// it keeps beating, and the first beat that lands is a fresh join
/// (`membership.rejoins`) — the coordinator rebalances a slice of the
/// pool back onto it.
///
/// [`Heartbeater::stop`] sends a best-effort graceful `deregister` (the
/// coordinator rebalances immediately instead of waiting out the lease);
/// [`Heartbeater::stop_quiet`] and plain `Drop` skip it — that is the
/// crash-simulation path the fault-injection harness uses.
pub struct Heartbeater {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    advertised: String,
    coordinator: String,
}

impl Heartbeater {
    pub fn start(
        advertised: &str,
        coordinator: &str,
        heartbeat_ms: u64,
        lease_ms: u64,
        metrics: Option<Arc<Registry>>,
    ) -> Heartbeater {
        let stop = Arc::new(AtomicBool::new(false));
        let (stop_bg, addr, coord) =
            (stop.clone(), advertised.to_string(), coordinator.to_string());
        let handle = std::thread::Builder::new()
            .name("alaas-worker-heartbeat".into())
            .spawn(move || {
                heartbeat_loop(&addr, &coord, heartbeat_ms, lease_ms, metrics, &stop_bg)
            })
            .expect("spawn heartbeat thread");
        Heartbeater {
            stop,
            handle: Some(handle),
            advertised: advertised.to_string(),
            coordinator: coordinator.to_string(),
        }
    }

    /// Stop beating and gracefully `deregister` (best effort), so the
    /// coordinator rebalances this worker's rows right away.
    pub fn stop(mut self) {
        self.stop_thread();
        if rpc_once(&self.coordinator, "deregister", &self.advertised).is_ok() {
            crate::log_info!("cluster", "deregistered from {}", self.coordinator);
        }
    }

    /// Stop without deregistering — the coordinator must discover the
    /// departure via lease expiry or keepalive probes (fault-injection
    /// harness: a crashed or wedged process sends no goodbyes).
    pub fn stop_quiet(mut self) {
        self.stop_thread();
    }

    fn stop_thread(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Heartbeater {
    fn drop(&mut self) {
        // quiet by default; only the explicit `stop()` deregisters
        self.stop_thread();
    }
}

fn count(metrics: &Option<Arc<Registry>>, name: &str) {
    if let Some(m) = metrics {
        m.counter(name).fetch_add(1, Ordering::Relaxed);
    }
}

fn heartbeat_loop(
    addr: &str,
    coordinator: &str,
    heartbeat_ms: u64,
    lease_ms: u64,
    metrics: Option<Arc<Registry>>,
    stop: &AtomicBool,
) {
    // one parked connection; kept longer than the lease so a healthy
    // loop never re-dials, while a coordinator restart is absorbed by
    // the pool's stale-detect/redial path
    let pool = ConnPool::new(
        PoolConfig { max_idle_per_peer: 1, idle_timeout_ms: lease_ms.max(1_000) * 4 },
        WireMode::Json,
        None,
    )
    // liveness plane: heartbeats are strictly serial and must never
    // share a socket with (or queue behind) data-plane traffic, so
    // multiplexing is explicitly off even if the wire ever goes binary
    .with_mux(false)
    .with_timeouts(Duration::from_secs(2), Duration::from_secs(5));
    let read_timeout = Duration::from_millis((heartbeat_ms * 4).max(1_000));
    // start the overdue clock at process start, so a worker that never
    // reaches the coordinator at all still flags itself after one lease
    let mut last_ok = Instant::now();
    // the coordinator's lease is authoritative (config may drift between
    // the two sides); until a reply carries one, use the local knob
    let mut lease = lease_ms;
    let mut deregistered = false;
    while !stop.load(Ordering::SeqCst) {
        let mut p = Map::new();
        p.insert("addr", Value::from(addr));
        match pool.call(coordinator, "heartbeat", &Payload::json(Value::Object(p)), Some(read_timeout)) {
            Ok(body) => {
                if let Some(l) = body.value.get("lease_ms").and_then(Value::as_usize) {
                    if l > 0 {
                        lease = l as u64;
                    }
                }
                if deregistered {
                    deregistered = false;
                    count(&metrics, "membership.rejoins");
                    crate::log_info!(
                        "cluster",
                        "re-registered with coordinator {coordinator} after lease loss"
                    );
                }
                last_ok = Instant::now();
                count(&metrics, "membership.worker.heartbeats");
            }
            Err(e) => {
                count(&metrics, "membership.worker.heartbeat_failures");
                let overdue = last_ok.elapsed() >= Duration::from_millis(lease);
                if overdue && !deregistered {
                    // the coordinator has certainly expired us by now:
                    // treat ourselves as out of the cluster (and say so
                    // once), but keep beating — the next success re-joins
                    deregistered = true;
                    count(&metrics, "membership.self_deregistered");
                    crate::log_warn!(
                        "cluster",
                        "lease with {coordinator} expired ({e}); self-deregistered, retrying"
                    );
                }
            }
        }
        // sleep one heartbeat in small slices so stop() joins promptly
        let mut slept = 0u64;
        while slept < heartbeat_ms && !stop.load(Ordering::SeqCst) {
            let step = 25u64.min(heartbeat_ms - slept);
            std::thread::sleep(Duration::from_millis(step));
            slept += step;
        }
    }
}

/// One fire-and-forget v1 RPC on a fresh connection (the graceful
/// deregister; no negotiation, no pooling). Deliberately *not*
/// `AlClient::deregister`: this runs on the worker's shutdown path and
/// must be bounded by seconds even when the coordinator is already gone,
/// while `AlClient::connect` eagerly dials with a 30 s bound (and its
/// `connect_timeout` variant needs a resolved `SocketAddr`, which a
/// hostname-configured coordinator address may not be).
fn rpc_once(coordinator: &str, method: &str, addr: &str) -> Result<(), RpcError> {
    let pool = ConnPool::new(
        PoolConfig { max_idle_per_peer: 0, idle_timeout_ms: 1_000 },
        WireMode::Json,
        None,
    )
    // one-shot bookkeeping RPC on the liveness plane: no muxing
    .with_mux(false)
    .with_timeouts(Duration::from_secs(2), Duration::from_secs(2));
    let mut p = Map::new();
    p.insert("addr", Value::from(addr));
    pool.call(
        coordinator,
        method,
        &Payload::json(Value::Object(p)),
        Some(Duration::from_secs(2)),
    )
    .map(|_| ())
}

/// Build the `select_shard` candidate list from a ready session's scan
/// outputs. `ok_rows[rel]` maps a strategy-relative index back to the
/// shard-local pool index the coordinator's plan understands. The server
/// puts the slim `{idx, score}` pairs in the JSON header and — under the
/// refine protocol — packs the per-candidate `scores`/`emb` rows into two
/// tensor sections (DESIGN.md §Wire).
#[allow(clippy::too_many_arguments)]
pub fn build_candidates(
    strategy: &str,
    budget: usize,
    with_embeddings: bool,
    ok_rows: &[usize],
    cand_emb: &Mat,
    cand_scores: &Mat,
    labeled: &Mat,
    backend: &dyn ComputeBackend,
    seed: u64,
) -> Result<Vec<Candidate>, String> {
    let kind = merge_kind(strategy)
        .ok_or_else(|| format!("select_shard: unknown strategy '{strategy}'"))?;
    let strat = strategies::by_name(strategy)
        .ok_or_else(|| format!("select_shard: unknown strategy '{strategy}'"))?;
    let ctx = SelectCtx {
        scores: cand_scores,
        embeddings: cand_emb,
        labeled,
        backend,
        seed,
    };
    let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
    Ok(picked
        .iter()
        .map(|&rel| {
            let score = match kind {
                MergeKind::ExactTopK { column, .. } => {
                    cand_scores.get(rel, column as usize)
                }
                // refine/random merges never read the scalar
                _ => 0.0,
            };
            Candidate {
                idx: ok_rows[rel],
                score,
                scores: if with_embeddings { cand_scores.row(rel).to_vec() } else { vec![] },
                emb: if with_embeddings { cand_emb.row(rel).to_vec() } else { vec![] },
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use crate::util::topk;

    #[test]
    fn candidates_are_local_topk_with_scores() {
        // 6 ok rows out of an 8-row shard (rows 2 and 5 failed upstream).
        let ok_rows = vec![0, 1, 3, 4, 6, 7];
        let mut scores = Mat::zeros(6, 4);
        let lc = [0.9f32, 0.1, 0.5, 0.7, 0.3, 0.8];
        for (i, &v) in lc.iter().enumerate() {
            scores.set(i, 0, v);
        }
        let emb = Mat::zeros(6, 4);
        let labeled = Mat::zeros(0, 4);
        let backend = HostBackend::new();
        let out = build_candidates(
            "least_confidence",
            3,
            false,
            &ok_rows,
            &emb,
            &scores,
            &labeled,
            &backend,
            7,
        )
        .unwrap();
        let want = topk::top_k_desc(&lc, 3); // [0, 5, 3] in rel indices
        let got_idx: Vec<usize> = out.iter().map(|c| c.idx).collect();
        let want_idx: Vec<usize> = want.iter().map(|&rel| ok_rows[rel]).collect();
        assert_eq!(got_idx, want_idx);
        // slim candidates: no embeddings attached, and the slim wire form
        // drops the heavy fields too
        assert!(out[0].emb.is_empty());
        assert!(out[0].to_value(false).get("emb").is_none());
        assert!((out[0].score - 0.9).abs() < 1e-6);
    }

    #[test]
    fn refine_candidates_carry_embeddings() {
        let ok_rows: Vec<usize> = (0..10).collect();
        let mut emb = Mat::zeros(10, 3);
        for i in 0..10 {
            emb.set(i, 0, i as f32);
        }
        let scores = Mat::zeros(10, 4);
        let labeled = Mat::zeros(0, 3);
        let backend = HostBackend::new();
        let out = build_candidates(
            "k_center_greedy",
            4,
            true,
            &ok_rows,
            &emb,
            &scores,
            &labeled,
            &backend,
            7,
        )
        .unwrap();
        assert_eq!(out.len(), 4);
        for c in &out {
            assert_eq!(c.emb.len(), 3);
            assert_eq!(c.scores.len(), 4);
            // embedding row matches the candidate's local index
            // (ok_rows is the identity here)
            assert_eq!(c.emb[0], c.idx as f32);
        }
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let emb = Mat::zeros(1, 2);
        let scores = Mat::zeros(1, 4);
        let labeled = Mat::zeros(0, 2);
        let backend = HostBackend::new();
        let e = build_candidates(
            "auto",
            1,
            false,
            &[0],
            &emb,
            &scores,
            &labeled,
            &backend,
            0,
        )
        .unwrap_err();
        assert!(e.contains("unknown strategy"), "{e}");
    }
}

//! Cluster worker role (DESIGN.md §Cluster).
//!
//! A worker *is* an `AlServer` — the worker-facing RPC methods
//! (`scan_shard`, `select_shard`, `drop_session`) live in the server
//! dispatch and reuse the same session/pipeline/strategy code paths as
//! `push_data`/`query`, so `serve --role worker` starts a plain server.
//! This module adds what the role needs on top: registration with a
//! coordinator and the candidate-building logic `select_shard` serves.

use crate::json::{Map, Value};
use crate::runtime::backend::ComputeBackend;
use crate::server::rpc::RpcError;
use crate::server::AlClient;
use crate::strategies::{self, SelectCtx};
use crate::util::mat::Mat;

use super::merge::{merge_kind, Candidate, MergeKind};

/// Register `worker_addr` ("host:port" as the *coordinator* should dial
/// it — a bind address of 0.0.0.0 is not routable) with the coordinator
/// at `coordinator`. Idempotent: re-registering a known address revives
/// it.
pub fn register_with(worker_addr: &str, coordinator: &str) -> Result<(), RpcError> {
    let mut c = AlClient::connect(coordinator)?;
    let mut p = Map::new();
    p.insert("addr", Value::from(worker_addr));
    c.call("register", Value::Object(p))?;
    Ok(())
}

/// Build the `select_shard` candidate list from a ready session's scan
/// outputs. `ok_rows[rel]` maps a strategy-relative index back to the
/// shard-local pool index the coordinator's plan understands. The server
/// puts the slim `{idx, score}` pairs in the JSON header and — under the
/// refine protocol — packs the per-candidate `scores`/`emb` rows into two
/// tensor sections (DESIGN.md §Wire).
#[allow(clippy::too_many_arguments)]
pub fn build_candidates(
    strategy: &str,
    budget: usize,
    with_embeddings: bool,
    ok_rows: &[usize],
    cand_emb: &Mat,
    cand_scores: &Mat,
    labeled: &Mat,
    backend: &dyn ComputeBackend,
    seed: u64,
) -> Result<Vec<Candidate>, String> {
    let kind = merge_kind(strategy)
        .ok_or_else(|| format!("select_shard: unknown strategy '{strategy}'"))?;
    let strat = strategies::by_name(strategy)
        .ok_or_else(|| format!("select_shard: unknown strategy '{strategy}'"))?;
    let ctx = SelectCtx {
        scores: cand_scores,
        embeddings: cand_emb,
        labeled,
        backend,
        seed,
    };
    let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
    Ok(picked
        .iter()
        .map(|&rel| {
            let score = match kind {
                MergeKind::ExactTopK { column, .. } => {
                    cand_scores.get(rel, column as usize)
                }
                // refine/random merges never read the scalar
                _ => 0.0,
            };
            Candidate {
                idx: ok_rows[rel],
                score,
                scores: if with_embeddings { cand_scores.row(rel).to_vec() } else { vec![] },
                emb: if with_embeddings { cand_emb.row(rel).to_vec() } else { vec![] },
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use crate::util::topk;

    #[test]
    fn candidates_are_local_topk_with_scores() {
        // 6 ok rows out of an 8-row shard (rows 2 and 5 failed upstream).
        let ok_rows = vec![0, 1, 3, 4, 6, 7];
        let mut scores = Mat::zeros(6, 4);
        let lc = [0.9f32, 0.1, 0.5, 0.7, 0.3, 0.8];
        for (i, &v) in lc.iter().enumerate() {
            scores.set(i, 0, v);
        }
        let emb = Mat::zeros(6, 4);
        let labeled = Mat::zeros(0, 4);
        let backend = HostBackend::new();
        let out = build_candidates(
            "least_confidence",
            3,
            false,
            &ok_rows,
            &emb,
            &scores,
            &labeled,
            &backend,
            7,
        )
        .unwrap();
        let want = topk::top_k_desc(&lc, 3); // [0, 5, 3] in rel indices
        let got_idx: Vec<usize> = out.iter().map(|c| c.idx).collect();
        let want_idx: Vec<usize> = want.iter().map(|&rel| ok_rows[rel]).collect();
        assert_eq!(got_idx, want_idx);
        // slim candidates: no embeddings attached, and the slim wire form
        // drops the heavy fields too
        assert!(out[0].emb.is_empty());
        assert!(out[0].to_value(false).get("emb").is_none());
        assert!((out[0].score - 0.9).abs() < 1e-6);
    }

    #[test]
    fn refine_candidates_carry_embeddings() {
        let ok_rows: Vec<usize> = (0..10).collect();
        let mut emb = Mat::zeros(10, 3);
        for i in 0..10 {
            emb.set(i, 0, i as f32);
        }
        let scores = Mat::zeros(10, 4);
        let labeled = Mat::zeros(0, 3);
        let backend = HostBackend::new();
        let out = build_candidates(
            "k_center_greedy",
            4,
            true,
            &ok_rows,
            &emb,
            &scores,
            &labeled,
            &backend,
            7,
        )
        .unwrap();
        assert_eq!(out.len(), 4);
        for c in &out {
            assert_eq!(c.emb.len(), 3);
            assert_eq!(c.scores.len(), 4);
            // embedding row matches the candidate's local index
            // (ok_rows is the identity here)
            assert_eq!(c.emb[0], c.idx as f32);
        }
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let emb = Mat::zeros(1, 2);
        let scores = Mat::zeros(1, 4);
        let labeled = Mat::zeros(0, 2);
        let backend = HostBackend::new();
        let e = build_candidates(
            "auto",
            1,
            false,
            &[0],
            &emb,
            &scores,
            &labeled,
            &backend,
            0,
        )
        .unwrap_err();
        assert!(e.contains("unknown strategy"), "{e}");
    }
}

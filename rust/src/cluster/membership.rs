//! Live cluster membership (DESIGN.md §Cluster): heartbeat/lease
//! auto-discovery and the rendezvous rebalance planner.
//!
//! PR 1's coordinator assumed a fixed worker set: membership changed only
//! through the one-shot `register` RPC, and a dead worker's entire shard
//! was dumped onto one survivor. This module is the data model behind
//! live membership:
//!
//! * [`Membership`] — a lease table keyed by worker address. Workers
//!   renew their lease with periodic `heartbeat` RPCs; leases that
//!   outlive `[cluster.membership] lease_ms` are swept out. Every join or
//!   departure bumps a **generation** counter, and the
//!   generation-numbered [`View`] is what the coordinator's scatter
//!   paths key their shard layout on.
//! * [`assign`] — the rebalance planner: a *pure function* from (pool
//!   size, member set) to row ownership, via rendezvous
//!   (highest-random-weight) hashing. Purity is the whole point: the
//!   final layout depends only on the final member set — never on the
//!   order membership events were observed in — every pool row is owned
//!   exactly once, and a single join/leave moves only the rows the
//!   changed member gains/loses (a joiner takes a proportional slice
//!   from everyone; a leaver's rows scatter across *all* survivors, not
//!   one). Property-tested below.
//! * [`MsClock`] — the millisecond clock leases are measured on, with a
//!   virtual offset so the fault-injection harness can expire leases
//!   deterministically without waiting wall-clock time.
//!
//! All time flows through explicit `now_ms` parameters; `Membership`
//! itself never reads a clock, which keeps every transition replayable
//! in tests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// `[cluster.membership]` knobs (DESIGN.md §Cluster). Disabled by
/// default: the coordinator then runs the PR 1 static-config protocol
/// (config `workers` + one-shot `register`) unchanged.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipConfig {
    /// Master switch for heartbeat/lease membership and shard
    /// rebalancing.
    pub enabled: bool,
    /// Interval between worker heartbeats; the coordinator's
    /// lease/probe sweep runs at half this.
    pub heartbeat_ms: u64,
    /// Lease granted per heartbeat. A worker silent for this long is
    /// swept from the view; must cover several heartbeats so one lost
    /// beat cannot expire a live worker.
    pub lease_ms: u64,
}

impl Default for MembershipConfig {
    fn default() -> Self {
        MembershipConfig { enabled: false, heartbeat_ms: 500, lease_ms: 2500 }
    }
}

/// Millisecond clock with a virtual offset. The coordinator stamps lease
/// deadlines off one of these; `advance` lets the test harness move time
/// forward (lease expiry without sleeping), which is why lease math must
/// never touch `Instant::now` directly.
pub struct MsClock {
    start: Instant,
    offset_ms: AtomicU64,
}

impl MsClock {
    pub fn new() -> MsClock {
        MsClock { start: Instant::now(), offset_ms: AtomicU64::new(0) }
    }

    pub fn now_ms(&self) -> u64 {
        let real = self.start.elapsed().as_millis().min(u64::MAX as u128) as u64;
        real.saturating_add(self.offset_ms.load(Ordering::Relaxed))
    }

    /// Jump the clock forward by `ms` (virtual-time fault injection).
    pub fn advance(&self, ms: u64) {
        self.offset_ms.fetch_add(ms, Ordering::Relaxed);
    }
}

impl Default for MsClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Generation-numbered snapshot of the live worker set. `members` is
/// ascending by address — a deterministic order for shard indexing that
/// does not depend on join order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct View {
    pub generation: u64,
    pub members: Vec<String>,
}

/// The coordinator's lease table. Every membership transition (join,
/// lease expiry, eviction, graceful deregister) bumps `generation`;
/// lease renewals do not.
#[derive(Debug, Default)]
pub struct Membership {
    generation: u64,
    /// Member address -> lease deadline (ms on the coordinator's clock).
    leases: BTreeMap<String, u64>,
}

impl Membership {
    pub fn new() -> Membership {
        Membership::default()
    }

    /// Renew (or establish) `addr`'s lease. Returns `(joined, generation)`
    /// where `joined` is true when the address was not in the view — a
    /// first contact or a return after expiry — which bumps the
    /// generation.
    pub fn heartbeat(&mut self, addr: &str, now_ms: u64, lease_ms: u64) -> (bool, u64) {
        let joined = !self.leases.contains_key(addr);
        self.leases.insert(addr.to_string(), now_ms.saturating_add(lease_ms));
        if joined {
            self.generation += 1;
        }
        (joined, self.generation)
    }

    /// Drop `addr` from the view (observed death, probe failure, or a
    /// graceful deregister). Returns whether it was present.
    pub fn remove(&mut self, addr: &str) -> bool {
        if self.leases.remove(addr).is_some() {
            self.generation += 1;
            true
        } else {
            false
        }
    }

    /// Sweep out every member whose lease deadline has passed, returning
    /// the expired addresses. One sweep bumps the generation at most
    /// once, however many members it expires.
    pub fn expire(&mut self, now_ms: u64) -> Vec<String> {
        let dead: Vec<String> = self
            .leases
            .iter()
            .filter(|(_, &deadline)| deadline < now_ms)
            .map(|(a, _)| a.clone())
            .collect();
        if !dead.is_empty() {
            for a in &dead {
                self.leases.remove(a);
            }
            self.generation += 1;
        }
        dead
    }

    pub fn contains(&self, addr: &str) -> bool {
        self.leases.contains_key(addr)
    }

    /// Milliseconds of lease left for `addr` (None if not a member; 0 if
    /// overdue but not yet swept).
    pub fn lease_remaining_ms(&self, addr: &str, now_ms: u64) -> Option<u64> {
        self.leases.get(addr).map(|&d| d.saturating_sub(now_ms))
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Raise the generation counter to at least `floor`. Crash recovery
    /// calls this with the WAL's recorded high-water view generation so a
    /// restarted coordinator (whose lease table starts empty) can never
    /// re-issue a generation number that pre-crash workers or shard
    /// layouts already observed — view generations are monotone across
    /// restarts, not just within a process lifetime.
    pub fn restore_generation(&mut self, floor: u64) {
        if self.generation < floor {
            self.generation = floor;
        }
    }

    pub fn len(&self) -> usize {
        self.leases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leases.is_empty()
    }

    /// Current members with their lease deadlines, ascending by address.
    pub fn leases(&self) -> Vec<(String, u64)> {
        self.leases.iter().map(|(a, &d)| (a.clone(), d)).collect()
    }

    pub fn view(&self) -> View {
        View {
            generation: self.generation,
            members: self.leases.keys().cloned().collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Rebalance planner: rendezvous hashing from (pool size, member set) to
// row ownership.

use crate::util::fnv1a;

/// SplitMix64 finalizer: full-avalanche mixing so nearby row indices and
/// similar addresses decorrelate.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Rendezvous weight of `(member, row)`: the row is owned by the member
/// with the highest weight.
fn weight(member_hash: u64, row: usize) -> u64 {
    mix(member_hash ^ mix((row as u64).wrapping_add(0x9E37_79B9_7F4A_7C15)))
}

/// The rebalance planner: split pool rows `0..n_rows` across `members`
/// by rendezvous hashing. Pure in the member *set* — the result is
/// independent of the order of `members`, so membership-event reordering
/// cannot change the final layout — and stable per member: a join or
/// leave only moves the rows the changed member wins or frees. Each
/// member's row list is ascending (the exact-top-k merge's tie-break
/// proof requires it, DESIGN.md §Cluster). Returns an empty map when
/// `members` is empty.
pub fn assign(n_rows: usize, members: &[String]) -> BTreeMap<String, Vec<usize>> {
    let mut out: BTreeMap<String, Vec<usize>> =
        members.iter().map(|m| (m.clone(), Vec::new())).collect();
    if out.is_empty() {
        return out;
    }
    // hash each member once; ties (astronomically unlikely) break by
    // address so the winner never depends on slice order
    let names: Vec<String> = out.keys().cloned().collect();
    let hashed: Vec<u64> = names.iter().map(|m| fnv1a(m.as_bytes())).collect();
    for row in 0..n_rows {
        let best = (0..names.len())
            .max_by_key(|&i| (weight(hashed[i], row), &names[i]))
            .expect("non-empty members");
        out.get_mut(&names[best]).expect("owner is a member").push(row);
    }
    out
}

/// Rows whose owner differs between two assignments — the planner's
/// move count (metrics + minimality tests). Rows present in only one
/// assignment count as moved.
pub fn moved_rows(
    old: &BTreeMap<String, Vec<usize>>,
    new: &BTreeMap<String, Vec<usize>>,
) -> usize {
    let owner_of = |a: &BTreeMap<String, Vec<usize>>| -> BTreeMap<usize, &String> {
        let mut m = BTreeMap::new();
        for (member, rows) in a {
            for &r in rows {
                m.insert(r, member);
            }
        }
        m
    };
    let old_of = owner_of(old);
    let new_of = owner_of(new);
    let mut moved = 0usize;
    for (row, owner) in &new_of {
        if old_of.get(row) != Some(owner) {
            moved += 1;
        }
    }
    for row in old_of.keys() {
        if !new_of.contains_key(row) {
            moved += 1;
        }
    }
    moved
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn addr(i: usize) -> String {
        format!("10.0.{}.{}:7{:03}", i / 8, i % 8, i)
    }

    /// Random distinct member set of size 1..=max.
    fn random_members(rng: &mut crate::util::rng::Rng, max: usize) -> Vec<String> {
        let k = 1 + rng.below(max);
        let mut pool: Vec<usize> = (0..16).collect();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let j = rng.below(pool.len());
            out.push(addr(pool.swap_remove(j)));
        }
        out
    }

    fn assert_partition(a: &BTreeMap<String, Vec<usize>>, n: usize) -> Result<(), String> {
        let mut all: Vec<usize> = a.values().flatten().copied().collect();
        all.sort_unstable();
        crate::prop_assert!(
            all == (0..n).collect::<Vec<_>>(),
            "not a partition of 0..{n}: {all:?}"
        );
        for (m, rows) in a {
            crate::prop_assert!(
                rows.windows(2).all(|w| w[0] < w[1]),
                "{m}: rows not ascending: {rows:?}"
            );
        }
        Ok(())
    }

    #[test]
    fn prop_assign_partitions_every_row_exactly_once() {
        prop::check("membership-assign-partition", 60, |rng| {
            let members = random_members(rng, 8);
            let n = rng.below(300);
            let a = assign(n, &members);
            crate::prop_assert!(a.len() == members.len(), "missing members in map");
            assert_partition(&a, n)
        });
    }

    #[test]
    fn prop_assign_is_order_independent() {
        prop::check("membership-assign-order", 40, |rng| {
            let mut members = random_members(rng, 8);
            let n = 1 + rng.below(200);
            let base = assign(n, &members);
            // shuffle and re-plan: the event/observation order of members
            // must not matter
            for _ in 0..3 {
                let i = rng.below(members.len());
                let j = rng.below(members.len());
                members.swap(i, j);
            }
            let again = assign(n, &members);
            crate::prop_assert!(base == again, "assignment depends on member order");
            Ok(())
        });
    }

    #[test]
    fn prop_single_join_moves_only_the_joiners_rows() {
        prop::check("membership-join-minimal", 40, |rng| {
            let mut members = random_members(rng, 6);
            let n = 1 + rng.below(300);
            let old = assign(n, &members);
            let newcomer = addr(40 + rng.below(8));
            members.push(newcomer.clone());
            let new = assign(n, &members);
            assert_partition(&new, n)?;
            // incumbents only *lose* rows, and everything lost lands on
            // the joiner — nothing shuffles between incumbents
            let mut lost = Vec::new();
            for (m, old_rows) in &old {
                let new_rows = &new[m];
                crate::prop_assert!(
                    new_rows.iter().all(|r| old_rows.contains(r)),
                    "{m} gained rows on an unrelated join"
                );
                lost.extend(old_rows.iter().filter(|r| !new_rows.contains(r)).copied());
            }
            lost.sort_unstable();
            crate::prop_assert!(
                lost == new[&newcomer],
                "lost rows {:?} != joiner's rows {:?}",
                lost,
                new[&newcomer]
            );
            crate::prop_assert!(
                moved_rows(&old, &new) == new[&newcomer].len(),
                "moved_rows disagrees with the joiner's slice"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_single_leave_moves_only_the_leavers_rows() {
        prop::check("membership-leave-minimal", 40, |rng| {
            let members = random_members(rng, 6);
            if members.len() < 2 {
                return Ok(());
            }
            let n = 1 + rng.below(300);
            let old = assign(n, &members);
            let gone = members[rng.below(members.len())].clone();
            let rest: Vec<String> =
                members.iter().filter(|m| **m != gone).cloned().collect();
            let new = assign(n, &rest);
            assert_partition(&new, n)?;
            // survivors keep every row they had; only the leaver's rows move
            for (m, old_rows) in &old {
                if *m == gone {
                    continue;
                }
                crate::prop_assert!(
                    old_rows.iter().all(|r| new[m].contains(r)),
                    "{m} lost rows on an unrelated leave"
                );
            }
            crate::prop_assert!(
                moved_rows(&old, &new) == old[&gone].len(),
                "moved_rows != the leaver's row count"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_event_reordering_converges_to_the_same_layout() {
        prop::check("membership-event-reorder", 30, |rng| {
            // apply a join and a leave in both orders: the final layout
            // must be identical because assign() is a function of the
            // final member set only
            let mut members = random_members(rng, 5);
            if members.len() < 2 {
                return Ok(());
            }
            let n = 1 + rng.below(200);
            let joiner = addr(50 + rng.below(8));
            let leaver = members[rng.below(members.len())].clone();
            let mut a_order: Vec<String> = members.clone();
            a_order.push(joiner.clone());
            a_order.retain(|m| *m != leaver);
            members.retain(|m| *m != leaver);
            members.push(joiner);
            let a = assign(n, &a_order);
            let b = assign(n, &members);
            crate::prop_assert!(a == b, "event order changed the final layout");
            Ok(())
        });
    }

    #[test]
    fn assignment_is_roughly_balanced() {
        // deterministic (fixed addrs): rendezvous balance is statistical,
        // so the bound is loose, but a pathological hash would blow it
        let members: Vec<String> = (0..4).map(addr).collect();
        let a = assign(1200, &members);
        for (m, rows) in &a {
            assert!(
                rows.len() >= 150 && rows.len() <= 600,
                "{m} owns {} of 1200 rows (expected ~300)",
                rows.len()
            );
        }
    }

    #[test]
    fn a_leavers_rows_scatter_across_multiple_survivors() {
        // the PR 1 failure mode this planner replaces: the dead worker's
        // shard must not be dumped onto one survivor
        let members: Vec<String> = (0..3).map(addr).collect();
        let old = assign(240, &members);
        let rest: Vec<String> = members[1..].to_vec();
        let new = assign(240, &rest);
        let gained: Vec<usize> = rest
            .iter()
            .map(|m| new[m].len().saturating_sub(old[m].len()))
            .collect();
        assert!(
            gained.iter().filter(|&&g| g > 0).count() >= 2,
            "dead worker's rows were not split: gains {gained:?}"
        );
        assert_eq!(gained.iter().sum::<usize>(), old[&members[0]].len());
    }

    #[test]
    fn lease_lifecycle_joins_renews_expires() {
        let mut m = Membership::new();
        let (joined, g1) = m.heartbeat("a:1", 100, 50);
        assert!(joined);
        assert_eq!(g1, 1);
        // renewal: no generation bump
        let (joined, g2) = m.heartbeat("a:1", 120, 50);
        assert!(!joined);
        assert_eq!(g2, 1);
        assert_eq!(m.lease_remaining_ms("a:1", 130), Some(40));
        m.heartbeat("b:2", 130, 50);
        assert_eq!(m.view().members, vec!["a:1".to_string(), "b:2".to_string()]);
        assert_eq!(m.view().generation, 2);
        // only the overdue lease expires; one sweep = one generation bump
        let dead = m.expire(175);
        assert_eq!(dead, vec!["a:1".to_string()]);
        assert_eq!(m.generation(), 3);
        assert!(m.contains("b:2") && !m.contains("a:1"));
        assert!(m.expire(175).is_empty());
        assert_eq!(m.generation(), 3);
        // a returning worker is a fresh join
        let (joined, g) = m.heartbeat("a:1", 200, 50);
        assert!(joined);
        assert_eq!(g, 4);
        assert!(m.remove("a:1"));
        assert!(!m.remove("a:1"));
        assert_eq!(m.generation(), 5);
    }

    #[test]
    fn restore_generation_is_a_monotone_floor() {
        let mut m = Membership::new();
        m.heartbeat("a:1", 0, 50);
        assert_eq!(m.generation(), 1);
        // recovery floor from a WAL that had seen generation 9
        m.restore_generation(9);
        assert_eq!(m.generation(), 9);
        // a floor below the current value is a no-op, never a regression
        m.restore_generation(3);
        assert_eq!(m.generation(), 9);
        let (_, g) = m.heartbeat("b:2", 0, 50);
        assert_eq!(g, 10);
    }

    #[test]
    fn clock_advances_virtually() {
        let c = MsClock::new();
        let t0 = c.now_ms();
        c.advance(5_000);
        assert!(c.now_ms() >= t0 + 5_000);
    }
}

//! Multi-tenant service policy on the coordinator (DESIGN.md §Tenancy).
//!
//! Two pieces, both config-gated by `coordinator.tenancy` and inert when
//! it is disabled:
//!
//! * [`TenantRegistry`] — the session registry behind the
//!   `session_create` / `session_close` RPC family: explicit lifecycle,
//!   opaque server-minted `tok-*` tokens, per-session weight and worker
//!   quota, and the `max_sessions` admission quota. Legacy callers that
//!   push a plain session name are auto-registered with weight 1, so the
//!   stringly-typed API keeps working under tenancy.
//! * [`AdmissionGate`] — a bounded admission queue in front of the
//!   scatter path with deficit-round-robin weighted fairness across
//!   sessions and an overload-shedding policy (reject-with-`retry_after`
//!   or drop-oldest) once the queue is full. At most
//!   `max_concurrent` scatters run on the workers at once; the rest
//!   queue with backpressure instead of piling onto worker sockets.
//!
//! Fairness model: classic DRR with a uniform cost of 1 per scatter and
//! quantum = session weight. Each visit of a backlogged session grants
//! up to `weight` scatters before the cursor rotates, so two saturating
//! sessions with weights 1:3 complete scatters in a ~1:3 ratio
//! regardless of arrival interleaving. A session's deficit is reset when
//! its queue drains (an idle tenant cannot bank credit).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::{ShedPolicy, TenancyConfig};
use crate::metrics::Registry;
use crate::server::rpc::ServiceError;

/// Prefix of every server-minted session token. Session *names* must not
/// use it — the RPC surface tells tokens and names apart by this prefix.
pub const TOKEN_PREFIX: &str = "tok-";

/// One registered session (tenant) as the registry sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantInfo {
    pub name: String,
    /// Opaque server-minted handle (`tok-<hex>`); the only thing a
    /// session-handle client ever sends back.
    pub token: String,
    /// Fair-share weight (DRR quantum); >= 1.
    pub weight: u64,
    /// Per-session worker cap (0 = all live workers).
    pub max_workers: usize,
    /// Created via `session_create` (true) or auto-registered by a
    /// legacy plain-name push (false).
    pub explicit: bool,
}

fn mint_token(seq: u64) -> String {
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    // splitmix64 over time ^ sequence: unique per process (seq) and
    // unguessable enough to be opaque; not a security boundary
    let mut x = now ^ seq.rotate_left(32) ^ 0x9e37_79b9_7f4a_7c15;
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    format!("{TOKEN_PREFIX}{x:016x}")
}

struct RegState {
    by_name: BTreeMap<String, TenantInfo>,
    by_token: HashMap<String, String>,
}

/// Session registry: name/token book-keeping + the `max_sessions` quota.
pub struct TenantRegistry {
    cfg: TenancyConfig,
    seq: AtomicU64,
    inner: Mutex<RegState>,
}

impl TenantRegistry {
    pub fn new(cfg: TenancyConfig) -> TenantRegistry {
        TenantRegistry {
            cfg,
            seq: AtomicU64::new(1),
            inner: Mutex::new(RegState {
                by_name: BTreeMap::new(),
                by_token: HashMap::new(),
            }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn config(&self) -> &TenancyConfig {
        &self.cfg
    }

    /// Explicit `session_create`: mint a token, subject to the
    /// `max_sessions` quota when tenancy is enabled. Re-creating an
    /// existing name is idempotent — it updates weight/worker-cap and
    /// returns the already-minted token (a retried create must not leak
    /// a second quota slot).
    pub fn create(
        &self,
        name: &str,
        weight: u64,
        max_workers: usize,
    ) -> Result<TenantInfo, ServiceError> {
        if name.is_empty() || name.starts_with(TOKEN_PREFIX) {
            return Err(ServiceError::new(
                crate::server::rpc::ErrorCode::Internal,
                format!("invalid session name '{name}' (empty or reserved '{TOKEN_PREFIX}' prefix)"),
            ));
        }
        let mut st = self.inner.lock().unwrap();
        if let Some(existing) = st.by_name.get_mut(name) {
            existing.weight = weight.max(1);
            existing.max_workers = max_workers;
            existing.explicit = true;
            return Ok(existing.clone());
        }
        if self.cfg.enabled && st.by_name.len() >= self.cfg.max_sessions {
            return Err(ServiceError::quota(format!(
                "session quota exceeded: {}/{} sessions registered",
                st.by_name.len(),
                self.cfg.max_sessions
            )));
        }
        let info = TenantInfo {
            name: name.to_string(),
            token: mint_token(self.seq.fetch_add(1, Ordering::Relaxed)),
            weight: weight.max(1),
            max_workers,
            explicit: true,
        };
        st.by_token.insert(info.token.clone(), info.name.clone());
        st.by_name.insert(info.name.clone(), info.clone());
        Ok(info)
    }

    /// Recovery path: re-install a tenant exactly as the WAL recorded it
    /// (same token, so handles minted before the crash keep working).
    /// No quota check — the record was already admitted once.
    pub fn install(&self, info: TenantInfo) {
        let mut st = self.inner.lock().unwrap();
        if let Some(old) = st.by_name.get(&info.name) {
            st.by_token.remove(&old.token);
        }
        st.by_token.insert(info.token.clone(), info.name.clone());
        st.by_name.insert(info.name.clone(), info);
    }

    /// Auto-register a legacy plain-name session on first push (weight
    /// 1), subject to the same quota. No-op if already registered.
    pub fn ensure(&self, name: &str) -> Result<(), ServiceError> {
        let mut st = self.inner.lock().unwrap();
        if st.by_name.contains_key(name) {
            return Ok(());
        }
        if self.cfg.enabled && st.by_name.len() >= self.cfg.max_sessions {
            return Err(ServiceError::quota(format!(
                "session quota exceeded: {}/{} sessions registered",
                st.by_name.len(),
                self.cfg.max_sessions
            )));
        }
        let info = TenantInfo {
            name: name.to_string(),
            token: mint_token(self.seq.fetch_add(1, Ordering::Relaxed)),
            weight: 1,
            max_workers: 0,
            explicit: false,
        };
        st.by_token.insert(info.token.clone(), info.name.clone());
        st.by_name.insert(info.name.clone(), info);
        Ok(())
    }

    /// Map what a client sent as `session` — a minted token or a plain
    /// name — to the session name. Unknown tokens are a structured
    /// `unknown_session`; plain names pass through untouched (they may
    /// legitimately not be registered yet).
    pub fn resolve(&self, raw: &str) -> Result<String, ServiceError> {
        if !raw.starts_with(TOKEN_PREFIX) {
            return Ok(raw.to_string());
        }
        self.inner
            .lock()
            .unwrap()
            .by_token
            .get(raw)
            .cloned()
            .ok_or_else(|| ServiceError::unknown_session(raw))
    }

    /// Remove a session by name or token, freeing its quota slot.
    pub fn close(&self, name_or_token: &str) -> Option<TenantInfo> {
        let mut st = self.inner.lock().unwrap();
        let name = if name_or_token.starts_with(TOKEN_PREFIX) {
            st.by_token.get(name_or_token)?.clone()
        } else {
            name_or_token.to_string()
        };
        let info = st.by_name.remove(&name)?;
        st.by_token.remove(&info.token);
        Some(info)
    }

    pub fn get(&self, name: &str) -> Option<TenantInfo> {
        self.inner.lock().unwrap().by_name.get(name).cloned()
    }

    /// All registered sessions, name-ordered.
    pub fn list(&self) -> Vec<TenantInfo> {
        self.inner.lock().unwrap().by_name.values().cloned().collect()
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().by_name.len()
    }

    /// Fair-share weight for the gate (1 for unregistered sessions).
    pub fn weight_of(&self, name: &str) -> u64 {
        self.get(name).map(|t| t.weight.max(1)).unwrap_or(1)
    }

    /// Per-session worker cap (0 = uncapped) for the shard planners.
    pub fn max_workers_of(&self, name: &str) -> usize {
        if !self.cfg.enabled {
            return 0;
        }
        let per_session = self.get(name).map(|t| t.max_workers).unwrap_or(0);
        match (per_session, self.cfg.max_workers_per_session) {
            (0, d) => d,
            (w, 0) => w,
            (w, d) => w.min(d),
        }
    }
}

/// Deterministic rendezvous top-k: the subset of `members` a
/// worker-capped session shards across. Stable under membership churn
/// the same way shard re-homing is: each (session, member) pair hashes
/// independently, so a leaver only promotes the next-ranked member.
pub fn worker_subset(members: &[String], k: usize, session: &str) -> Vec<String> {
    if k == 0 || k >= members.len() {
        return members.to_vec();
    }
    let mut scored: Vec<(u64, &String)> =
        members.iter().map(|m| (rv_score(session, m), m)).collect();
    // highest score wins; tie-break on name for full determinism
    scored.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(b.1)));
    let mut keep: Vec<String> = scored.into_iter().take(k).map(|(_, m)| m.clone()).collect();
    // preserve the caller's member order (shard plans are positional)
    keep.sort_by_key(|m| members.iter().position(|x| x == m));
    keep
}

fn rv_score(session: &str, member: &str) -> u64 {
    let mut x: u64 = 0xcbf2_9ce4_8422_2325;
    for b in session.as_bytes().iter().chain([0xffu8].iter()).chain(member.as_bytes()) {
        x ^= *b as u64;
        x = x.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // splitmix finisher spreads the fnv accumulation
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^ (x >> 31)
}

/// Why an admission was refused: the structured payload of the
/// `Overloaded` error (`retry_after_ms` is always > 0).
#[derive(Debug, Clone, PartialEq)]
pub struct Shed {
    pub retry_after_ms: u64,
    pub queued: usize,
}

impl Shed {
    /// The wire error the coordinator returns for this shed.
    pub fn to_service_error(&self) -> ServiceError {
        ServiceError::overloaded(
            format!("admit queue full ({} scatters queued)", self.queued),
            self.retry_after_ms,
        )
    }
}

struct GateState {
    /// Waiting tickets per session, FIFO.
    queues: BTreeMap<String, VecDeque<u64>>,
    /// DRR visit order over sessions with non-empty queues.
    active: VecDeque<String>,
    /// Remaining grants in the current DRR visit of each active session.
    deficit: HashMap<String, u64>,
    /// Last weight seen per session (refreshed at every admit).
    weights: HashMap<String, u64>,
    /// Tickets granted a run slot, awaiting pickup by their waiter.
    granted: HashSet<u64>,
    /// Tickets evicted by drop-oldest, with the retry hint to deliver.
    shed: HashMap<u64, u64>,
    next_ticket: u64,
    running: usize,
    queued_total: usize,
    /// EWMA of scatter wall time (ms); feeds `retry_after_ms`.
    ewma_ms: f64,
    admitted_total: u64,
    shed_total: u64,
    per_session: BTreeMap<String, SessCounts>,
}

#[derive(Default, Clone, Copy)]
struct SessCounts {
    admitted: u64,
    shed: u64,
}

/// Gate-side stats for the `service_stats` RPC.
pub struct GateStats {
    pub running: usize,
    pub queued: usize,
    pub admitted_total: u64,
    pub shed_total: u64,
    /// name -> (admitted, shed, currently queued)
    pub per_session: BTreeMap<String, (u64, u64, usize)>,
}

/// Bounded, weighted-fair admission gate in front of the scatter path.
pub struct AdmissionGate {
    enabled: bool,
    queue_len: usize,
    max_concurrent: usize,
    policy: ShedPolicy,
    metrics: Option<Arc<Registry>>,
    state: Mutex<GateState>,
    cv: Condvar,
}

/// Floor for `retry_after_ms`: a shed reply always tells the client to
/// wait a positive amount, even before any scatter has been timed.
const MIN_RETRY_MS: u64 = 10;

impl AdmissionGate {
    pub fn new(cfg: &TenancyConfig, metrics: Option<Arc<Registry>>) -> AdmissionGate {
        AdmissionGate {
            enabled: cfg.enabled,
            queue_len: cfg.admit_queue_len.max(1),
            max_concurrent: cfg.max_concurrent.max(1),
            policy: cfg.shed_policy,
            metrics,
            state: Mutex::new(GateState {
                queues: BTreeMap::new(),
                active: VecDeque::new(),
                deficit: HashMap::new(),
                weights: HashMap::new(),
                granted: HashSet::new(),
                shed: HashMap::new(),
                next_ticket: 1,
                running: 0,
                queued_total: 0,
                ewma_ms: 0.0,
                admitted_total: 0,
                shed_total: 0,
                per_session: BTreeMap::new(),
            }),
            cv: Condvar::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Scatters currently waiting in the admit queue.
    pub fn queued(&self) -> usize {
        self.state.lock().unwrap().queued_total
    }

    /// Block until this session's scatter is granted a run slot (or shed).
    /// The returned permit releases the slot — and pumps the scheduler —
    /// on drop. With tenancy disabled this is a no-op pass-through: no
    /// lock, no queue, bit-identical scheduling to the pre-tenancy path.
    pub fn admit(self: &Arc<Self>, session: &str, weight: u64) -> Result<AdmitPermit, Shed> {
        if !self.enabled {
            return Ok(AdmitPermit { gate: None, session: String::new(), started: Instant::now() });
        }
        let ticket = {
            let mut st = self.state.lock().unwrap();
            st.weights.insert(session.to_string(), weight.max(1));
            let ticket = st.next_ticket;
            st.next_ticket += 1;
            st.queues.entry(session.to_string()).or_default().push_back(ticket);
            st.queued_total += 1;
            if !st.active.iter().any(|n| n == session) {
                st.active.push_back(session.to_string());
            }
            self.pump(&mut st);
            if !st.granted.contains(&ticket) && st.queued_total > self.queue_len {
                // over capacity: shed per policy
                let victim = match self.policy {
                    ShedPolicy::RejectNew => ticket,
                    // evict the oldest waiter of the *most-backlogged*
                    // session; the arrival keeps its place in the queue.
                    // (Evicting the globally-oldest ticket let one heavy
                    // tenant starve light ones of queue slots: a light
                    // tenant's lone early waiter was always the oldest.)
                    ShedPolicy::DropOldest => oldest_ticket(&st).unwrap_or(ticket),
                };
                let retry = self.retry_after_ms(&st);
                let vsession = remove_ticket(&mut st, victim).unwrap_or_else(|| session.to_string());
                st.shed_total += 1;
                st.per_session.entry(vsession.clone()).or_default().shed += 1;
                if let Some(m) = &self.metrics {
                    m.counter("tenancy.shed").fetch_add(1, Ordering::Relaxed);
                    m.counter(&format!("session.{vsession}.shed")).fetch_add(1, Ordering::Relaxed);
                    m.gauge_set("tenancy.queued", st.queued_total as u64);
                }
                if victim == ticket {
                    return Err(Shed { retry_after_ms: retry, queued: st.queued_total });
                }
                // a parked waiter took the hit: hand it the retry hint
                st.shed.insert(victim, retry);
                drop(st);
                self.cv.notify_all();
                return self.wait_for(ticket, session);
            }
            ticket
        };
        self.wait_for(ticket, session)
    }

    fn wait_for(self: &Arc<Self>, ticket: u64, session: &str) -> Result<AdmitPermit, Shed> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.granted.remove(&ticket) {
                st.admitted_total += 1;
                st.per_session.entry(session.to_string()).or_default().admitted += 1;
                if let Some(m) = &self.metrics {
                    m.counter("tenancy.admitted").fetch_add(1, Ordering::Relaxed);
                    m.counter(&format!("session.{session}.admitted"))
                        .fetch_add(1, Ordering::Relaxed);
                    m.gauge_set("tenancy.queued", st.queued_total as u64);
                }
                drop(st);
                return Ok(AdmitPermit {
                    gate: Some(self.clone()),
                    session: session.to_string(),
                    started: Instant::now(),
                });
            }
            if let Some(retry) = st.shed.remove(&ticket) {
                let queued = st.queued_total;
                drop(st);
                return Err(Shed { retry_after_ms: retry, queued });
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Grant run slots to queued tickets in deficit-round-robin order.
    /// Caller holds the state lock; waiters are woken by the caller.
    fn pump(&self, st: &mut GateState) {
        while st.running < self.max_concurrent {
            let Some(name) = st.active.front().cloned() else { break };
            let empty = st.queues.get(&name).map(|q| q.is_empty()).unwrap_or(true);
            if empty {
                // queue drained: retire the visit and reset the deficit
                // (idle sessions don't bank credit)
                st.active.pop_front();
                st.deficit.remove(&name);
                st.queues.remove(&name);
                continue;
            }
            let quantum = *st.weights.get(&name).unwrap_or(&1);
            let d = st.deficit.entry(name.clone()).or_insert(0);
            if *d == 0 {
                // fresh visit: refill the quantum
                *d = quantum.max(1);
            }
            *d -= 1;
            let exhausted = *d == 0;
            let ticket = st
                .queues
                .get_mut(&name)
                .and_then(|q| q.pop_front())
                .expect("non-empty queue checked above");
            st.granted.insert(ticket);
            st.running += 1;
            st.queued_total -= 1;
            if let Some(m) = &self.metrics {
                m.gauge_set(&format!("session.{name}.debt"), *st.deficit.get(&name).unwrap_or(&0));
            }
            let drained = st.queues.get(&name).map(|q| q.is_empty()).unwrap_or(true);
            if drained {
                st.active.retain(|n| n != &name);
                st.deficit.remove(&name);
                st.queues.remove(&name);
            } else if exhausted {
                // visit spent: rotate the cursor to the next session
                st.active.rotate_left(1);
            }
        }
        if let Some(m) = &self.metrics {
            m.gauge_set("tenancy.queued", st.queued_total as u64);
            m.gauge_set("tenancy.running", st.running as u64);
        }
    }

    /// Load-derived retry hint: expected drain time of everything ahead
    /// of a new arrival, from the EWMA scatter duration. Never zero.
    fn retry_after_ms(&self, st: &GateState) -> u64 {
        let ahead = (st.queued_total + st.running) as f64;
        let per = if st.ewma_ms > 0.0 { st.ewma_ms } else { MIN_RETRY_MS as f64 };
        ((ahead * per / self.max_concurrent as f64).ceil() as u64).max(MIN_RETRY_MS)
    }

    fn release(&self, session: &str, elapsed: Duration) {
        let mut st = self.state.lock().unwrap();
        st.running = st.running.saturating_sub(1);
        let ms = elapsed.as_secs_f64() * 1e3;
        st.ewma_ms = if st.ewma_ms > 0.0 { 0.7 * st.ewma_ms + 0.3 * ms } else { ms };
        if let Some(m) = &self.metrics {
            m.time(&format!("session.{session}.scatter_ms"), elapsed);
        }
        self.pump(&mut st);
        drop(st);
        self.cv.notify_all();
    }

    pub fn stats(&self) -> GateStats {
        let st = self.state.lock().unwrap();
        let mut per_session: BTreeMap<String, (u64, u64, usize)> = BTreeMap::new();
        for (name, c) in &st.per_session {
            per_session.insert(name.clone(), (c.admitted, c.shed, 0));
        }
        for (name, q) in &st.queues {
            per_session.entry(name.clone()).or_insert((0, 0, 0)).2 = q.len();
        }
        GateStats {
            running: st.running,
            queued: st.queued_total,
            admitted_total: st.admitted_total,
            shed_total: st.shed_total,
            per_session,
        }
    }
}

/// `drop_oldest` victim: the oldest (front) waiter of the session with
/// the deepest backlog. Ties on depth break toward the lexicographically
/// smaller session name so shedding is deterministic under test.
fn oldest_ticket(st: &GateState) -> Option<u64> {
    st.queues
        .iter()
        .filter(|(_, q)| !q.is_empty())
        .max_by(|(a_s, a_q), (b_s, b_q)| {
            a_q.len().cmp(&b_q.len()).then_with(|| b_s.cmp(a_s))
        })
        .and_then(|(_, q)| q.front().copied())
}

/// Remove a waiting ticket from whichever queue holds it; returns the
/// session it belonged to. Keeps `active` consistent.
fn remove_ticket(st: &mut GateState, ticket: u64) -> Option<String> {
    let name = st.queues.iter().find_map(|(n, q)| {
        if q.contains(&ticket) {
            Some(n.clone())
        } else {
            None
        }
    })?;
    if let Some(q) = st.queues.get_mut(&name) {
        q.retain(|&t| t != ticket);
        st.queued_total -= 1;
        if q.is_empty() {
            st.queues.remove(&name);
            st.active.retain(|n| n != &name);
            st.deficit.remove(&name);
        }
    }
    Some(name)
}

/// RAII run slot: dropping it (scatter done, success or failure)
/// releases the slot, feeds the duration EWMA, and pumps the scheduler.
/// The `gate: None` form is the disabled-tenancy pass-through.
pub struct AdmitPermit {
    gate: Option<Arc<AdmissionGate>>,
    session: String,
    started: Instant,
}

impl Drop for AdmitPermit {
    fn drop(&mut self) {
        if let Some(g) = self.gate.take() {
            g.release(&self.session, self.started.elapsed());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(enabled: bool) -> TenancyConfig {
        TenancyConfig { enabled, ..TenancyConfig::default() }
    }

    #[test]
    fn registry_mints_resolves_and_closes_tokens() {
        let reg = TenantRegistry::new(cfg(true));
        let a = reg.create("alpha", 2, 0).unwrap();
        assert!(a.token.starts_with(TOKEN_PREFIX));
        assert_eq!(a.weight, 2);
        assert!(a.explicit);
        // token and plain name both resolve to the name
        assert_eq!(reg.resolve(&a.token).unwrap(), "alpha");
        assert_eq!(reg.resolve("alpha").unwrap(), "alpha");
        // unknown token is a structured unknown_session
        let e = reg.resolve("tok-doesnotexist").unwrap_err();
        assert_eq!(e.code, crate::server::rpc::ErrorCode::UnknownSession);
        // close by token frees the slot and forgets the token
        assert_eq!(reg.close(&a.token).unwrap().name, "alpha");
        assert!(reg.resolve(&a.token).is_err());
        assert!(reg.get("alpha").is_none());
    }

    #[test]
    fn registry_enforces_session_quota() {
        let reg = TenantRegistry::new(TenancyConfig {
            enabled: true,
            max_sessions: 2,
            ..TenancyConfig::default()
        });
        reg.create("a", 1, 0).unwrap();
        reg.create("b", 1, 0).unwrap();
        let e = reg.create("c", 1, 0).unwrap_err();
        assert_eq!(e.code, crate::server::rpc::ErrorCode::QuotaExceeded);
        // re-create of an existing name is idempotent, not a quota hit
        let b2 = reg.create("b", 5, 1).unwrap();
        assert_eq!(b2.weight, 5);
        assert_eq!(reg.count(), 2);
        // closing frees the slot
        reg.close("a").unwrap();
        reg.create("c", 1, 0).unwrap();
        // implicit registration obeys the same quota
        let e = reg.ensure("d").unwrap_err();
        assert_eq!(e.code, crate::server::rpc::ErrorCode::QuotaExceeded);
    }

    #[test]
    fn registry_rejects_reserved_names_and_disabled_quota_is_open() {
        let reg = TenantRegistry::new(cfg(true));
        assert!(reg.create("tok-sneaky", 1, 0).is_err());
        assert!(reg.create("", 1, 0).is_err());
        // disabled tenancy: registry still mints tokens but never quotas
        let open = TenantRegistry::new(TenancyConfig {
            enabled: false,
            max_sessions: 1,
            ..TenancyConfig::default()
        });
        open.create("a", 1, 0).unwrap();
        open.create("b", 1, 0).unwrap();
        open.ensure("c").unwrap();
        assert_eq!(open.count(), 3);
    }

    #[test]
    fn registry_worker_cap_combines_session_and_config() {
        let reg = TenantRegistry::new(TenancyConfig {
            enabled: true,
            max_workers_per_session: 3,
            ..TenancyConfig::default()
        });
        reg.create("capped", 1, 2).unwrap();
        reg.create("open", 1, 0).unwrap();
        assert_eq!(reg.max_workers_of("capped"), 2); // per-session tighter
        assert_eq!(reg.max_workers_of("open"), 3); // config default applies
        assert_eq!(reg.max_workers_of("unregistered"), 3);
        let off = TenantRegistry::new(cfg(false));
        off.create("capped", 1, 2).unwrap();
        assert_eq!(off.max_workers_of("capped"), 0, "disabled tenancy never caps");
    }

    #[test]
    fn install_preserves_token_across_restart() {
        let reg = TenantRegistry::new(cfg(true));
        let a = reg.create("alpha", 2, 1).unwrap();
        let reborn = TenantRegistry::new(cfg(true));
        reborn.install(a.clone());
        assert_eq!(reborn.resolve(&a.token).unwrap(), "alpha");
        assert_eq!(reborn.get("alpha").unwrap(), a);
    }

    #[test]
    fn worker_subset_is_deterministic_and_stable() {
        let members: Vec<String> =
            (0..5).map(|i| format!("127.0.0.1:{}", 7000 + i)).collect();
        let s1 = worker_subset(&members, 2, "alpha");
        assert_eq!(s1.len(), 2);
        assert_eq!(s1, worker_subset(&members, 2, "alpha"), "deterministic");
        // k >= n or k == 0 keeps everyone
        assert_eq!(worker_subset(&members, 0, "alpha"), members);
        assert_eq!(worker_subset(&members, 9, "alpha"), members);
        // removing a non-chosen member does not reshuffle the chosen set
        let without: Vec<String> =
            members.iter().filter(|m| !s1.contains(m)).cloned().collect();
        let mut reduced = members.clone();
        reduced.retain(|m| *m != without[0]);
        assert_eq!(worker_subset(&reduced, 2, "alpha"), s1, "stable under churn");
        // different sessions land on different subsets (spread, not pile-up)
        let spread: HashSet<Vec<String>> = (0..16)
            .map(|i| worker_subset(&members, 2, &format!("s{i}")))
            .collect();
        assert!(spread.len() > 1, "rendezvous should spread sessions");
    }

    fn gate(queue_len: usize, max_concurrent: usize, policy: ShedPolicy) -> Arc<AdmissionGate> {
        Arc::new(AdmissionGate::new(
            &TenancyConfig {
                enabled: true,
                admit_queue_len: queue_len,
                max_concurrent,
                shed_policy: policy,
                ..TenancyConfig::default()
            },
            None,
        ))
    }

    /// Spin until the gate shows `n` queued tickets (threaded tests need
    /// the parked waiters in place before asserting scheduling order).
    fn wait_queued(g: &AdmissionGate, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while g.queued() < n {
            assert!(Instant::now() < deadline, "queue never reached {n}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn disabled_gate_is_a_no_op() {
        let g = Arc::new(AdmissionGate::new(&cfg(false), None));
        let p1 = g.admit("a", 1).unwrap();
        let p2 = g.admit("a", 1).unwrap(); // no cap, no queue
        drop(p1);
        drop(p2);
        assert_eq!(g.queued(), 0);
        assert_eq!(g.stats().admitted_total, 0, "disabled gate keeps no books");
    }

    #[test]
    fn immediate_grant_under_capacity() {
        let g = gate(4, 2, ShedPolicy::RejectNew);
        let p1 = g.admit("a", 1).unwrap();
        let p2 = g.admit("b", 1).unwrap();
        assert_eq!(g.queued(), 0);
        let st = g.stats();
        assert_eq!(st.running, 2);
        assert_eq!(st.admitted_total, 2);
        drop(p1);
        drop(p2);
        assert_eq!(g.stats().running, 0);
    }

    #[test]
    fn reject_new_sheds_arrival_with_positive_retry() {
        let g = gate(1, 1, ShedPolicy::RejectNew);
        let held = g.admit("a", 1).unwrap(); // occupies the run slot
        let g2 = g.clone();
        let waiter = std::thread::spawn(move || g2.admit("a", 1));
        wait_queued(&g, 1); // queue now at capacity
        let shed = g.admit("b", 1).unwrap_err();
        assert!(shed.retry_after_ms > 0, "retry_after must be positive");
        assert_eq!(g.stats().shed_total, 1);
        let se = shed.to_service_error();
        assert_eq!(se.code, crate::server::rpc::ErrorCode::Overloaded);
        assert_eq!(se.retry_after_ms, Some(shed.retry_after_ms));
        // drain: the queued waiter gets the slot once the holder is done
        drop(held);
        let p = waiter.join().unwrap().unwrap();
        drop(p);
        // and a fresh admit after drain succeeds immediately
        drop(g.admit("b", 1).unwrap());
    }

    #[test]
    fn drop_oldest_evicts_the_parked_waiter_not_the_arrival() {
        let g = gate(1, 1, ShedPolicy::DropOldest);
        let held = g.admit("a", 1).unwrap();
        let g2 = g.clone();
        let oldest = std::thread::spawn(move || g2.admit("a", 1));
        wait_queued(&g, 1);
        let g3 = g.clone();
        let newest = std::thread::spawn(move || g3.admit("b", 1));
        // the oldest waiter is evicted with a retry hint...
        let shed = oldest.join().unwrap().unwrap_err();
        assert!(shed.retry_after_ms > 0);
        // ...and the arrival holds its place, running after the holder
        drop(held);
        let p = newest.join().unwrap().unwrap();
        drop(p);
        assert_eq!(g.stats().shed_total, 1);
    }

    #[test]
    fn drop_oldest_targets_most_backlogged_session_not_global_oldest() {
        // A heavy tenant piles up a deep backlog behind a light tenant's
        // single, globally-oldest waiter. On overflow, the victim must
        // come from the heavy tenant's queue — evicting the globally
        // oldest ticket (the old behavior) let the heavy tenant starve
        // the light one out of its lone queue slot.
        let g = gate(4, 1, ShedPolicy::DropOldest);
        let held = g.admit("heavy", 1).unwrap(); // occupies the run slot
        // the light tenant parks first: its waiter is globally oldest
        let gl = g.clone();
        let light = std::thread::spawn(move || gl.admit("light", 1).map(drop).is_ok());
        wait_queued(&g, 1);
        let mut heavies = Vec::new();
        for i in 0..3 {
            wait_queued(&g, 1 + i); // serialize arrivals: heavy's queue is FIFO
            let gh = g.clone();
            heavies.push(std::thread::spawn(move || gh.admit("heavy", 1).map(drop).is_ok()));
        }
        wait_queued(&g, 4); // queue at capacity
        // overflow arrival (kept): someone else must be evicted
        let ga = g.clone();
        let arrival = std::thread::spawn(move || ga.admit("light", 1).map(drop).is_ok());
        // exactly one heavy waiter is shed; everyone else drains through
        // the single slot once the holder releases it
        drop(held);
        assert!(light.join().unwrap(), "light tenant's oldest waiter must survive");
        assert!(arrival.join().unwrap(), "the arrival keeps its place");
        let survived =
            heavies.into_iter().map(|h| h.join().unwrap()).filter(|&ok| ok).count();
        assert_eq!(survived, 2, "exactly one heavy waiter takes the eviction");
        let st = g.stats();
        assert_eq!(st.shed_total, 1);
        assert_eq!(
            st.per_session.get("heavy").map(|&(_, shed, _)| shed).unwrap_or(0),
            1,
            "the shed must be booked against the heavy session"
        );
        assert_eq!(st.per_session.get("light").map(|&(_, shed, _)| shed).unwrap_or(0), 0);
    }

    #[test]
    fn drr_grants_track_weights_under_backlog() {
        // one run slot, both sessions backlogged: grant order must
        // interleave ~1:3 by weight, not round-robin 1:1
        let g = gate(64, 1, ShedPolicy::RejectNew);
        let hold = g.admit("z", 1).unwrap(); // park the slot so queues build
        let order = Arc::new(Mutex::new(Vec::<String>::new()));
        let mut threads = Vec::new();
        // enqueue strictly alternating a, b, a, b... so arrival order
        // cannot explain a 3:1 outcome
        for i in 0..12 {
            let (name, w) = if i % 2 == 0 { ("a", 1) } else { ("b", 3) };
            let g = g.clone();
            let order = order.clone();
            wait_queued(&g, i); // serialize arrivals
            threads.push(std::thread::spawn(move || {
                let p = g.admit(name, w).unwrap();
                order.lock().unwrap().push(name.to_string());
                // hold briefly so the grant order is observable
                std::thread::sleep(Duration::from_millis(2));
                drop(p);
            }));
        }
        wait_queued(&g, 12);
        drop(hold);
        for t in threads {
            t.join().unwrap();
        }
        let order = order.lock().unwrap().clone();
        assert_eq!(order.len(), 12);
        // in the first 8 grants (two full DRR rounds), b must have ~3x
        // a's share: exactly 2 a's and 6 b's
        let first8_b = order.iter().take(8).filter(|s| *s == "b").count();
        assert_eq!(first8_b, 6, "weighted share violated: {order:?}");
    }

    #[test]
    fn deficit_resets_when_queue_drains() {
        // a heavy session that drains must not bank credit for later
        let g = gate(64, 1, ShedPolicy::RejectNew);
        let p = g.admit("heavy", 100).unwrap();
        drop(p); // drained: deficit map must be empty again
        let st = g.state.lock().unwrap();
        assert!(st.deficit.is_empty());
        assert!(st.active.is_empty());
        assert!(st.queues.is_empty());
    }

    #[test]
    fn retry_after_scales_with_observed_scatter_time() {
        let g = gate(1, 1, ShedPolicy::RejectNew);
        // teach the EWMA a ~20ms scatter
        let p = g.admit("a", 1).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        drop(p);
        let held = g.admit("a", 1).unwrap();
        let g2 = g.clone();
        let waiter = std::thread::spawn(move || g2.admit("a", 1));
        wait_queued(&g, 1);
        let shed = g.admit("b", 1).unwrap_err();
        // 2 ahead (1 queued + 1 running) at ~20ms each => >= ~40ms hint
        assert!(
            shed.retry_after_ms >= 20,
            "retry hint should reflect the EWMA: {}",
            shed.retry_after_ms
        );
        drop(held);
        drop(waiter.join().unwrap().unwrap());
    }
}

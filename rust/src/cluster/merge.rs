//! Coordinator-side merge semantics (DESIGN.md §Cluster).
//!
//! Three distributed-selection protocols, chosen per strategy:
//!
//! * **Exact top-k** (the four uncertainty strategies): each worker
//!   returns its local top-`budget` `(index, score)` pairs; the global
//!   top-`budget` is a subset of that union, so merging under the *same
//!   total order* as `util::topk` (NaN last, ties broken by ascending
//!   global index) reproduces the single-server selection exactly.
//!   Shard plans keep per-shard index lists ascending so local
//!   tie-breaks agree with global ones.
//! * **Coordinator-side sampling** (`random`): selection is a pure
//!   function of (non-failed pool size, seed), so the coordinator
//!   samples locally; workers only report their failure lists. Also
//!   exact.
//! * **Candidate-then-refine** (diversity/hybrid): each worker returns an
//!   oversampled, locally-diverse candidate set *with embeddings*; the
//!   coordinator runs the full strategy (KCG / Core-Set / DBAL) over the
//!   candidate union against the labeled-set embeddings.

use std::cmp::Ordering;

use crate::json::{Map, Value};
use crate::strategies::ScoreColumn;
use crate::util::mat::Mat;

// Matrix wire forms live in the data-plane module with the v2 protocol
// (DESIGN.md §Wire); Candidate's slim/fat JSON forms reuse them. On the
// v2 wire the packed candidate tensors are consumed zero-copy: rows are
// copied once from the frame buffer into `Candidate::scores`/`emb`
// (coordinator::decode_shard_reply), then stacked here.
use crate::server::wire::{f32s_from_value, f32s_to_value};
#[cfg(test)]
use crate::server::wire::{mat_from_value, mat_to_value};

/// How the coordinator combines per-shard results for a strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeKind {
    /// Global selection = top-k merge of per-worker top-k lists.
    ExactTopK { column: ScoreColumn, ascending: bool },
    /// Global selection = strategy re-run over the oversampled candidate
    /// union (needs embeddings on the wire).
    Refine,
    /// Coordinator-side sampling over the global non-failed index set
    /// (workers only report their failure lists).
    Random,
}

/// Merge protocol for a zoo strategy name; `None` for unknown names
/// (including `auto`, which needs the agent workflow, as on the single
/// server).
pub fn merge_kind(strategy: &str) -> Option<MergeKind> {
    match strategy {
        "random" => Some(MergeKind::Random),
        "least_confidence" => Some(MergeKind::ExactTopK {
            column: ScoreColumn::LeastConfidence,
            ascending: false,
        }),
        "margin_confidence" => {
            Some(MergeKind::ExactTopK { column: ScoreColumn::Margin, ascending: true })
        }
        "ratio_confidence" => {
            Some(MergeKind::ExactTopK { column: ScoreColumn::Ratio, ascending: false })
        }
        "entropy" => {
            Some(MergeKind::ExactTopK { column: ScoreColumn::Entropy, ascending: false })
        }
        "k_center_greedy" | "core_set" | "dbal" => Some(MergeKind::Refine),
        _ => None,
    }
}

/// Best-first comparison matching `util::topk`'s total order: better
/// scores first (direction per `ascending`), NaN strictly after every
/// finite score.
fn cmp_best_first(a: f32, b: f32, ascending: bool) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => {
            if ascending {
                a.partial_cmp(&b).unwrap()
            } else {
                b.partial_cmp(&a).unwrap()
            }
        }
    }
}

/// Exact top-`budget` over `(global index, score)` candidates, best-first,
/// deterministic (ties break on ascending index, NaN never beats finite).
pub fn merge_exact_topk(
    candidates: &[(usize, f32)],
    budget: usize,
    ascending: bool,
) -> Vec<usize> {
    let mut v: Vec<(usize, f32)> = candidates.to_vec();
    v.sort_by(|a, b| cmp_best_first(a.1, b.1, ascending).then_with(|| a.0.cmp(&b.0)));
    v.truncate(budget);
    v.into_iter().map(|(i, _)| i).collect()
}

/// Stack a candidate union's per-row score/embedding vectors into the
/// `[N, 4]` / `[N, D]` matrices the refine pass consumes — shared by the
/// plain `query` merge and the agent arm's distributed select so the two
/// cannot drift.
pub fn refine_inputs(all: &[&Candidate]) -> (Mat, Mat) {
    let scores = Mat::from_rows(all.iter().map(|c| c.scores.as_slice()));
    let emb = Mat::from_rows(all.iter().map(|c| c.emb.as_slice()));
    (scores, emb)
}

/// One worker-reported candidate. `idx` is a *local* pool index on the
/// wire; the coordinator rewrites it to a global index via the shard plan
/// before merging.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub idx: usize,
    /// Strategy-relevant scalar (the merge column) for exact top-k.
    pub score: f32,
    /// Full `[NUM_SCORES]` row (refine protocol only).
    pub scores: Vec<f32>,
    /// Embedding row (refine protocol only).
    pub emb: Vec<f32>,
}

impl Candidate {
    pub fn to_value(&self, with_embeddings: bool) -> Value {
        let mut m = Map::new();
        m.insert("idx", Value::from(self.idx));
        m.insert("score", Value::Number(self.score as f64));
        if with_embeddings {
            m.insert("scores", f32s_to_value(&self.scores));
            m.insert("emb", f32s_to_value(&self.emb));
        }
        Value::Object(m)
    }

    pub fn from_value(v: &Value) -> Result<Candidate, String> {
        let idx = v
            .get("idx")
            .and_then(Value::as_usize)
            .ok_or("candidate missing idx")?;
        // non-finite scores serialize as JSON null; decode back to NaN so
        // the merge order still puts them last.
        let score = match v.get("score") {
            Some(Value::Number(n)) => *n as f32,
            _ => f32::NAN,
        };
        Ok(Candidate {
            idx,
            score,
            scores: v.get("scores").map(f32s_from_value).transpose()?.unwrap_or_default(),
            emb: v.get("emb").map(f32s_from_value).transpose()?.unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mat::Mat;
    use crate::util::topk;

    /// Split scores into shards, take each shard's local top-k, merge, and
    /// compare to the global single-machine top-k — the tentpole's exact
    /// parity argument in miniature.
    #[test]
    fn prop_merge_matches_global_topk() {
        crate::util::prop::check("merge-topk-parity", 60, |rng| {
            let n = 1 + rng.below(200);
            let k = rng.below(n + 3);
            let n_shards = 1 + rng.below(5);
            let mut scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            // inject duplicates and NaN
            for _ in 0..n / 4 {
                let (a, b) = (rng.below(n), rng.below(n));
                scores[a] = scores[b];
            }
            if n > 2 {
                scores[rng.below(n)] = f32::NAN;
            }
            for ascending in [false, true] {
                let want = if ascending {
                    topk::top_k_asc(&scores, k)
                } else {
                    topk::top_k_desc(&scores, k)
                };
                // strided shards (ascending within each shard)
                let mut union: Vec<(usize, f32)> = Vec::new();
                for s in 0..n_shards {
                    let local: Vec<usize> = (s..n).step_by(n_shards).collect();
                    let local_scores: Vec<f32> =
                        local.iter().map(|&i| scores[i]).collect();
                    let local_top = if ascending {
                        topk::top_k_asc(&local_scores, k)
                    } else {
                        topk::top_k_desc(&local_scores, k)
                    };
                    for rel in local_top {
                        union.push((local[rel], local_scores[rel]));
                    }
                }
                let got = merge_exact_topk(&union, k, ascending);
                crate::prop_assert!(
                    got == want,
                    "asc={ascending} n={n} k={k} shards={n_shards}: {got:?} != {want:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn merge_never_prefers_nan() {
        let cands = vec![(0, f32::NAN), (1, 0.1), (2, f32::NAN), (3, 0.7)];
        assert_eq!(merge_exact_topk(&cands, 2, false), vec![3, 1]);
        assert_eq!(merge_exact_topk(&cands, 2, true), vec![1, 3]);
        // NaN only fills leftover slots
        assert_eq!(merge_exact_topk(&cands, 3, false), vec![3, 1, 0]);
    }

    #[test]
    fn merge_ties_break_on_index() {
        let cands = vec![(9, 1.0), (2, 1.0), (5, 1.0)];
        assert_eq!(merge_exact_topk(&cands, 2, false), vec![2, 5]);
    }

    #[test]
    fn merge_kind_covers_the_zoo() {
        for name in crate::strategies::zoo_names() {
            assert!(merge_kind(name).is_some(), "no merge kind for {name}");
        }
        assert!(merge_kind("auto").is_none());
        assert!(merge_kind("nonsense").is_none());
        assert_eq!(merge_kind("core_set"), Some(MergeKind::Refine));
        assert_eq!(
            merge_kind("margin_confidence"),
            Some(MergeKind::ExactTopK { column: ScoreColumn::Margin, ascending: true })
        );
    }

    #[test]
    fn candidate_roundtrips_through_json() {
        let c = Candidate {
            idx: 17,
            score: 0.25,
            scores: vec![0.1, 0.2, 0.3, 0.4],
            emb: vec![1.5, -2.5],
        };
        let text = crate::json::to_string(&c.to_value(true));
        let back = Candidate::from_value(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, c);
        // slim form drops the heavy fields
        let slim =
            Candidate::from_value(&crate::json::parse(
                &crate::json::to_string(&c.to_value(false)),
            )
            .unwrap())
            .unwrap();
        assert_eq!(slim.idx, 17);
        assert!(slim.emb.is_empty());
    }

    #[test]
    fn nan_score_survives_the_wire_as_nan() {
        let c = Candidate { idx: 1, score: f32::NAN, scores: vec![], emb: vec![] };
        let text = crate::json::to_string(&c.to_value(false));
        let back = Candidate::from_value(&crate::json::parse(&text).unwrap()).unwrap();
        assert!(back.score.is_nan());
    }

    #[test]
    fn mat_roundtrips_through_json() {
        let m = Mat::from_vec(vec![1.0, 2.5, -3.0, 0.125, 4.0, 5.0], 2, 3);
        let text = crate::json::to_string(&mat_to_value(&m));
        let back = mat_from_value(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        assert!(mat_from_value(&crate::json::parse("{\"rows\":2}").unwrap()).is_err());
    }
}

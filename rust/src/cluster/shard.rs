//! Shard planning: how one pushed pool is split across N workers.
//!
//! A plan maps every global pool position to exactly one shard, keeps the
//! per-shard index lists ascending (so a worker's local tie-breaks agree
//! with global tie-breaks — the exact-merge proof in `merge` depends on
//! this), and balances shard sizes within one sample of each other.

use crate::config::ShardPolicy;

/// Assignment of global pool indices to shards. `shards[i]` holds the
/// (ascending) global indices scanned by shard `i`; shards may be empty
/// when there are more workers than samples.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub shards: Vec<Vec<usize>>,
}

impl ShardPlan {
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(Vec::len).collect()
    }

    pub fn total(&self) -> usize {
        self.shards.iter().map(Vec::len).sum()
    }
}

/// Split `0..n_items` into `n_shards` parts under `policy`.
pub fn plan(n_items: usize, n_shards: usize, policy: ShardPolicy) -> ShardPlan {
    assert!(n_shards >= 1, "plan needs >= 1 shard");
    let mut shards: Vec<Vec<usize>> = (0..n_shards).map(|_| Vec::new()).collect();
    match policy {
        ShardPolicy::Contiguous => {
            // first (n_items % n_shards) shards get one extra item
            let base = n_items / n_shards;
            let extra = n_items % n_shards;
            let mut next = 0usize;
            for (i, shard) in shards.iter_mut().enumerate() {
                let take = base + usize::from(i < extra);
                shard.extend(next..next + take);
                next += take;
            }
        }
        ShardPolicy::Strided => {
            for j in 0..n_items {
                shards[j % n_shards].push(j);
            }
        }
    }
    ShardPlan { shards }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_partition(p: &ShardPlan, n: usize) {
        let mut all: Vec<usize> = p.shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>(), "not a partition of 0..{n}");
        for (i, s) in p.shards.iter().enumerate() {
            assert!(s.windows(2).all(|w| w[0] < w[1]), "shard {i} not ascending: {s:?}");
        }
    }

    #[test]
    fn contiguous_partitions_and_balances() {
        for (n, k) in [(10, 3), (12, 4), (1, 1), (7, 7), (100, 6)] {
            let p = plan(n, k, ShardPolicy::Contiguous);
            assert_eq!(p.shards.len(), k);
            assert_partition(&p, n);
            let sizes = p.shard_sizes();
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced {sizes:?}");
        }
    }

    #[test]
    fn strided_partitions_and_balances() {
        for (n, k) in [(10, 3), (12, 4), (5, 8)] {
            let p = plan(n, k, ShardPolicy::Strided);
            assert_partition(&p, n);
            let sizes = p.shard_sizes();
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced {sizes:?}");
        }
        // stride shape: shard 0 of 3 gets 0, 3, 6, ...
        let p = plan(7, 3, ShardPolicy::Strided);
        assert_eq!(p.shards[0], vec![0, 3, 6]);
        assert_eq!(p.shards[1], vec![1, 4]);
    }

    #[test]
    fn more_shards_than_items_leaves_empties() {
        let p = plan(2, 5, ShardPolicy::Contiguous);
        assert_partition(&p, 2);
        assert_eq!(p.shard_sizes().iter().filter(|&&s| s == 0).count(), 3);
        assert_eq!(plan(0, 3, ShardPolicy::Strided).total(), 0);
    }
}

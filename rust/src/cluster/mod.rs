//! Coordinator/worker cluster: scale one AL session across N replica
//! servers (DESIGN.md §Cluster).
//!
//! The paper's server–client design (§3.2, Fig 1) runs one `AlServer`
//! per box; the ROADMAP's north star is heavy multi-user traffic, and the
//! biggest remaining lever on end-to-end AL latency is scanning one
//! pushed pool on N machines at once. This subsystem adds a second
//! serving topology on top of the framed RPC protocol (JSON v1 or the
//! binary tensor data plane, DESIGN.md §Wire):
//!
//! * [`membership`] — live membership: heartbeat/lease auto-discovery, a
//!   generation-numbered view, and the rendezvous rebalance planner that
//!   re-maps pool rows when workers join, die, or return mid-session.
//! * [`shard`] — deterministic shard plans (contiguous / strided) mapping
//!   global pool positions onto workers (the static-config layout).
//! * [`worker`] — the worker role: any `AlServer` already dispatches the
//!   worker-facing `scan_shard` / `select_shard` / `drop_session`
//!   methods; this module adds coordinator registration and the
//!   candidate-building logic.
//! * [`coordinator`] — the `AlClient`-compatible front: scatter on
//!   `push_data`, scatter-gather with failure-aware re-dispatch on
//!   `query`, per-shard scan metrics and a straggler gauge.
//! * [`merge`] — distributed strategy semantics: exact top-k merge for
//!   the uncertainty strategies (provably identical to the single-server
//!   selection), coordinator-side sampling for `random`, and a
//!   candidate-then-refine pass for the diversity/hybrid strategies.
//! * [`recovery`] — crash recovery: the WAL record vocabulary the
//!   coordinator appends through [`crate::durable`] and the pure replay
//!   fold that rebuilds sessions and in-flight PSHEA jobs on restart.
//! * [`tenancy`] — the multi-tenant service policy: session registry
//!   (tokens, quotas) and the weighted-fair admission gate with load
//!   shedding in front of the scatter path.

pub mod coordinator;
pub mod membership;
pub mod merge;
pub(crate) mod recovery;
pub mod shard;
pub mod tenancy;
pub mod worker;

pub use coordinator::{Coordinator, CoordinatorDeps};
pub use membership::{Membership, MembershipConfig, MsClock, View};
pub use merge::{merge_kind, MergeKind};
pub use shard::{plan, ShardPlan};
pub use tenancy::{AdmissionGate, AdmitPermit, TenantInfo, TenantRegistry};
pub use worker::{register_with, Heartbeater};

//! Coordinator crash recovery: the WAL record vocabulary and the pure
//! replay fold (DESIGN.md §Durability).
//!
//! The coordinator's durable state is an ordered stream of small JSON
//! records appended to a [`crate::durable::SharedLog`] *before* the
//! operation they describe is acknowledged. This module owns both ends
//! of that contract:
//!
//! * **Constructors** (`rec_*`) — the only place record shapes are
//!   written, so the append sites and the replay can never skew.
//! * **[`fold`]** — a pure function from a [`Replay`] (snapshot +
//!   uncovered records) to [`Recovered`]: the sessions to re-install,
//!   the PSHEA jobs to resume or report, and the monotonic high-waters
//!   (view generation, push epoch) a restarted coordinator must not
//!   regress below. Pure on purpose: replay is testable without a
//!   cluster, and a snapshot is literally a compacted record list run
//!   through the same `apply` as the live log.
//!
//! Job streams and the resume point: each arm-round appends a
//! `job_spend` (the labeled rows the arm just bought) then a
//! `job_record` (its measured accuracy); end-of-round appends
//! `job_elim`s and one `job_round` marker. Replay resumes from the last
//! `job_round` marker — records and spends past it belong to a round the
//! crash interrupted, and are discarded so the resumed loop re-runs that
//! round deterministically (same seed derivation, same picks). A
//! `job_resume` marker records that truncation durably, so a second
//! crash replays the same decision instead of mixing two half-rounds.
//!
//! Records that fail to apply (an unknown tag from a newer version, a
//! malformed field) are skipped with a warning, never a panic: recovery
//! degrades record-by-record, exactly like the torn-tail contract one
//! layer down.

use std::collections::BTreeMap;

use crate::agent::job::{self, EliminatedArm, JobState, JobStatus};
use crate::agent::{PsheaObserver, PsheaTrace, RoundRecord};
use crate::durable::{Replay, SharedLog};
use crate::json::{value::obj, Map, Value};
use crate::store::Manifest;
use std::sync::Arc;

// ---------------------------------------------------------------------------
// Record constructors.

/// A session exists (created or replaced by `push_data`).
pub(crate) fn rec_session(
    session: &str,
    manifest: &Manifest,
    init_labels: Option<&[u8]>,
) -> Value {
    let mut m = Map::new();
    m.insert("t", Value::from("session"));
    m.insert("session", Value::from(session));
    m.insert("manifest", manifest.to_value());
    m.insert(
        "init_labels",
        match init_labels {
            Some(l) => Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect()),
            None => Value::Null,
        },
    );
    Value::Object(m)
}

/// A session's shard-layout identifiers moved (push or rebalance
/// install). Only the monotonic identifiers are durable — concrete
/// shard→worker ownership is rebuilt from live membership after a
/// restart, so persisting it would only pin dead workers.
pub(crate) fn rec_layout(session: &str, epoch: u64, view_gen: u64, next_sid: u64) -> Value {
    obj([
        ("t", Value::from("layout")),
        ("session", Value::from(session)),
        ("epoch", Value::from(epoch)),
        ("view_gen", Value::from(view_gen)),
        ("next_sid", Value::from(next_sid)),
    ])
}

/// The membership view generation advanced.
pub(crate) fn rec_view(generation: u64) -> Value {
    obj([("t", Value::from("view")), ("generation", Value::from(generation))])
}

/// A tenant session was registered (`session_create`, or the implicit
/// auto-registration of a legacy plain-name push). The minted token is
/// durable so handles held by clients keep working across a restart.
pub(crate) fn rec_tenant(
    session: &str,
    token: &str,
    weight: u64,
    max_workers: usize,
    explicit: bool,
) -> Value {
    obj([
        ("t", Value::from("tenant")),
        ("session", Value::from(session)),
        ("token", Value::from(token)),
        ("weight", Value::from(weight)),
        ("max_workers", Value::from(max_workers)),
        ("explicit", Value::Bool(explicit)),
    ])
}

/// A session was closed (`session_close`): the quota slot is free and
/// the session's data-plane state is gone — replay must not resurrect
/// either.
pub(crate) fn rec_session_close(session: &str) -> Value {
    obj([("t", Value::from("session_close")), ("session", Value::from(session))])
}

/// A PSHEA job was accepted (logged before the `agent_start` reply).
/// Carries everything a restart needs to re-drive the loop: the oracle
/// label arrays ride along because they exist only in the original
/// request.
#[allow(clippy::too_many_arguments)]
pub(crate) fn rec_job_start(
    job: &str,
    session: &str,
    strategies: &[String],
    cfg_value: Value,
    seed: u64,
    pool_labels: &[u8],
    test_labels: &[u8],
    wait_ms: u64,
) -> Value {
    let labels = |l: &[u8]| Value::Array(l.iter().map(|&x| Value::from(x as u64)).collect());
    obj([
        ("t", Value::from("job_start")),
        ("job", Value::from(job)),
        ("session", Value::from(session)),
        (
            "strategies",
            Value::Array(strategies.iter().map(|s| Value::from(s.clone())).collect()),
        ),
        ("config", cfg_value),
        ("seed", Value::from(seed)),
        ("pool_labels", labels(pool_labels)),
        ("test_labels", labels(test_labels)),
        ("wait_ms", Value::from(wait_ms)),
    ])
}

/// One arm bought labels for `picked` (global pool indices, pick order).
pub(crate) fn rec_job_spend(job: &str, strategy: &str, picked: &[usize]) -> Value {
    obj([
        ("t", Value::from("job_spend")),
        ("job", Value::from(job)),
        ("strategy", Value::from(strategy)),
        ("picked", Value::Array(picked.iter().map(|&i| Value::from(i)).collect())),
    ])
}

/// One arm finished one round (accuracy measured).
pub(crate) fn rec_job_record(job: &str, rec: &RoundRecord) -> Value {
    obj([
        ("t", Value::from("job_record")),
        ("job", Value::from(job)),
        ("record", job::record_to_value(rec)),
    ])
}

/// An arm was eliminated at the end of `round`.
pub(crate) fn rec_job_elim(
    job: &str,
    strategy: &str,
    round: usize,
    predicted: f64,
    observed: f64,
) -> Value {
    obj([
        ("t", Value::from("job_elim")),
        ("job", Value::from(job)),
        ("strategy", Value::from(strategy)),
        ("round", Value::from(round)),
        ("predicted", Value::Number(predicted)),
        ("observed", Value::Number(observed)),
    ])
}

/// Round `round` fully completed — the resume point marker.
pub(crate) fn rec_job_round(job: &str, round: usize) -> Value {
    obj([
        ("t", Value::from("job_round")),
        ("job", Value::from(job)),
        ("round", Value::from(round)),
    ])
}

/// Restart recovery resumed this job from `from_round` completed rounds,
/// discarding anything the crash left beyond them.
pub(crate) fn rec_job_resume(job: &str, from_round: usize) -> Value {
    obj([
        ("t", Value::from("job_resume")),
        ("job", Value::from(job)),
        ("from_round", Value::from(from_round)),
    ])
}

/// `agent_cancel` was acknowledged for this job.
pub(crate) fn rec_job_cancel(job: &str) -> Value {
    obj([("t", Value::from("job_cancel")), ("job", Value::from(job))])
}

/// The job reached a terminal state.
pub(crate) fn rec_job_done(job: &str, status: &str, trace: Option<&PsheaTrace>) -> Value {
    obj([
        ("t", Value::from("job_done")),
        ("job", Value::from(job)),
        ("status", Value::from(status)),
        ("trace", trace.map(trace_value).unwrap_or(Value::Null)),
    ])
}

/// Serialize a trace in the exact shape [`job::trace_from_value`] parses.
pub(crate) fn trace_value(t: &PsheaTrace) -> Value {
    obj([
        ("records", Value::Array(t.records.iter().map(job::record_to_value).collect())),
        (
            "survivors",
            Value::Array(t.survivors.iter().map(|s| Value::from(s.clone())).collect()),
        ),
        ("stop", Value::from(job::stop_to_str(t.stop))),
        ("total_budget", Value::from(t.total_budget)),
        ("best_accuracy", Value::Number(t.best_accuracy)),
        ("rounds", Value::from(t.rounds)),
    ])
}

// ---------------------------------------------------------------------------
// The replay fold.

/// A session as the WAL remembers it: manifest + monotonic identifiers.
/// Shards are rebuilt from live membership after restart (lazy re-home).
pub(crate) struct RecoveredSession {
    pub manifest: Manifest,
    pub init_labels: Option<Vec<u8>>,
    pub epoch: u64,
    pub view_gen: u64,
    pub next_sid: u64,
}

/// A PSHEA job as replay reconstructed it. For an in-flight job (no
/// `done`), `records`/`eliminated`/`spends` hold only the completed-round
/// prefix after [`fold`] finishes — the partial final round is already
/// discarded.
pub(crate) struct RecoveredJob {
    pub id: String,
    pub session: String,
    pub strategies: Vec<String>,
    /// Serialized config overlay ([`job::config_from_value`] input).
    pub config: Value,
    pub seed: u64,
    pub pool_labels: Vec<u8>,
    pub test_labels: Vec<u8>,
    pub wait_ms: u64,
    pub records: Vec<RoundRecord>,
    pub eliminated: Vec<EliminatedArm>,
    /// Fully completed rounds (last `job_round` marker + 1).
    pub completed_rounds: usize,
    /// Per-strategy labeled picks, one entry per completed arm-round.
    pub spends: BTreeMap<String, Vec<Vec<usize>>>,
    pub cancelled: bool,
    /// `(status string, trace value)` once the job finished pre-crash.
    pub done: Option<(String, Option<Value>)>,
    /// Every job-scoped record after `job_start`, verbatim and in
    /// physical replay order — **never** truncated by resume logic,
    /// because it re-seeds the job's push-event buffer and WAL mirror:
    /// a reconnecting subscriber's sequence numbers must keep counting
    /// exactly what the durable log holds (DESIGN.md §Events).
    pub raw: Vec<Value>,
}

impl RecoveredJob {
    /// Keep only the first `rounds` completed rounds: records, spends and
    /// eliminations past them belong to a crash-interrupted round.
    fn truncate_to(&mut self, rounds: usize) {
        self.completed_rounds = rounds;
        self.records.retain(|r| r.round < rounds);
        self.eliminated.retain(|e| e.round < rounds);
        let counts: BTreeMap<String, usize> = self
            .strategies
            .iter()
            .map(|s| (s.clone(), self.records.iter().filter(|r| &r.strategy == s).count()))
            .collect();
        for (s, sp) in self.spends.iter_mut() {
            sp.truncate(counts.get(s).copied().unwrap_or(0));
        }
    }

    /// Completed rounds this arm has run (its `restore_arm` round count).
    pub(crate) fn arm_rounds(&self, strategy: &str) -> u64 {
        self.records.iter().filter(|r| r.strategy == strategy).count() as u64
    }

    /// Every row this arm labeled, in pick order.
    pub(crate) fn arm_picks(&self, strategy: &str) -> Vec<usize> {
        self.spends.get(strategy).map(|v| v.concat()).unwrap_or_default()
    }

    /// Strategies not yet eliminated in the kept prefix.
    pub(crate) fn live(&self) -> Vec<String> {
        self.strategies
            .iter()
            .filter(|s| !self.records.iter().any(|r| &r.strategy == *s && r.eliminated))
            .cloned()
            .collect()
    }

    /// A [`JobState`] for this job under `status`, from the kept prefix.
    /// Total spend is summed from the per-arm cumulative ledgers, so it
    /// stays honest even for an interrupted job.
    pub(crate) fn state_as(&self, status: JobStatus) -> JobState {
        let mut per_arm: BTreeMap<&str, usize> = BTreeMap::new();
        for r in &self.records {
            let e = per_arm.entry(r.strategy.as_str()).or_insert(0);
            *e = (*e).max(r.budget_spent);
        }
        JobState {
            status,
            strategies: self.strategies.clone(),
            live: self.live(),
            eliminated: self.eliminated.clone(),
            records: self.records.clone(),
            rounds: self.completed_rounds,
            budget_spent: per_arm.values().sum(),
            best_accuracy: self.records.iter().fold(0.0, |a, r| a.max(r.accuracy)),
            trace: None,
        }
    }

    /// The [`JobState`] for a job that reached a terminal state before
    /// the crash (`None` for in-flight jobs). A `done` trace that no
    /// longer parses degrades to `Interrupted` — ledger kept, no panic.
    pub(crate) fn terminal_state(&self) -> Option<JobState> {
        let (status, trace_v) = self.done.as_ref()?;
        Some(match status.as_str() {
            "done" => {
                match trace_v.as_ref().map(job::trace_from_value) {
                    Some(Ok(trace)) => {
                        let mut s = self.state_as(JobStatus::Done);
                        s.live = trace.survivors.clone();
                        s.records = trace.records.clone();
                        s.rounds = trace.rounds;
                        s.budget_spent = trace.total_budget;
                        s.best_accuracy = trace.best_accuracy;
                        s.trace = Some(trace);
                        s
                    }
                    _ => self.state_as(JobStatus::Interrupted),
                }
            }
            "cancelled" => self.state_as(JobStatus::Cancelled),
            other => match other.strip_prefix("failed: ") {
                Some(e) => self.state_as(JobStatus::Failed(e.to_string())),
                None => self.state_as(JobStatus::Interrupted),
            },
        })
    }
}

/// A tenant as the WAL remembers it — mirrors
/// [`super::tenancy::TenantInfo`] field for field.
pub(crate) struct RecoveredTenant {
    pub name: String,
    pub token: String,
    pub weight: u64,
    pub max_workers: usize,
    pub explicit: bool,
}

/// Everything [`fold`] reconstructs from one replay.
pub(crate) struct Recovered {
    pub sessions: Vec<(String, RecoveredSession)>,
    /// Tenant registry entries (tokens survive restart).
    pub tenants: Vec<RecoveredTenant>,
    pub jobs: Vec<RecoveredJob>,
    /// Highest membership view generation the WAL observed.
    pub view_gen: u64,
    /// Highest push epoch observed (`None` when no session survived).
    pub max_epoch: Option<u64>,
    /// Records applied (snapshot + log), for `recovery.replayed_records`.
    pub replayed: u64,
    /// Records skipped as unreplayable (version skew, malformed).
    pub skipped: u64,
}

/// Replay a [`Replay`] into [`Recovered`]. The snapshot's state is itself
/// a `{"records": [...]}` list (a compacted log) run through the same
/// per-record apply as the live records that follow it.
pub(crate) fn fold(replay: &Replay) -> Recovered {
    let mut out = Recovered {
        sessions: Vec::new(),
        tenants: Vec::new(),
        jobs: Vec::new(),
        view_gen: 0,
        max_epoch: None,
        replayed: 0,
        skipped: 0,
    };
    let snap_records: &[Value] = replay
        .snapshot
        .as_ref()
        .and_then(|s| s.get("records"))
        .and_then(Value::as_array)
        .unwrap_or(&[]);
    for v in snap_records.iter().chain(replay.records.iter()) {
        out.replayed += 1;
        if let Err(e) = apply(&mut out, v) {
            out.skipped += 1;
            crate::log_warn!("durable", "skipping unreplayable WAL record: {e}");
        }
    }
    // in-flight jobs: discard the crash-interrupted partial round
    for j in out.jobs.iter_mut().filter(|j| j.done.is_none()) {
        let completed = j.completed_rounds;
        j.truncate_to(completed);
    }
    out
}

fn str_of(v: &Value, k: &str) -> Result<String, String> {
    v.get(k)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("record missing string '{k}'"))
}

fn u64_of(v: &Value, k: &str) -> Result<u64, String> {
    v.get(k)
        .and_then(Value::as_i64)
        .filter(|&x| x >= 0)
        .map(|x| x as u64)
        .ok_or_else(|| format!("record missing u64 '{k}'"))
}

fn usize_of(v: &Value, k: &str) -> Result<usize, String> {
    v.get(k).and_then(Value::as_usize).ok_or_else(|| format!("record missing usize '{k}'"))
}

fn labels_of(v: &Value) -> Result<Vec<u8>, String> {
    v.as_array()
        .ok_or("labels not an array")?
        .iter()
        .map(|x| {
            x.as_usize()
                .filter(|&c| c <= u8::MAX as usize)
                .map(|c| c as u8)
                .ok_or_else(|| "bad label value".to_string())
        })
        .collect()
}

fn job_mut<'a>(out: &'a mut Recovered, v: &Value) -> Result<&'a mut RecoveredJob, String> {
    let id = str_of(v, "job")?;
    out.jobs
        .iter_mut()
        .find(|j| j.id == id)
        .ok_or_else(|| format!("record for unknown job '{id}' (no job_start replayed)"))
}

fn apply(out: &mut Recovered, v: &Value) -> Result<(), String> {
    match v.get("t").and_then(Value::as_str).ok_or("record has no 't' tag")? {
        "session" => {
            let name = str_of(v, "session")?;
            let manifest =
                Manifest::from_value(v.get("manifest").ok_or("session record missing manifest")?)
                    .map_err(|e| e.to_string())?;
            let init_labels = match v.get("init_labels") {
                None | Some(Value::Null) => None,
                Some(x) => Some(labels_of(x)?),
            };
            let rs = RecoveredSession {
                manifest,
                init_labels,
                epoch: 0,
                view_gen: 0,
                next_sid: 0,
            };
            match out.sessions.iter_mut().find(|(n, _)| n == &name) {
                Some((_, s)) => *s = rs, // re-push replaces
                None => out.sessions.push((name, rs)),
            }
        }
        "layout" => {
            let name = str_of(v, "session")?;
            let (epoch, view_gen, next_sid) =
                (u64_of(v, "epoch")?, u64_of(v, "view_gen")?, u64_of(v, "next_sid")?);
            let (_, s) = out
                .sessions
                .iter_mut()
                .find(|(n, _)| n == &name)
                .ok_or_else(|| format!("layout for unknown session '{name}'"))?;
            s.epoch = epoch;
            s.view_gen = s.view_gen.max(view_gen);
            s.next_sid = s.next_sid.max(next_sid);
            out.view_gen = out.view_gen.max(view_gen);
            out.max_epoch = Some(out.max_epoch.map_or(epoch, |m| m.max(epoch)));
        }
        "view" => out.view_gen = out.view_gen.max(u64_of(v, "generation")?),
        "tenant" => {
            let t = RecoveredTenant {
                name: str_of(v, "session")?,
                token: str_of(v, "token")?,
                weight: u64_of(v, "weight")?.max(1),
                max_workers: usize_of(v, "max_workers")?,
                explicit: v.get("explicit").and_then(Value::as_bool).unwrap_or(true),
            };
            match out.tenants.iter_mut().find(|e| e.name == t.name) {
                Some(e) => *e = t, // idempotent re-create updates in place
                None => out.tenants.push(t),
            }
        }
        "session_close" => {
            let name = str_of(v, "session")?;
            out.tenants.retain(|t| t.name != name);
            out.sessions.retain(|(n, _)| n != &name);
        }
        "job_start" => {
            let id = str_of(v, "job")?;
            let strategies = v
                .get("strategies")
                .and_then(Value::as_array)
                .ok_or("job_start missing strategies")?
                .iter()
                .map(|x| x.as_str().map(str::to_string).ok_or("bad strategy".to_string()))
                .collect::<Result<Vec<_>, _>>()?;
            let j = RecoveredJob {
                id,
                session: str_of(v, "session")?,
                strategies,
                config: v.get("config").cloned().unwrap_or(Value::Null),
                seed: u64_of(v, "seed")?,
                pool_labels: labels_of(
                    v.get("pool_labels").ok_or("job_start missing pool_labels")?,
                )?,
                test_labels: labels_of(
                    v.get("test_labels").ok_or("job_start missing test_labels")?,
                )?,
                wait_ms: u64_of(v, "wait_ms")?,
                records: Vec::new(),
                eliminated: Vec::new(),
                completed_rounds: 0,
                spends: BTreeMap::new(),
                cancelled: false,
                done: None,
                raw: Vec::new(),
            };
            match out.jobs.iter_mut().find(|e| e.id == j.id) {
                Some(e) => *e = j,
                None => out.jobs.push(j),
            }
        }
        "job_spend" => {
            let strategy = str_of(v, "strategy")?;
            let picked = v
                .get("picked")
                .and_then(Value::as_array)
                .ok_or("job_spend missing picked")?
                .iter()
                .map(|x| x.as_usize().ok_or("bad picked index".to_string()))
                .collect::<Result<Vec<_>, _>>()?;
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            j.spends.entry(strategy).or_default().push(picked);
        }
        "job_record" => {
            let rec =
                job::record_from_value(v.get("record").ok_or("job_record missing record")?)?;
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            j.records.push(rec);
        }
        "job_elim" => {
            let arm = EliminatedArm {
                strategy: str_of(v, "strategy")?,
                round: usize_of(v, "round")?,
                predicted: v.get("predicted").and_then(Value::as_f64).unwrap_or(0.0),
                observed: v.get("observed").and_then(Value::as_f64).unwrap_or(0.0),
            };
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            // the live `job_record` append predates the end-of-round
            // elimination verdict; stamp it in so the kept prefix carries
            // the flag exactly like an in-memory trace would
            if let Some(r) = j
                .records
                .iter_mut()
                .rev()
                .find(|r| r.strategy == arm.strategy && r.round == arm.round)
            {
                r.eliminated = true;
            }
            j.eliminated.push(arm);
        }
        "job_round" => {
            let round = usize_of(v, "round")?;
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            j.completed_rounds = j.completed_rounds.max(round + 1);
        }
        "job_resume" => {
            let from = usize_of(v, "from_round")?;
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            j.truncate_to(from);
        }
        "job_cancel" => {
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            j.cancelled = true;
        }
        "job_done" => {
            let status = str_of(v, "status")?;
            let trace = match v.get("trace") {
                None | Some(Value::Null) => None,
                Some(t) => Some(t.clone()),
            };
            let j = job_mut(out, v)?;
            j.raw.push(v.clone());
            j.done = Some((status, trace));
        }
        other => return Err(format!("unknown record type '{other}'")),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// The live-loop WAL observer.

/// [`PsheaObserver`] that appends every loop event to the coordinator's
/// WAL — teed *before* the slot observer by `job::drive_with`, so an
/// event is durable before it is observable. Appends are best-effort:
/// a full disk degrades durability (logged loudly), never the job.
/// Each append is also mirrored into the job slot's in-memory record
/// list, the raw material a forced mid-job snapshot embeds so a
/// `max_wal_bytes` compaction cannot orphan a running job.
pub(crate) struct WalObserver {
    pub wal: Arc<SharedLog>,
    pub job: String,
    pub slot: Arc<job::JobSlot>,
}

impl WalObserver {
    fn append(&self, rec: Value) {
        // mirror push under the log lock: the forced byte-cap compaction
        // captures mirrors atomically with its rotation, so the record
        // must land on the same side of the rotation point in both
        self.wal.append_best_effort_with(&rec, || self.slot.wal_mirror(&rec));
    }
}

impl PsheaObserver for WalObserver {
    fn on_record(&mut self, rec: &RoundRecord) {
        self.append(rec_job_record(&self.job, rec));
    }

    fn on_eliminated(&mut self, strategy: &str, round: usize, predicted: f64, observed: f64) {
        self.append(rec_job_elim(&self.job, strategy, round, predicted, observed));
    }

    fn on_round(&mut self, round: usize, _live: &[String], _total: usize, _a_max: f64) {
        self.append(rec_job_round(&self.job, round));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::SampleRef;

    fn manifest(pool: usize) -> Manifest {
        let refs = |n: usize, tag: &str| -> Vec<SampleRef> {
            (0..n)
                .map(|i| SampleRef { id: i as u32, uri: format!("mem://{tag}/{i}") })
                .collect()
        };
        Manifest {
            name: "m".into(),
            num_classes: 2,
            img_dim: 4,
            init: refs(2, "init"),
            pool: refs(pool, "pool"),
            test: refs(2, "test"),
        }
    }

    fn record(strategy: &str, round: usize, spent: usize, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            strategy: strategy.into(),
            budget_spent: spent,
            accuracy: acc,
            predicted_next: None,
            eliminated: false,
        }
    }

    fn start_record(id: &str) -> Value {
        rec_job_start(
            id,
            "sess",
            &["a".to_string(), "b".to_string()],
            Value::Null,
            7,
            &[0, 1, 0, 1],
            &[1, 0],
            50,
        )
    }

    fn replay_of(records: Vec<Value>) -> Replay {
        Replay { snapshot: None, records, torn_bytes: 0 }
    }

    #[test]
    fn fold_rebuilds_sessions_and_high_waters() {
        let m = manifest(4);
        let r = fold(&replay_of(vec![
            rec_view(3),
            rec_session("s1", &m, Some(&[0, 1])),
            rec_layout("s1", 2, 5, 4),
            rec_session("s2", &m, None),
            rec_layout("s2", 6, 1, 2),
            // re-push of s1 replaces it and advances the epoch
            rec_session("s1", &m, Some(&[1, 1])),
            rec_layout("s1", 7, 8, 9),
        ]));
        assert_eq!(r.skipped, 0);
        assert_eq!(r.replayed, 7);
        assert_eq!(r.sessions.len(), 2);
        let s1 = &r.sessions.iter().find(|(n, _)| n == "s1").unwrap().1;
        assert_eq!(s1.epoch, 7);
        assert_eq!(s1.next_sid, 9);
        assert_eq!(s1.init_labels.as_deref(), Some(&[1u8, 1][..]));
        assert_eq!(r.view_gen, 8, "view high-water tracks layout view_gens too");
        assert_eq!(r.max_epoch, Some(7));
    }

    #[test]
    fn fold_rebuilds_tenants_and_honors_session_close() {
        let m = manifest(4);
        let r = fold(&replay_of(vec![
            rec_tenant("alpha", "tok-aaaa", 3, 2, true),
            rec_tenant("beta", "tok-bbbb", 1, 0, false),
            rec_session("alpha", &m, None),
            rec_layout("alpha", 1, 0, 2),
            rec_session("beta", &m, None),
            rec_layout("beta", 2, 0, 2),
            // idempotent re-create updates the entry in place
            rec_tenant("alpha", "tok-aaaa", 5, 1, true),
            // closing beta removes both its tenant slot and its session
            rec_session_close("beta"),
        ]));
        assert_eq!(r.skipped, 0);
        assert_eq!(r.tenants.len(), 1);
        let t = &r.tenants[0];
        assert_eq!((t.name.as_str(), t.token.as_str()), ("alpha", "tok-aaaa"));
        assert_eq!((t.weight, t.max_workers, t.explicit), (5, 1, true));
        assert_eq!(r.sessions.len(), 1, "closed session must not be resurrected");
        assert_eq!(r.sessions[0].0, "alpha");
    }

    #[test]
    fn fold_discards_the_crash_interrupted_partial_round() {
        let recs = vec![
            start_record("job-3"),
            // round 0 completes for both arms
            rec_job_spend("job-3", "a", &[0, 1]),
            rec_job_record("job-3", &record("a", 0, 2, 0.5)),
            rec_job_spend("job-3", "b", &[2, 3]),
            rec_job_record("job-3", &record("b", 0, 2, 0.4)),
            rec_job_round("job-3", 0),
            // round 1: arm a spent and recorded, b spent, then crash
            rec_job_spend("job-3", "a", &[5, 6]),
            rec_job_record("job-3", &record("a", 1, 4, 0.6)),
            rec_job_spend("job-3", "b", &[7, 8]),
        ];
        let r = fold(&replay_of(recs));
        let j = &r.jobs[0];
        assert!(j.done.is_none());
        assert_eq!(j.completed_rounds, 1);
        assert_eq!(j.records.len(), 2, "round-1 record discarded");
        assert_eq!(j.arm_picks("a"), vec![0, 1], "round-1 spend discarded with its round");
        assert_eq!(j.arm_picks("b"), vec![2, 3]);
        assert_eq!(j.arm_rounds("a"), 1);
        let s = j.state_as(JobStatus::Interrupted);
        assert_eq!(s.budget_spent, 4, "ledger sums per-arm cumulative spend");
        assert_eq!(s.rounds, 1);
    }

    #[test]
    fn resume_marker_truncates_before_second_run_records_apply() {
        // first run reached round 1 (partial), recovery resumed from 1,
        // second run re-ran round 1 with its own spends — replaying the
        // whole stream must keep exactly one copy of round 1
        let recs = vec![
            start_record("job-1"),
            rec_job_spend("job-1", "a", &[0]),
            rec_job_record("job-1", &record("a", 0, 1, 0.5)),
            rec_job_spend("job-1", "b", &[1]),
            rec_job_record("job-1", &record("b", 0, 1, 0.4)),
            rec_job_round("job-1", 0),
            rec_job_spend("job-1", "a", &[2]), // interrupted round 1
            rec_job_resume("job-1", 1),
            rec_job_spend("job-1", "a", &[3]), // the re-run picks differently-framed rows
            rec_job_record("job-1", &record("a", 1, 2, 0.6)),
            rec_job_spend("job-1", "b", &[4]),
            rec_job_record("job-1", &record("b", 1, 2, 0.5)),
            rec_job_elim("job-1", "b", 1, 0.51, 0.5),
            rec_job_round("job-1", 1),
        ];
        let r = fold(&replay_of(recs));
        let j = &r.jobs[0];
        assert_eq!(j.completed_rounds, 2);
        assert_eq!(j.arm_picks("a"), vec![0, 3], "pre-crash partial spend dropped");
        assert_eq!(j.arm_picks("b"), vec![1, 4]);
        assert_eq!(j.live(), vec!["a".to_string()], "elimination stamped onto the record");
        assert_eq!(j.eliminated.len(), 1);
        assert!(j.records.iter().any(|x| x.strategy == "b" && x.round == 1 && x.eliminated));
    }

    #[test]
    fn terminal_jobs_and_unknown_records_round_trip() {
        let trace = PsheaTrace {
            records: vec![record("a", 0, 2, 0.9)],
            survivors: vec!["a".into()],
            stop: crate::agent::StopReason::TargetReached,
            total_budget: 4,
            best_accuracy: 0.9,
            rounds: 1,
        };
        let recs = vec![
            start_record("job-9"),
            rec_job_done("job-9", "done", Some(&trace)),
            obj([("t", Value::from("from_the_future")), ("x", Value::from(1))]),
            rec_job_spend("job-0", "a", &[1]), // job without a start: skipped
        ];
        let r = fold(&replay_of(recs));
        assert_eq!(r.skipped, 2);
        let s = r.jobs[0].terminal_state().unwrap();
        assert_eq!(s.status, JobStatus::Done);
        let t = s.trace.unwrap();
        assert_eq!(t.total_budget, 4);
        assert_eq!(t.survivors, vec!["a".to_string()]);
        // snapshots replay through the same apply: wrap the same records
        let snap = Replay {
            snapshot: Some(obj([(
                "records",
                Value::Array(vec![start_record("job-9"), rec_job_done("job-9", "cancelled", None)]),
            )])),
            records: vec![],
            torn_bytes: 0,
        };
        let r2 = fold(&snap);
        assert_eq!(r2.jobs[0].terminal_state().unwrap().status, JobStatus::Cancelled);
    }
}

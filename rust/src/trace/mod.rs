//! Span-based distributed tracing plane (DESIGN.md §Observability).
//!
//! A [`Tracer`] mints per-request trace ids and hierarchical spans. Spans
//! are RAII guards ([`SpanGuard`]): creating one installs its context in a
//! thread-local slot (so nested spans parent automatically and `log_*!`
//! lines pick up the trace id), dropping it records a [`SpanRecord`] —
//! start/end ns, parent id, name, `key=value` annotations — into a
//! fixed-size ring buffer. The ring is lock-light: one short mutexed push
//! per *completed* span; span creation touches only thread-locals and two
//! atomics, and a disabled tracer costs a single atomic load.
//!
//! Cross-process propagation rides the RPC envelope: requests carry
//! `trace: {id, parent}` (ignored by old peers, exactly like `hello`
//! negotiation — unknown envelope keys are skipped by every decoder) and
//! replies piggyback the callee's span subtree as `trace_spans`, which the
//! caller [`Tracer::adopt`]s so one `trace_get` on the coordinator yields
//! the full end-to-end tree. Cross-thread fan-out uses
//! [`Tracer::child_of`] with a [`SpanCtx`] captured before the spawn.
//!
//! Requests whose *root* span exceeds the configured `slow_query_ms` are
//! retained verbatim — the whole span tree, per-shard timings and
//! straggler annotations included — in a small bounded slow-query log
//! that survives ring eviction.
//!
//! Clock note: `start_ns` is relative to each process's own epoch, so
//! absolute offsets are only comparable within one process. Durations and
//! parent/child structure (what the tree rendering and self-times use)
//! are skew-free.

use std::cell::{Cell, RefCell};
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::json::{Map, Value};
use crate::util::logger;

/// Spans retained in the ring buffer by default.
pub const RING_CAP: usize = 4096;
/// Slow-query traces retained verbatim.
const SLOW_CAP: usize = 32;
/// Cap on spans piggybacked on one RPC reply (bounds reply growth on
/// deep fan-out; the callee's own ring still holds everything).
pub const MAX_PIGGYBACK: usize = 128;

/// A span's wire-propagatable identity: which trace, which span. The
/// all-zero value means "no active trace".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpanCtx {
    pub trace_id: u64,
    pub span_id: u64,
}

/// No active trace.
pub const NONE: SpanCtx = SpanCtx { trace_id: 0, span_id: 0 };

impl SpanCtx {
    pub fn is_active(&self) -> bool {
        self.trace_id != 0
    }
}

/// One completed span.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    /// Parent span id (0 = none).
    pub parent: u64,
    pub name: String,
    /// Nanoseconds since the owning process's trace epoch.
    pub start_ns: u64,
    pub end_ns: u64,
    /// `key=value` annotations, in insertion order.
    pub notes: Vec<(String, String)>,
    /// Entry span of a request that arrived without a remote parent —
    /// the unit the slow-query log triggers on.
    pub root: bool,
}

impl SpanRecord {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }

    /// Wire form. Ids are 48-bit by construction, so they survive the
    /// JSON number plane (f64) exactly.
    pub fn to_value(&self) -> Value {
        let mut m = Map::new();
        m.insert("trace", Value::from(self.trace_id));
        m.insert("span", Value::from(self.span_id));
        if self.parent != 0 {
            m.insert("parent", Value::from(self.parent));
        }
        m.insert("name", Value::from(self.name.as_str()));
        m.insert("start_ns", Value::from(self.start_ns));
        m.insert("dur_ns", Value::from(self.duration_ns()));
        if !self.notes.is_empty() {
            let mut notes = Map::new();
            for (k, v) in &self.notes {
                notes.insert(k.clone(), Value::from(v.as_str()));
            }
            m.insert("notes", Value::Object(notes));
        }
        Value::Object(m)
    }

    /// Lenient wire decode; `None` only when the identifying fields are
    /// missing (an old or foreign peer's extra keys are ignored).
    pub fn from_value(v: &Value) -> Option<SpanRecord> {
        let id = |k: &str| v.get(k).and_then(Value::as_i64).map(|x| x as u64);
        let start_ns = id("start_ns").unwrap_or(0);
        let mut notes = Vec::new();
        if let Some(o) = v.get("notes").and_then(Value::as_object) {
            for (k, nv) in o.iter() {
                let s = nv
                    .as_str()
                    .map(str::to_string)
                    .unwrap_or_else(|| crate::json::to_string(nv));
                notes.push((k.to_string(), s));
            }
        }
        Some(SpanRecord {
            trace_id: id("trace")?,
            span_id: id("span")?,
            parent: id("parent").unwrap_or(0),
            name: v.get("name").and_then(Value::as_str).unwrap_or("?").to_string(),
            start_ns,
            end_ns: start_ns.saturating_add(id("dur_ns").unwrap_or(0)),
            notes,
            root: false,
        })
    }
}

/// Wire form of a span list (the `trace_spans` reply field).
pub fn spans_to_value(spans: &[SpanRecord]) -> Value {
    Value::Array(spans.iter().map(SpanRecord::to_value).collect())
}

/// Lenient decode of a `trace_spans` field; malformed entries drop out.
pub fn spans_from_value(v: &Value) -> Vec<SpanRecord> {
    v.as_array()
        .map(|a| a.iter().filter_map(SpanRecord::from_value).collect())
        .unwrap_or_default()
}

/// Methods traced even when the caller sent no context (the request
/// entry points worth a root span); polls, heartbeats and `hello` stay
/// untraced so the ring holds work, not liveness chatter.
pub fn default_traced(method: &str) -> bool {
    matches!(
        method,
        "query" | "push_data" | "select_shard" | "scan_shard" | "fetch_rows" | "agent_start"
    )
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos().min(u64::MAX as u128) as u64
}

thread_local! {
    static CTX: Cell<SpanCtx> = const { Cell::new(SpanCtx { trace_id: 0, span_id: 0 }) };
    static COLLECT: RefCell<Option<Vec<SpanRecord>>> = const { RefCell::new(None) };
}

/// This thread's current span context (what `send_request_wire` stamps
/// onto outbound requests).
pub fn current() -> SpanCtx {
    CTX.with(|c| c.get())
}

/// Install `ctx` as this thread's current context (and sync the logger's
/// trace slot); returns the previous value so callers can restore it.
/// Span guards do this automatically — reach for it only when handing a
/// context to code that outlives the guard.
pub fn set_current(ctx: SpanCtx) -> SpanCtx {
    logger::set_trace(ctx.trace_id);
    CTX.with(|c| c.replace(ctx))
}

/// Start collecting every span completed on *this thread* until
/// [`take_collected`] — the RPC handler's reply-piggyback path.
pub fn begin_collect() {
    COLLECT.with(|c| *c.borrow_mut() = Some(Vec::new()));
}

/// Stop collecting and return the spans recorded since [`begin_collect`]
/// (empty when collection was never started).
pub fn take_collected() -> Vec<SpanRecord> {
    COLLECT.with(|c| c.borrow_mut().take()).unwrap_or_default()
}

fn collect(rec: &SpanRecord) {
    COLLECT.with(|c| {
        if let Some(v) = c.borrow_mut().as_mut() {
            v.push(rec.clone());
        }
    });
}

struct Ring {
    buf: Vec<Option<SpanRecord>>,
    /// Next write position.
    head: usize,
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) {
        let cap = self.buf.len();
        self.buf[self.head] = Some(rec);
        self.head = (self.head + 1) % cap;
    }

    fn newest_first(&self) -> impl Iterator<Item = &SpanRecord> {
        let cap = self.buf.len();
        (1..=cap).filter_map(move |i| self.buf[(self.head + cap - i) % cap].as_ref())
    }

    fn spans_for(&self, trace_id: u64) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> = self
            .buf
            .iter()
            .flatten()
            .filter(|r| r.trace_id == trace_id)
            .cloned()
            .collect();
        out.sort_by_key(|r| (r.start_ns, r.span_id));
        out
    }
}

/// One slow request, retained verbatim.
#[derive(Debug, Clone)]
pub struct SlowEntry {
    pub trace_id: u64,
    pub name: String,
    pub dur_ms: u64,
    /// The whole tree as captured at completion (per-shard timings and
    /// straggler annotations included).
    pub spans: Vec<SpanRecord>,
}

/// Process-wide span recorder: id minting, the span ring, and the
/// slow-query log.
pub struct Tracer {
    enabled: AtomicBool,
    /// Root spans at or above this duration are captured into the
    /// slow-query log (0 disables capture).
    slow_ms: u64,
    /// High 16 bits of every id minted here — distinguishes processes
    /// (and tracer instances) so adopted remote spans cannot collide.
    base: u64,
    next: AtomicU64,
    ring: Mutex<Ring>,
    slow: Mutex<Vec<SlowEntry>>,
}

impl Tracer {
    pub fn new(enabled: bool, slow_ms: u64) -> Tracer {
        Tracer::with_capacity(enabled, slow_ms, RING_CAP)
    }

    /// Test hook: a tiny ring makes wraparound observable.
    pub fn with_capacity(enabled: bool, slow_ms: u64, cap: usize) -> Tracer {
        let mut h = DefaultHasher::new();
        std::process::id().hash(&mut h);
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .subsec_nanos()
            .hash(&mut h);
        static SEQ: AtomicU64 = AtomicU64::new(0);
        SEQ.fetch_add(1, Ordering::Relaxed).hash(&mut h);
        // ids are 48-bit (16-bit instance tag + 32-bit sequence) so they
        // survive the JSON wire's f64 number plane exactly
        let base = (h.finish() & 0xffff) << 32;
        Tracer {
            enabled: AtomicBool::new(enabled),
            slow_ms,
            base,
            next: AtomicU64::new(1),
            ring: Mutex::new(Ring { buf: vec![None; cap.max(1)], head: 0 }),
            slow: Mutex::new(Vec::new()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn slow_query_ms(&self) -> u64 {
        self.slow_ms
    }

    fn mint(&self) -> u64 {
        self.base | (self.next.fetch_add(1, Ordering::Relaxed) & 0xffff_ffff)
    }

    /// Start a brand-new trace rooted at `name`.
    pub fn root(&self, name: &str) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard::inert();
        }
        let trace_id = self.mint();
        self.start_span(name, trace_id, 0, true)
    }

    /// Entry span for an inbound request: continues the remote context
    /// when one arrived, otherwise starts a new root trace.
    pub fn request(&self, name: &str, remote: SpanCtx) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard::inert();
        }
        if remote.is_active() {
            self.start_span(name, remote.trace_id, remote.span_id, false)
        } else {
            self.root(name)
        }
    }

    /// Child of this thread's current span; inert when no trace is
    /// active, so instrumentation costs nothing on untraced paths.
    pub fn child(&self, name: &str) -> SpanGuard<'_> {
        if !self.enabled() {
            return SpanGuard::inert();
        }
        let cur = current();
        if !cur.is_active() {
            return SpanGuard::inert();
        }
        self.start_span(name, cur.trace_id, cur.span_id, false)
    }

    /// Child of an explicit context — the cross-thread scatter form: a
    /// spawned thread has no inherited thread-local context, so the
    /// parent captures `ctx()` before the spawn and the spawned body
    /// opens its spans under it. The guard installs the context on the
    /// new thread for its lifetime.
    pub fn child_of(&self, ctx: SpanCtx, name: &str) -> SpanGuard<'_> {
        if !self.enabled() || !ctx.is_active() {
            return SpanGuard::inert();
        }
        self.start_span(name, ctx.trace_id, ctx.span_id, false)
    }

    fn start_span(&self, name: &str, trace_id: u64, parent: u64, root: bool) -> SpanGuard<'_> {
        let span_id = self.mint();
        let prev = set_current(SpanCtx { trace_id, span_id });
        SpanGuard {
            tracer: Some(self),
            rec: Some(SpanRecord {
                trace_id,
                span_id,
                parent,
                name: name.to_string(),
                start_ns: now_ns(),
                end_ns: 0,
                notes: Vec::new(),
                root,
            }),
            prev,
        }
    }

    fn record(&self, rec: SpanRecord) {
        collect(&rec);
        let slow = rec.root
            && self.slow_ms > 0
            && rec.duration_ns() >= self.slow_ms.saturating_mul(1_000_000);
        let captured = {
            let mut ring = self.ring.lock().unwrap();
            ring.push(rec.clone());
            if slow {
                Some(ring.spans_for(rec.trace_id))
            } else {
                None
            }
        };
        if let Some(spans) = captured {
            let mut log = self.slow.lock().unwrap();
            if log.len() >= SLOW_CAP {
                log.remove(0);
            }
            log.push(SlowEntry {
                trace_id: rec.trace_id,
                name: rec.name,
                dur_ms: rec.duration_ns() / 1_000_000,
                spans,
            });
        }
    }

    /// Fold spans piggybacked on an RPC reply into this tracer's ring so
    /// one `trace_get` here assembles the full cross-process tree. Remote
    /// entry spans lose their root flag: slow-query accounting belongs to
    /// the process that owns the request.
    pub fn adopt(&self, spans: Vec<SpanRecord>) {
        if !self.enabled() || spans.is_empty() {
            return;
        }
        let mut ring = self.ring.lock().unwrap();
        for mut rec in spans {
            rec.root = false;
            collect(&rec);
            ring.push(rec);
        }
    }

    /// `trace_recent` payload: newest root spans plus the slow-query log.
    pub fn recent(&self, limit: usize) -> Value {
        let limit = if limit == 0 { 20 } else { limit.min(200) };
        let mut roots = Vec::new();
        {
            let ring = self.ring.lock().unwrap();
            for rec in ring.newest_first() {
                if !rec.root {
                    continue;
                }
                let mut m = Map::new();
                m.insert("trace", Value::from(rec.trace_id));
                m.insert("name", Value::from(rec.name.as_str()));
                m.insert("dur_us", Value::from(rec.duration_ns() / 1_000));
                roots.push(Value::Object(m));
                if roots.len() >= limit {
                    break;
                }
            }
        }
        let slow: Vec<Value> = {
            let log = self.slow.lock().unwrap();
            log.iter()
                .rev()
                .map(|e| {
                    let mut m = Map::new();
                    m.insert("trace", Value::from(e.trace_id));
                    m.insert("name", Value::from(e.name.as_str()));
                    m.insert("dur_ms", Value::from(e.dur_ms));
                    m.insert("spans", Value::from(e.spans.len()));
                    Value::Object(m)
                })
                .collect()
        };
        let mut root = Map::new();
        root.insert("enabled", Value::from(self.enabled()));
        root.insert("slow_query_ms", Value::from(self.slow_ms));
        root.insert("roots", Value::Array(roots));
        root.insert("slow", Value::Array(slow));
        Value::Object(root)
    }

    /// Every retained span of `trace_id`, sorted by start time — from
    /// the live ring first, then the slow-query log (which keeps evicted
    /// traces verbatim).
    pub fn get(&self, trace_id: u64) -> Vec<SpanRecord> {
        let from_ring = self.ring.lock().unwrap().spans_for(trace_id);
        if !from_ring.is_empty() {
            return from_ring;
        }
        let log = self.slow.lock().unwrap();
        log.iter()
            .rev()
            .find(|e| e.trace_id == trace_id)
            .map(|e| e.spans.clone())
            .unwrap_or_default()
    }
}

/// RAII span: created by [`Tracer`] methods, recorded on drop. An inert
/// guard (tracing disabled / no active trace) does nothing and allocates
/// nothing.
pub struct SpanGuard<'a> {
    tracer: Option<&'a Tracer>,
    rec: Option<SpanRecord>,
    prev: SpanCtx,
}

impl<'a> SpanGuard<'a> {
    fn inert() -> SpanGuard<'a> {
        SpanGuard { tracer: None, rec: None, prev: NONE }
    }

    /// Attach a `key=value` annotation. On an inert guard the value is
    /// never even formatted.
    pub fn annotate(&mut self, key: &str, value: impl std::fmt::Display) {
        if let Some(rec) = &mut self.rec {
            rec.notes.push((key.to_string(), value.to_string()));
        }
    }

    /// This span's context (NONE when inert) — what scatter paths
    /// capture before spawning worker threads.
    pub fn ctx(&self) -> SpanCtx {
        self.rec
            .as_ref()
            .map(|r| SpanCtx { trace_id: r.trace_id, span_id: r.span_id })
            .unwrap_or(NONE)
    }

    pub fn is_active(&self) -> bool {
        self.rec.is_some()
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        if let Some(mut rec) = self.rec.take() {
            rec.end_ns = now_ns();
            set_current(self.prev);
            if let Some(t) = self.tracer {
                t.record(rec);
            }
        }
    }
}

/// Parse a `trace` request field: a JSON number or a hex string (as the
/// CLI and logs print trace ids).
pub fn parse_trace_param(params: &Value) -> Result<u64, String> {
    match params.get("trace") {
        Some(Value::String(s)) => u64::from_str_radix(s.trim_start_matches("0x"), 16)
            .map_err(|_| format!("bad hex trace id '{s}'")),
        Some(v) => v
            .as_i64()
            .map(|x| x as u64)
            .ok_or_else(|| "trace must be a number or hex string".to_string()),
        None => Err("missing param 'trace' (number or hex string)".to_string()),
    }
}

/// `trace_recent {n?}` handler body, shared by the single server and the
/// cluster coordinator so the RPC surfaces cannot drift.
pub fn rpc_recent(t: &Tracer, params: &Value) -> Value {
    t.recent(params.get("n").and_then(Value::as_usize).unwrap_or(0))
}

/// `trace_get {trace}` handler body: every retained span of one trace.
pub fn rpc_get(t: &Tracer, params: &Value) -> Result<Value, String> {
    let id = parse_trace_param(params)?;
    let spans = t.get(id);
    let mut m = Map::new();
    m.insert("trace", Value::from(id));
    m.insert("spans", spans_to_value(&spans));
    Ok(Value::Object(m))
}

/// Render an assembled span tree with per-stage self-times (`cli trace`).
/// Children sort by start time; a span whose parent is missing from the
/// set renders as a root.
pub fn render_tree(spans: &[SpanRecord]) -> String {
    let ids: HashSet<u64> = spans.iter().map(|s| s.span_id).collect();
    let mut children: HashMap<u64, Vec<usize>> = HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        if s.parent != 0 && s.parent != s.span_id && ids.contains(&s.parent) {
            children.entry(s.parent).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    for v in children.values_mut() {
        v.sort_by_key(|&i| (spans[i].start_ns, spans[i].span_id));
    }
    roots.sort_by_key(|&i| (spans[i].start_ns, spans[i].span_id));

    fn emit(
        out: &mut String,
        spans: &[SpanRecord],
        children: &HashMap<u64, Vec<usize>>,
        i: usize,
        depth: usize,
    ) {
        let s = &spans[i];
        let dur = s.duration_ns();
        let child_sum: u64 = children
            .get(&s.span_id)
            .map(|c| c.iter().map(|&j| spans[j].duration_ns()).sum())
            .unwrap_or(0);
        let _ = write!(out, "{:indent$}{}  {}us", "", s.name, dur / 1_000, indent = depth * 2);
        if child_sum > 0 {
            let _ = write!(out, " (self {}us)", dur.saturating_sub(child_sum) / 1_000);
        }
        for (k, v) in &s.notes {
            let _ = write!(out, " {k}={v}");
        }
        out.push('\n');
        if let Some(c) = children.get(&s.span_id) {
            for &j in c {
                emit(out, spans, children, j, depth + 1);
            }
        }
    }

    let mut out = String::new();
    for &i in &roots {
        emit(&mut out, spans, &children, i, 0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_survive_the_json_number_plane() {
        let t = Tracer::new(true, 0);
        for _ in 0..100 {
            assert!(t.mint() < (1u64 << 53), "ids must be exact as f64");
        }
    }

    #[test]
    fn span_nesting_links_parents_and_restores_context() {
        let t = Tracer::with_capacity(true, 0, 64);
        assert_eq!(current(), NONE);
        let (root_ctx, child_ctx) = {
            let root = t.root("query");
            let root_ctx = root.ctx();
            assert_eq!(current(), root_ctx);
            let child_ctx = {
                let mut child = t.child("scatter");
                child.annotate("shards", 2);
                assert_eq!(current(), child.ctx());
                child.ctx()
            };
            // child dropped: context pops back to the root span
            assert_eq!(current(), root_ctx);
            (root_ctx, child_ctx)
        };
        assert_eq!(current(), NONE, "all guards dropped");
        let spans = t.get(root_ctx.trace_id);
        assert_eq!(spans.len(), 2);
        let root = spans.iter().find(|s| s.name == "query").unwrap();
        let child = spans.iter().find(|s| s.name == "scatter").unwrap();
        assert!(root.root);
        assert_eq!(root.parent, 0);
        assert_eq!(child.parent, root_ctx.span_id);
        assert_eq!(child.span_id, child_ctx.span_id);
        assert_eq!(child.notes, vec![("shards".to_string(), "2".to_string())]);
        assert!(!child.root);
    }

    #[test]
    fn child_of_carries_context_across_threads() {
        let t = Tracer::with_capacity(true, 0, 64);
        let root = t.root("scatter");
        let ctx = root.ctx();
        std::thread::scope(|s| {
            s.spawn(|| {
                assert_eq!(current(), NONE, "spawned threads inherit nothing");
                let mut g = t.child_of(ctx, "select_shard");
                g.annotate("shard", 1);
                assert_eq!(current().trace_id, ctx.trace_id);
            });
        });
        drop(root);
        let spans = t.get(ctx.trace_id);
        assert_eq!(spans.len(), 2);
        let leaf = spans.iter().find(|s| s.name == "select_shard").unwrap();
        assert_eq!(leaf.parent, ctx.span_id);
    }

    #[test]
    fn ring_wraparound_keeps_newest_spans() {
        let t = Tracer::with_capacity(true, 0, 8);
        let mut traces = Vec::new();
        for i in 0..20 {
            let mut g = t.root("req");
            g.annotate("i", i);
            traces.push(g.ctx().trace_id);
        }
        // the first trace has been overwritten; the last survives
        assert!(t.get(traces[0]).is_empty(), "oldest span must be evicted");
        assert_eq!(t.get(traces[19]).len(), 1);
        // recent() sees at most the ring's capacity, newest first
        let recent = t.recent(50);
        let roots = recent.get("roots").unwrap().as_array().unwrap();
        assert_eq!(roots.len(), 8);
        assert_eq!(
            roots[0].get("trace").unwrap().as_i64().unwrap() as u64,
            traces[19],
            "newest first"
        );
    }

    #[test]
    fn disabled_tracer_is_inert_and_touches_no_context() {
        let t = Tracer::with_capacity(false, 500, 8);
        let outer = t.root("outer");
        assert!(!outer.is_active());
        assert_eq!(current(), NONE, "inert guards must not install context");
        let mut c = t.child("inner");
        c.annotate("k", "v");
        drop(c);
        drop(outer);
        let recent = t.recent(10);
        assert_eq!(recent.get("roots").unwrap().as_array().unwrap().len(), 0);
        assert_eq!(recent.get("enabled").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn collector_gathers_this_threads_spans() {
        let t = Tracer::with_capacity(true, 0, 64);
        begin_collect();
        let ctx = {
            let root = t.root("rpc.select_shard");
            let _c = t.child("candidates");
            root.ctx()
        };
        let collected = take_collected();
        assert_eq!(collected.len(), 2);
        // drop order: the child completes before the root
        assert_eq!(collected[0].name, "candidates");
        assert_eq!(collected[1].name, "rpc.select_shard");
        assert!(collected.iter().all(|s| s.trace_id == ctx.trace_id));
        // collection is one-shot
        assert!(take_collected().is_empty());
    }

    #[test]
    fn adopt_merges_remote_spans_without_root_flags() {
        let remote = Tracer::with_capacity(true, 0, 64);
        let local = Tracer::with_capacity(true, 0, 64);
        let local_root = local.root("query");
        let ctx = local_root.ctx();
        // remote side: a request span continuing our context
        begin_collect();
        drop(remote.request("rpc.select_shard", ctx));
        let shipped = take_collected();
        // wire round trip, then adoption
        let decoded = spans_from_value(&spans_to_value(&shipped));
        assert_eq!(decoded.len(), 1);
        local.adopt(decoded);
        drop(local_root);
        let spans = local.get(ctx.trace_id);
        assert_eq!(spans.len(), 2);
        let worker = spans.iter().find(|s| s.name == "rpc.select_shard").unwrap();
        assert_eq!(worker.parent, ctx.span_id, "remote span nests under ours");
        assert!(!worker.root, "adopted spans never trigger the local slow log");
    }

    #[test]
    fn span_value_roundtrip_is_lenient() {
        let rec = SpanRecord {
            trace_id: 7,
            span_id: 8,
            parent: 3,
            name: "scan".into(),
            start_ns: 100,
            end_ns: 400,
            notes: vec![("shard".into(), "2".into())],
            root: true,
        };
        let back = SpanRecord::from_value(&rec.to_value()).unwrap();
        assert_eq!(back.span_id, 8);
        assert_eq!(back.parent, 3);
        assert_eq!(back.duration_ns(), 300);
        assert_eq!(back.notes, rec.notes);
        assert!(!back.root, "root never crosses the wire");
        // garbage and old-peer shapes decode to nothing, not errors
        assert!(spans_from_value(&Value::Null).is_empty());
        assert!(spans_from_value(&Value::from("x")).is_empty());
        assert!(SpanRecord::from_value(&Value::from(3i64)).is_none());
    }

    #[test]
    fn slow_queries_are_captured_verbatim_and_survive_eviction() {
        let t = Tracer::with_capacity(true, 1, 4);
        let trace_id = {
            let root = t.root("query");
            let _child = t.child("scatter");
            std::thread::sleep(std::time::Duration::from_millis(3));
            root.ctx().trace_id
        };
        // flood the ring so the slow trace is evicted from it
        for _ in 0..10 {
            drop(t.root("noise"));
        }
        let spans = t.get(trace_id);
        assert_eq!(spans.len(), 2, "slow log retains the whole tree");
        let recent = t.recent(10);
        let slow = recent.get("slow").unwrap().as_array().unwrap();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].get("trace").unwrap().as_i64().unwrap() as u64, trace_id);
        assert!(slow[0].get("dur_ms").unwrap().as_i64().unwrap() >= 2);
    }

    #[test]
    fn fast_queries_skip_the_slow_log() {
        let t = Tracer::with_capacity(true, 10_000, 16);
        drop(t.root("query"));
        let recent = t.recent(10);
        assert_eq!(recent.get("slow").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn render_tree_nests_and_reports_self_time() {
        let mk = |span_id, parent, name: &str, start, end| SpanRecord {
            trace_id: 1,
            span_id,
            parent,
            name: name.into(),
            start_ns: start,
            end_ns: end,
            notes: vec![],
            root: parent == 0,
        };
        let mut spans = vec![
            mk(10, 0, "query", 0, 10_000_000),
            mk(11, 10, "scatter", 1_000_000, 7_000_000),
            mk(12, 11, "select_shard", 1_500_000, 4_000_000),
            mk(13, 11, "select_shard", 1_200_000, 5_000_000),
            mk(14, 10, "merge", 7_000_000, 9_000_000),
        ];
        spans[2].notes.push(("shard".into(), "1".into()));
        let text = render_tree(&spans);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines[0].starts_with("query"), "{text}");
        assert!(lines[1].starts_with("  scatter"), "{text}");
        // children order by start time: span 13 before span 12
        assert!(lines[2].starts_with("    select_shard"), "{text}");
        assert!(lines[3].contains("shard=1"), "{text}");
        assert!(lines[4].starts_with("  merge"), "{text}");
        // query: 10ms total, children 6ms + 2ms => self 2ms
        assert!(lines[0].contains("10000us"), "{text}");
        assert!(lines[0].contains("(self 2000us)"), "{text}");
        // an orphan (parent outside the set) renders as a root
        let orphan = vec![mk(20, 999, "lost", 0, 1_000)];
        assert!(render_tree(&orphan).starts_with("lost"));
    }

    #[test]
    fn disabled_tracing_overhead_under_five_percent_on_hot_path() {
        // The acceptance pin: with `[observability] trace = false`, the
        // per-request instrumentation (one inert guard + an annotation
        // around a JSON rpc-frame round trip, the micro-hot-path unit)
        // must cost < 5%. Min-of-N defeats scheduler noise.
        let t = Tracer::new(false, 500);
        let v = crate::json::parse(
            r#"{"id":42,"method":"query","params":{"session":"s1","budget":1000}}"#,
        )
        .unwrap();
        let iters = 3_000;
        let base = (0..7)
            .map(|_| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    let s = crate::json::to_string(&v);
                    std::hint::black_box(&s);
                }
                t0.elapsed()
            })
            .min()
            .unwrap();
        let traced = (0..7)
            .map(|_| {
                let t0 = Instant::now();
                for _ in 0..iters {
                    let mut g = t.child("rpc.query");
                    g.annotate("budget", 1000);
                    let s = crate::json::to_string(&v);
                    std::hint::black_box(&s);
                    drop(g);
                }
                t0.elapsed()
            })
            .min()
            .unwrap();
        assert!(
            traced.as_secs_f64() <= base.as_secs_f64() * 1.05 + 2e-4,
            "disabled tracing overhead too high: base {base:?} traced {traced:?}"
        );
    }
}

//! PSHEA — Predictive-based Successive Halving Early-stop (Algorithm 1).
//!
//! The loop controller runs every candidate strategy as an independent AL
//! arm; each round every *live* strategy selects + labels `round_budget`
//! samples, retrains, and reports evaluation accuracy. A
//! [`NegExpPredictor`] is fit to each arm's history to forecast its
//! next-round accuracy, and (while more than one arm is alive) the arm
//! with the *lowest forecast* is eliminated — successive halving with a
//! predictive, not observed, criterion. Stopping: target accuracy reached,
//! budget exhausted, or convergence (max accuracy stopped improving).

use super::predictor::NegExpPredictor;
use crate::runtime::backend::RtResult;

/// Controller knobs (Algorithm 1 inputs).
#[derive(Debug, Clone)]
pub struct PsheaConfig {
    /// Target accuracy `a_t`.
    pub target_accuracy: f64,
    /// Maximum total labeling budget `b_max` (across all live arms — the
    /// paper charges every arm's labeling to the user).
    pub max_budget: usize,
    /// Labels each live strategy gets per round.
    pub round_budget: usize,
    /// Convergence: this many consecutive rounds with < `converge_eps`
    /// improvement of the best accuracy stops the loop.
    pub converge_rounds: usize,
    pub converge_eps: f64,
    /// Hard cap on rounds (0 = unlimited); the paper's Fig 5 runs 8.
    pub max_rounds: usize,
    /// Observations each arm needs before elimination starts. The
    /// negative-exponential predictor needs 3 points to identify its
    /// asymptote; killing arms on 1-2 observations would just rank current
    /// accuracy, which is exactly the failure mode predictive elimination
    /// exists to avoid (crossing curves — see the crossing-curves test).
    pub min_history: usize,
    /// Pre-training accuracy `a_0` (Algorithm 1 initializes
    /// `a_max = a_0`); when the baseline already meets the target the loop
    /// stops before spending any budget.
    pub initial_accuracy: Option<f64>,
}

impl Default for PsheaConfig {
    fn default() -> Self {
        PsheaConfig {
            target_accuracy: 0.95,
            max_budget: 10_000,
            round_budget: 500,
            converge_rounds: 3,
            converge_eps: 0.002,
            max_rounds: 0,
            min_history: 3,
            initial_accuracy: None,
        }
    }
}

/// What the controller drives. `sim::AlExperiment` implements this for
/// real datasets; tests drive it with synthetic curves.
pub trait AlTask {
    /// One AL round for `strategy`: select + label `budget` samples from
    /// the pool, update the arm's model, return evaluation accuracy.
    /// Returns `None` accuracy when the arm's pool is exhausted.
    fn run_round(&mut self, strategy: &str, budget: usize) -> RtResult<Option<f64>>;
}

/// Per-round record of one arm.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    pub strategy: String,
    /// Cumulative labels this arm has consumed.
    pub budget_spent: usize,
    pub accuracy: f64,
    /// Next-round forecast (None in round 0: predictor needs 2 points).
    pub predicted_next: Option<f64>,
    /// True if the arm was eliminated at the end of this round.
    pub eliminated: bool,
}

/// Why the loop stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    TargetReached,
    BudgetExhausted,
    Converged,
    RoundLimit,
    PoolExhausted,
}

/// Full trace of a PSHEA run (what Fig 5b plots).
#[derive(Debug, Clone)]
pub struct PsheaTrace {
    pub records: Vec<RoundRecord>,
    /// Strategies still alive at stop, best first.
    pub survivors: Vec<String>,
    pub stop: StopReason,
    pub total_budget: usize,
    pub best_accuracy: f64,
    pub rounds: usize,
}

impl PsheaTrace {
    /// The agent's recommendation: best surviving strategy.
    pub fn recommendation(&self) -> Option<&str> {
        self.survivors.first().map(String::as_str)
    }

    /// Records of a given round.
    pub fn round(&self, r: usize) -> impl Iterator<Item = &RoundRecord> {
        self.records.iter().filter(move |rec| rec.round == r)
    }
}

/// Mid-run hook into the loop: the served agent job (`agent/job.rs`)
/// publishes progress through this so `agent_status` can report the round
/// log, live/eliminated arms, and budget while the job runs. All methods
/// default to no-ops; `()` is the null observer `run_pshea` uses.
pub trait PsheaObserver {
    /// One arm finished one round (the record is not yet marked
    /// eliminated — elimination is decided at end of round).
    fn on_record(&mut self, _rec: &RoundRecord) {}
    /// An arm was eliminated at the end of `round`: `predicted` is the
    /// forecast that killed it, `observed` its last measured accuracy.
    fn on_eliminated(&mut self, _strategy: &str, _round: usize, _predicted: f64, _observed: f64) {
    }
    /// A full round completed with `live` arms still in play.
    fn on_round(&mut self, _round: usize, _live: &[String], _total_budget: usize, _a_max: f64) {}
}

impl PsheaObserver for () {}

/// Run Algorithm 1 over `strategies` on `task`.
pub fn run_pshea(
    task: &mut dyn AlTask,
    strategies: &[String],
    cfg: &PsheaConfig,
) -> RtResult<PsheaTrace> {
    run_pshea_observed(task, strategies, cfg, &mut ())
}

/// [`run_pshea`] with a progress observer (the agent-job entry point).
pub fn run_pshea_observed(
    task: &mut dyn AlTask,
    strategies: &[String],
    cfg: &PsheaConfig,
    obs: &mut dyn PsheaObserver,
) -> RtResult<PsheaTrace> {
    run_pshea_resumed(task, strategies, cfg, &[], obs)
}

/// [`run_pshea_observed`] continuing from `prior`: the completed-round
/// records of an interrupted run (crash recovery, DESIGN.md §Durability).
/// The controller state — per-arm accuracy history, live set, `a_max`,
/// convergence stall counter, round number — is fully derivable from the
/// ordered record list plus the config, so it is reconstructed here and
/// the loop picks up exactly where the prior run's last *complete* round
/// left off. `task` must already hold the matching arm state (labeled
/// rows, retrained heads); the caller rebuilds it from the spend ledger.
/// With an empty `prior` this *is* `run_pshea_observed`. The observer
/// fires only for new events; the returned trace carries prior + new
/// records.
pub fn run_pshea_resumed(
    task: &mut dyn AlTask,
    strategies: &[String],
    cfg: &PsheaConfig,
    prior: &[RoundRecord],
    obs: &mut dyn PsheaObserver,
) -> RtResult<PsheaTrace> {
    assert!(!strategies.is_empty(), "need at least one candidate strategy");
    let mut live: Vec<String> = strategies
        .iter()
        .filter(|s| !prior.iter().any(|r| r.strategy == **s && r.eliminated))
        .cloned()
        .collect();
    let mut history: std::collections::BTreeMap<String, (Vec<f64>, Vec<f64>)> =
        strategies.iter().map(|s| (s.clone(), (vec![], vec![]))).collect();
    for rec in prior {
        let (xs, ys) = history
            .get_mut(&rec.strategy)
            .expect("prior record for a strategy not in the candidate set");
        xs.push(((xs.len() + 1) * cfg.round_budget) as f64);
        ys.push(rec.accuracy);
    }
    let mut records = prior.to_vec();
    let mut total_budget = prior.len() * cfg.round_budget;
    let round_count = prior.iter().map(|r| r.round + 1).max().unwrap_or(0);
    // a_max and the convergence stall counter are replayed round by round,
    // exactly as the live loop would have updated them
    let mut a_max = cfg.initial_accuracy.unwrap_or(0.0);
    let mut stall_rounds = 0usize;
    for r in 0..round_count {
        let prev_a_max = a_max;
        for rec in prior.iter().filter(|rec| rec.round == r) {
            a_max = a_max.max(rec.accuracy);
        }
        stall_rounds = if a_max - prev_a_max < cfg.converge_eps { stall_rounds + 1 } else { 0 };
    }
    let mut round = round_count;
    let stop;

    'outer: loop {
        // Stop checks (line 11-13 of Algorithm 1)
        if a_max >= cfg.target_accuracy {
            stop = StopReason::TargetReached;
            break;
        }
        if total_budget + live.len() * cfg.round_budget > cfg.max_budget && round > 0 {
            stop = StopReason::BudgetExhausted;
            break;
        }
        if cfg.converge_rounds > 0 && stall_rounds >= cfg.converge_rounds {
            stop = StopReason::Converged;
            break;
        }
        if cfg.max_rounds > 0 && round >= cfg.max_rounds {
            stop = StopReason::RoundLimit;
            break;
        }

        let prev_a_max = a_max;
        let mut predicted: Vec<(String, f64)> = Vec::new();
        for s in live.clone() {
            let acc = match task.run_round(&s, cfg.round_budget)? {
                Some(a) => a,
                None => {
                    stop = StopReason::PoolExhausted;
                    break 'outer;
                }
            };
            total_budget += cfg.round_budget;
            let (xs, ys) = history.get_mut(&s).unwrap();
            xs.push(((xs.len() + 1) * cfg.round_budget) as f64);
            ys.push(acc);
            a_max = a_max.max(acc);

            // forecast the arm's next round (line 17)
            let pred = NegExpPredictor::fit(xs, ys)
                .map(|p| p.predict(xs.last().unwrap() + cfg.round_budget as f64));
            predicted.push((s.clone(), pred.unwrap_or(acc)));
            records.push(RoundRecord {
                round,
                strategy: s.clone(),
                budget_spent: xs.len() * cfg.round_budget,
                accuracy: acc,
                predicted_next: pred,
                eliminated: false,
            });
            obs.on_record(records.last().unwrap());
        }

        // strategy-level early stopping (lines 22-24): drop the worst
        // forecast while >1 arm is alive and every arm has enough history
        // for the forecast to mean anything.
        let enough_history =
            live.iter().all(|s| history[s].0.len() >= cfg.min_history.max(1));
        if live.len() > 1 && enough_history {
            let worst = predicted
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .map(|(s, _)| s.clone())
                .expect("non-empty");
            live.retain(|s| *s != worst);
            if let Some(rec) = records
                .iter_mut()
                .rev()
                .find(|r| r.round == round && r.strategy == worst)
            {
                rec.eliminated = true;
            }
            let forecast = predicted
                .iter()
                .find(|(s, _)| *s == worst)
                .map(|(_, p)| *p)
                .unwrap_or(f64::NAN);
            let observed = history[&worst].1.last().copied().unwrap_or(f64::NAN);
            obs.on_eliminated(&worst, round, forecast, observed);
        }

        stall_rounds = if a_max - prev_a_max < cfg.converge_eps { stall_rounds + 1 } else { 0 };
        obs.on_round(round, &live, total_budget, a_max);
        round += 1;
    }

    // survivors ranked by their latest accuracy
    let mut survivors: Vec<(String, f64)> = live
        .into_iter()
        .map(|s| {
            let acc = history[&s].1.last().copied().unwrap_or(0.0);
            (s, acc)
        })
        .collect();
    survivors.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    Ok(PsheaTrace {
        records,
        survivors: survivors.into_iter().map(|(s, _)| s).collect(),
        stop,
        total_budget,
        best_accuracy: a_max,
        rounds: round,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic task: each strategy follows its own neg-exp curve.
    struct CurveTask {
        curves: std::collections::BTreeMap<String, (f64, f64, f64)>, // a_inf, a0, k
        spent: std::collections::BTreeMap<String, usize>,
        pool_left: usize,
    }

    impl CurveTask {
        fn new(curves: &[(&str, f64, f64, f64)]) -> Self {
            CurveTask {
                curves: curves
                    .iter()
                    .map(|(s, ai, a0, k)| (s.to_string(), (*ai, *a0, *k)))
                    .collect(),
                spent: Default::default(),
                pool_left: usize::MAX,
            }
        }
    }

    impl AlTask for CurveTask {
        fn run_round(&mut self, strategy: &str, budget: usize) -> RtResult<Option<f64>> {
            if self.pool_left < budget {
                return Ok(None);
            }
            self.pool_left -= budget;
            let spent = self.spent.entry(strategy.to_string()).or_insert(0);
            *spent += budget;
            let (a_inf, a0, k) = self.curves[strategy];
            Ok(Some(a_inf - (a_inf - a0) * (-k * (*spent as f64 - budget as f64)).exp()))
        }
    }

    fn cfg(rounds: usize) -> PsheaConfig {
        PsheaConfig {
            target_accuracy: 0.99,
            max_budget: 1_000_000,
            round_budget: 500,
            converge_rounds: 0,
            converge_eps: 0.0,
            max_rounds: rounds,
            min_history: 3,
            initial_accuracy: None,
        }
    }

    #[test]
    fn eliminates_one_arm_per_round_and_keeps_the_best() {
        let mut task = CurveTask::new(&[
            ("good", 0.95, 0.5, 0.002),
            ("mid", 0.85, 0.5, 0.002),
            ("bad", 0.70, 0.5, 0.002),
        ]);
        let strategies: Vec<String> =
            ["good", "mid", "bad"].iter().map(|s| s.to_string()).collect();
        let trace = run_pshea(&mut task, &strategies, &cfg(8)).unwrap();
        assert_eq!(trace.survivors, vec!["good".to_string()]);
        // min_history = 3: rounds 0-2 keep all 3 arms; elimination starts
        // at round 2, one arm per round after.
        assert_eq!(trace.round(0).count(), 3);
        assert_eq!(trace.round(1).count(), 3);
        assert_eq!(trace.round(2).count(), 3);
        assert_eq!(trace.round(3).count(), 2);
        assert_eq!(trace.round(4).count(), 1);
        // the first eliminated arm (round 2) must be 'bad'
        let elim2: Vec<&str> = trace
            .round(2)
            .filter(|r| r.eliminated)
            .map(|r| r.strategy.as_str())
            .collect();
        assert_eq!(elim2, vec!["bad"]);
        let elim3: Vec<&str> = trace
            .round(3)
            .filter(|r| r.eliminated)
            .map(|r| r.strategy.as_str())
            .collect();
        assert_eq!(elim3, vec!["mid"]);
        assert_eq!(trace.stop, StopReason::RoundLimit);
    }

    #[test]
    fn stops_on_target_accuracy() {
        let mut task = CurveTask::new(&[("fast", 0.99, 0.8, 0.01)]);
        let mut c = cfg(100);
        c.target_accuracy = 0.9;
        let trace = run_pshea(&mut task, &["fast".to_string()], &c).unwrap();
        assert_eq!(trace.stop, StopReason::TargetReached);
        assert!(trace.best_accuracy >= 0.9);
        assert!(trace.rounds < 100);
    }

    #[test]
    fn stops_on_budget() {
        let mut task = CurveTask::new(&[("slow", 0.9, 0.5, 0.00001)]);
        let mut c = cfg(0);
        c.max_budget = 1600; // 3 rounds of 500 fit, the 4th would exceed
        let trace = run_pshea(&mut task, &["slow".to_string()], &c).unwrap();
        assert_eq!(trace.stop, StopReason::BudgetExhausted);
        assert!(trace.total_budget <= 1600);
    }

    #[test]
    fn stops_on_convergence() {
        let mut task = CurveTask::new(&[("plateau", 0.72, 0.70, 0.05)]);
        let mut c = cfg(0);
        c.converge_rounds = 3;
        c.converge_eps = 0.002;
        let trace = run_pshea(&mut task, &["plateau".to_string()], &c).unwrap();
        assert_eq!(trace.stop, StopReason::Converged);
    }

    #[test]
    fn stops_when_pool_exhausted() {
        let mut task = CurveTask::new(&[("a", 0.9, 0.5, 0.001), ("b", 0.8, 0.5, 0.001)]);
        task.pool_left = 1700;
        let trace = run_pshea(
            &mut task,
            &["a".to_string(), "b".to_string()],
            &cfg(100),
        )
        .unwrap();
        assert_eq!(trace.stop, StopReason::PoolExhausted);
    }

    #[test]
    fn single_arm_never_eliminated() {
        let mut task = CurveTask::new(&[("only", 0.9, 0.5, 0.001)]);
        let trace = run_pshea(&mut task, &["only".to_string()], &cfg(5)).unwrap();
        assert!(trace.records.iter().all(|r| !r.eliminated));
        assert_eq!(trace.survivors, vec!["only".to_string()]);
    }

    #[test]
    fn crossing_curves_need_history_before_elimination() {
        // 'slow_start' ends higher but starts lower: with enough observed
        // rounds before the kill decision, the predictor should spare it.
        // (This is the paper's core claim: predictive elimination beats
        // eliminating on current accuracy.)
        let mut task = CurveTask::new(&[
            ("flash", 0.75, 0.70, 0.02), // starts high, saturates low
            ("slow_start", 0.95, 0.40, 0.0012), // starts low, ends high
        ]);
        let strategies: Vec<String> =
            ["flash", "slow_start"].iter().map(|s| s.to_string()).collect();
        let trace = run_pshea(&mut task, &strategies, &cfg(8)).unwrap();
        // flash's forecast saturates at ~0.75 while slow_start's keeps
        // climbing; the survivor must be slow_start.
        assert_eq!(trace.survivors, vec!["slow_start".to_string()]);
    }

    /// Full elimination order on crossing curves is pinned: the arm that
    /// saturates lowest goes first even though it *currently* leads, then
    /// the mid curve — refactors of Algorithm 1 cannot silently change
    /// which forecast loses.
    #[test]
    fn crossing_curves_elimination_order_is_pinned() {
        let mut task = CurveTask::new(&[
            ("flash", 0.75, 0.70, 0.02), // leads early, saturates at 0.75
            ("mid", 0.85, 0.55, 0.004),
            ("slow_start", 0.95, 0.40, 0.0012), // trails early, wins late
        ]);
        let strategies: Vec<String> =
            ["flash", "mid", "slow_start"].iter().map(|s| s.to_string()).collect();
        let trace = run_pshea(&mut task, &strategies, &cfg(8)).unwrap();
        let order: Vec<(usize, &str)> = trace
            .records
            .iter()
            .filter(|r| r.eliminated)
            .map(|r| (r.round, r.strategy.as_str()))
            .collect();
        assert_eq!(order, vec![(2, "flash"), (3, "mid")]);
        assert_eq!(trace.survivors, vec!["slow_start".to_string()]);
    }

    /// `min_history` delays the first kill: with 5 required observations
    /// no arm may be eliminated before round 4, and every earlier round
    /// runs the full field.
    #[test]
    fn min_history_guard_delays_elimination() {
        let mut task = CurveTask::new(&[
            ("good", 0.95, 0.5, 0.002),
            ("mid", 0.85, 0.5, 0.002),
            ("bad", 0.70, 0.5, 0.002),
        ]);
        let strategies: Vec<String> =
            ["good", "mid", "bad"].iter().map(|s| s.to_string()).collect();
        let mut c = cfg(8);
        c.min_history = 5;
        let trace = run_pshea(&mut task, &strategies, &c).unwrap();
        for r in 0..4 {
            assert_eq!(trace.round(r).count(), 3, "round {r} lost an arm early");
            assert!(
                trace.round(r).all(|rec| !rec.eliminated),
                "elimination before min_history at round {r}"
            );
        }
        let elim4: Vec<&str> = trace
            .round(4)
            .filter(|r| r.eliminated)
            .map(|r| r.strategy.as_str())
            .collect();
        assert_eq!(elim4, vec!["bad"]);
    }

    /// Algorithm 1 initializes `a_max = a_0`: a baseline that already
    /// meets the target stops the loop before any budget is spent.
    #[test]
    fn initial_accuracy_meeting_target_spends_nothing() {
        let mut task = CurveTask::new(&[("a", 0.9, 0.5, 0.001), ("b", 0.8, 0.5, 0.001)]);
        let mut c = cfg(8);
        c.target_accuracy = 0.95;
        c.initial_accuracy = Some(0.97);
        let strategies: Vec<String> = ["a", "b"].iter().map(|s| s.to_string()).collect();
        let trace = run_pshea(&mut task, &strategies, &c).unwrap();
        assert_eq!(trace.stop, StopReason::TargetReached);
        assert_eq!(trace.rounds, 0);
        assert_eq!(trace.total_budget, 0);
        assert!(trace.records.is_empty());
        assert!((trace.best_accuracy - 0.97).abs() < 1e-12);
        // no history -> survivor ranking is the stable input order
        assert_eq!(trace.survivors, strategies);
    }

    /// Budget exhaustion with identical arms: the stop fires before the
    /// over-budget round starts, and equal accuracies keep the *input*
    /// order (stable sort) — names chosen so alphabetical order would
    /// differ and expose a tie-break regression.
    #[test]
    fn budget_exhaustion_tie_break_keeps_input_order() {
        let mut task =
            CurveTask::new(&[("zeta", 0.7, 0.7, 0.0), ("alpha", 0.7, 0.7, 0.0)]);
        let strategies: Vec<String> =
            ["zeta", "alpha"].iter().map(|s| s.to_string()).collect();
        let mut c = cfg(0);
        c.max_budget = 2500; // 2 rounds of 2x500 fit; the 3rd would hit 3000
        let trace = run_pshea(&mut task, &strategies, &c).unwrap();
        assert_eq!(trace.stop, StopReason::BudgetExhausted);
        assert_eq!(trace.rounds, 2);
        assert_eq!(trace.total_budget, 2000);
        assert!(trace.total_budget <= c.max_budget);
        assert_eq!(trace.survivors, strategies, "tie must keep input order");
    }

    /// The observer sees the same story the trace tells: every record,
    /// every elimination (with the killing forecast), every round.
    #[test]
    fn observer_mirrors_trace() {
        #[derive(Default)]
        struct Spy {
            records: usize,
            eliminated: Vec<(String, usize)>,
            rounds: Vec<usize>,
            last_budget: usize,
        }
        impl PsheaObserver for Spy {
            fn on_record(&mut self, _rec: &RoundRecord) {
                self.records += 1;
            }
            fn on_eliminated(
                &mut self,
                strategy: &str,
                round: usize,
                predicted: f64,
                observed: f64,
            ) {
                assert!(predicted.is_finite() && observed.is_finite());
                self.eliminated.push((strategy.to_string(), round));
            }
            fn on_round(&mut self, round: usize, live: &[String], total: usize, _a: f64) {
                assert!(!live.is_empty());
                self.rounds.push(round);
                self.last_budget = total;
            }
        }
        let mut task = CurveTask::new(&[
            ("good", 0.95, 0.5, 0.002),
            ("bad", 0.70, 0.5, 0.002),
        ]);
        let strategies: Vec<String> =
            ["good", "bad"].iter().map(|s| s.to_string()).collect();
        let mut spy = Spy::default();
        let trace =
            run_pshea_observed(&mut task, &strategies, &cfg(6), &mut spy).unwrap();
        assert_eq!(spy.records, trace.records.len());
        let want_elim: Vec<(String, usize)> = trace
            .records
            .iter()
            .filter(|r| r.eliminated)
            .map(|r| (r.strategy.clone(), r.round))
            .collect();
        assert_eq!(spy.eliminated, want_elim);
        assert_eq!(spy.rounds, (0..trace.rounds).collect::<Vec<_>>());
        assert_eq!(spy.last_budget, trace.total_budget);
    }

    /// Crash-recovery invariant: cutting a finished run after any number
    /// of complete rounds and resuming from those records reproduces the
    /// uninterrupted trace bit for bit — records (incl. forecasts),
    /// elimination order, survivors, stop reason, budget.
    #[test]
    fn resumed_run_matches_uninterrupted_bit_for_bit() {
        let curves: &[(&str, f64, f64, f64)] = &[
            ("flash", 0.75, 0.70, 0.02),
            ("mid", 0.85, 0.55, 0.004),
            ("slow_start", 0.95, 0.40, 0.0012),
        ];
        let strategies: Vec<String> =
            ["flash", "mid", "slow_start"].iter().map(|s| s.to_string()).collect();
        let c = cfg(8);
        let full = run_pshea(&mut CurveTask::new(curves), &strategies, &c).unwrap();
        assert!(full.rounds >= 4, "test needs a multi-round run");
        for cut in 1..=full.rounds {
            let prior: Vec<RoundRecord> =
                full.records.iter().filter(|r| r.round < cut).cloned().collect();
            // rebuild the task's arm state as the job-resume path does:
            // re-apply each arm's spend ledger
            let mut task = CurveTask::new(curves);
            for rec in &prior {
                *task.spent.entry(rec.strategy.clone()).or_insert(0) += c.round_budget;
            }
            let resumed =
                run_pshea_resumed(&mut task, &strategies, &c, &prior, &mut ()).unwrap();
            assert_eq!(resumed.records, full.records, "cut at round {cut}");
            assert_eq!(resumed.survivors, full.survivors, "cut at round {cut}");
            assert_eq!(resumed.stop, full.stop, "cut at round {cut}");
            assert_eq!(resumed.total_budget, full.total_budget, "cut at round {cut}");
            assert_eq!(resumed.rounds, full.rounds, "cut at round {cut}");
            assert_eq!(resumed.best_accuracy, full.best_accuracy, "cut at round {cut}");
        }
    }

    /// The convergence stall counter survives a resume: a plateau run cut
    /// mid-stall still converges at the same round with the same trace.
    #[test]
    fn resume_replays_convergence_stall_state() {
        let curves: &[(&str, f64, f64, f64)] = &[("plateau", 0.72, 0.70, 0.05)];
        let strategies = vec!["plateau".to_string()];
        let mut c = cfg(0);
        c.converge_rounds = 3;
        c.converge_eps = 0.002;
        let full = run_pshea(&mut CurveTask::new(curves), &strategies, &c).unwrap();
        assert_eq!(full.stop, StopReason::Converged);
        for cut in 1..=full.rounds {
            let prior: Vec<RoundRecord> =
                full.records.iter().filter(|r| r.round < cut).cloned().collect();
            let mut task = CurveTask::new(curves);
            for rec in &prior {
                *task.spent.entry(rec.strategy.clone()).or_insert(0) += c.round_budget;
            }
            let resumed =
                run_pshea_resumed(&mut task, &strategies, &c, &prior, &mut ()).unwrap();
            assert_eq!(resumed.stop, StopReason::Converged, "cut at round {cut}");
            assert_eq!(resumed.rounds, full.rounds, "cut at round {cut}");
            assert_eq!(resumed.records, full.records, "cut at round {cut}");
        }
    }

    /// On resume the observer reports only the new rounds, while the
    /// returned trace still carries prior + new records.
    #[test]
    fn resume_observer_sees_only_new_events() {
        #[derive(Default)]
        struct Spy {
            records: usize,
            rounds: Vec<usize>,
        }
        impl PsheaObserver for Spy {
            fn on_record(&mut self, _rec: &RoundRecord) {
                self.records += 1;
            }
            fn on_round(&mut self, round: usize, _live: &[String], _t: usize, _a: f64) {
                self.rounds.push(round);
            }
        }
        let curves: &[(&str, f64, f64, f64)] =
            &[("good", 0.95, 0.5, 0.002), ("bad", 0.70, 0.5, 0.002)];
        let strategies: Vec<String> =
            ["good", "bad"].iter().map(|s| s.to_string()).collect();
        let c = cfg(6);
        let full = run_pshea(&mut CurveTask::new(curves), &strategies, &c).unwrap();
        let cut = 2;
        let prior: Vec<RoundRecord> =
            full.records.iter().filter(|r| r.round < cut).cloned().collect();
        let mut task = CurveTask::new(curves);
        for rec in &prior {
            *task.spent.entry(rec.strategy.clone()).or_insert(0) += c.round_budget;
        }
        let mut spy = Spy::default();
        let resumed =
            run_pshea_resumed(&mut task, &strategies, &c, &prior, &mut spy).unwrap();
        assert_eq!(spy.records, resumed.records.len() - prior.len());
        assert_eq!(spy.rounds, (cut..resumed.rounds).collect::<Vec<_>>());
    }

    #[test]
    fn total_budget_accounts_for_all_arms() {
        let mut task = CurveTask::new(&[
            ("a", 0.9, 0.5, 0.001),
            ("b", 0.8, 0.5, 0.001),
            ("c", 0.7, 0.5, 0.001),
        ]);
        let strategies: Vec<String> =
            ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        let trace = run_pshea(&mut task, &strategies, &cfg(4)).unwrap();
        // rounds 0-2: 3*500 each (min_history), round 3: 2*500
        assert_eq!(trace.total_budget, 3 * 1500 + 1000);
    }
}

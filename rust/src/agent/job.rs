//! Agent-as-a-service: the PSHEA loop as a background server job
//! (DESIGN.md §Agent).
//!
//! [`super::run_pshea`] stays the single Algorithm 1 implementation; this
//! module adds what *serving* it needs:
//!
//! * [`ArmSelect`] — the hook that routes each arm's per-round selection
//!   through the serving layers (the single server's candidate view, or
//!   the coordinator's scatter/merge across worker shards).
//! * [`AgentTask`] — an [`super::AlTask`] that replays the
//!   `sim::AlExperiment` round semantics (baseline head from the init
//!   split, per-round seed derivation via [`super::arm_round_seed`],
//!   oracle labeling, last-layer retrain, test-split evaluation) on top
//!   of that hook — the remote-vs-local parity tests pin the two
//!   implementations to each other.
//! * [`JobRegistry`] / [`JobSlot`] — cancellable, mid-run-queryable job
//!   state behind the `agent_start` / `agent_status` / `agent_result` /
//!   `agent_cancel` RPC family, shared by `AlServer` and the cluster
//!   coordinator so the two dispatchers cannot drift.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::runtime::backend::{ComputeBackend, RtResult, RuntimeError};
use crate::trainer::{self, LinearHead, TrainConfig};
use crate::util::mat::Mat;

use super::pshea::{
    run_pshea_resumed, AlTask, PsheaConfig, PsheaObserver, PsheaTrace, RoundRecord,
    StopReason,
};

/// Error text a cancelled job's select step surfaces; the drive wrapper
/// checks the cancel flag (not this string) to classify the outcome.
pub const CANCELLED: &str = "agent job cancelled";

/// One picked sample: global pool position plus its embedding row.
pub type Picked = (usize, Vec<f32>);

/// The serving-layer selection hook one agent arm round goes through.
pub trait ArmSelect: Send {
    /// Select `budget` unlabeled pool samples for one arm round.
    /// `exclude` holds the arm's already-labeled global pool positions in
    /// labeling order, `arm_labeled` their embeddings (same order, used
    /// as extra labeled context for the diversity strategies), and
    /// uncertainty scores are recomputed under the arm's current `head`.
    fn select_arm(
        &mut self,
        strategy: &str,
        budget: usize,
        head: &LinearHead,
        exclude: &[usize],
        arm_labeled: &Mat,
        seed: u64,
    ) -> Result<Vec<Picked>, String>;
}

/// Per-arm state the served loop keeps (Algorithm 1's `d^l` per strategy).
struct ArmState {
    /// Global pool positions labeled so far, in labeling order.
    labeled: Vec<usize>,
    /// Oracle labels parallel to `labeled`.
    labels: Vec<u8>,
    /// Embedding rows parallel to `labeled`.
    emb_rows: Vec<Vec<f32>>,
    head: LinearHead,
    /// Completed rounds (drives the per-round seed derivation).
    rounds: u64,
}

/// [`AlTask`] over a ready session's data + an [`ArmSelect`] hook.
pub struct AgentTask<S: ArmSelect> {
    sel: S,
    backend: Arc<dyn ComputeBackend>,
    /// Selectable (non-failed) pool size; bounds every arm's labeling.
    selectable_pool: usize,
    init_emb: Mat,
    init_labels: Vec<u8>,
    /// Oracle labels by global pool position (the label service the RPC
    /// carries in place of a human annotator).
    pool_labels: Vec<u8>,
    test_emb: Mat,
    test_labels: Vec<u8>,
    num_classes: usize,
    train_cfg: TrainConfig,
    seed: u64,
    cancel: Option<Arc<AtomicBool>>,
    /// When set, every arm round runs under an `agent.round` root span
    /// annotated with the arm/round ids (DESIGN.md §Observability), so
    /// the scatter RPCs the round issues assemble under one trace.
    tracer: Option<Arc<crate::trace::Tracer>>,
    baseline: Option<LinearHead>,
    arms: BTreeMap<String, ArmState>,
}

impl<S: ArmSelect> AgentTask<S> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        sel: S,
        backend: Arc<dyn ComputeBackend>,
        selectable_pool: usize,
        init_emb: Mat,
        init_labels: Vec<u8>,
        pool_labels: Vec<u8>,
        test_emb: Mat,
        test_labels: Vec<u8>,
        num_classes: usize,
        seed: u64,
        cancel: Option<Arc<AtomicBool>>,
    ) -> AgentTask<S> {
        assert_eq!(init_emb.rows(), init_labels.len(), "init emb/labels length");
        assert_eq!(test_emb.rows(), test_labels.len(), "test emb/labels length");
        AgentTask {
            sel,
            backend,
            selectable_pool,
            init_emb,
            init_labels,
            pool_labels,
            test_emb,
            test_labels,
            num_classes,
            train_cfg: TrainConfig::default(),
            seed,
            cancel,
            tracer: None,
            baseline: None,
            arms: BTreeMap::new(),
        }
    }

    /// Trace each arm round (and the selection RPCs it fans out) under a
    /// per-round root span.
    pub fn with_tracer(mut self, tracer: Arc<crate::trace::Tracer>) -> AgentTask<S> {
        self.tracer = Some(tracer);
        self
    }

    /// Head trained on the init split only (Algorithm 1 line 5) — every
    /// new arm starts from it, exactly like `sim::AlExperiment::baseline`.
    fn baseline_head(&mut self) -> RtResult<LinearHead> {
        if self.baseline.is_none() {
            let (h, _) = trainer::fit(
                self.backend.as_ref(),
                &self.init_emb,
                &self.init_labels,
                self.num_classes,
                &self.train_cfg,
            )?;
            self.baseline = Some(h);
        }
        Ok(self.baseline.clone().unwrap())
    }

    /// Rebuild one arm from a crash-recovery spend ledger (DESIGN.md
    /// §Durability): `labeled` holds the arm's picked global pool
    /// positions in labeling order across `rounds` completed rounds,
    /// `emb_rows` their embeddings re-fetched from the serving layer.
    /// Oracle labels are recomputed from the pool label service, and the
    /// head is retrained on init + the restored set — exactly the state
    /// the last completed round's retrain left behind, so the next
    /// `run_round` (seeded via `arm_round_seed(seed, rounds)`) behaves
    /// bit-identically to the uninterrupted run's.
    pub fn restore_arm(
        &mut self,
        strategy: &str,
        labeled: Vec<usize>,
        emb_rows: Vec<Vec<f32>>,
        rounds: u64,
    ) -> RtResult<()> {
        if labeled.len() != emb_rows.len() {
            return Err(RuntimeError::Shape(format!(
                "restore_arm '{strategy}': {} indices vs {} embedding rows",
                labeled.len(),
                emb_rows.len()
            )));
        }
        let labels = labeled
            .iter()
            .map(|&g| {
                self.pool_labels.get(g).copied().ok_or_else(|| {
                    RuntimeError::Shape(format!(
                        "restore_arm '{strategy}': index {g} outside pool labels"
                    ))
                })
            })
            .collect::<RtResult<Vec<u8>>>()?;
        let head = if labeled.is_empty() {
            self.baseline_head()?
        } else {
            let lab_mat = Mat::from_rows(emb_rows.iter().map(|r| r.as_slice()));
            let emb = self.init_emb.vstack(&lab_mat);
            let mut all = self.init_labels.clone();
            all.extend_from_slice(&labels);
            trainer::fit(self.backend.as_ref(), &emb, &all, self.num_classes, &self.train_cfg)?
                .0
        };
        self.arms.insert(
            strategy.to_string(),
            ArmState { labeled, labels, emb_rows, head, rounds },
        );
        Ok(())
    }
}

impl<S: ArmSelect> AlTask for AgentTask<S> {
    fn run_round(&mut self, strategy: &str, budget: usize) -> RtResult<Option<f64>> {
        if self.cancel.as_ref().is_some_and(|c| c.load(Ordering::SeqCst)) {
            return Err(RuntimeError::Pool(CANCELLED.into()));
        }
        // per-round root span: the selection RPCs this round fans out
        // inherit its context through the thread-local slot
        let tracer = self.tracer.clone();
        let mut span = tracer.as_deref().map(|t| t.root("agent.round"));
        if let Some(g) = span.as_mut() {
            g.annotate("arm", strategy);
            g.annotate("budget", budget);
        }
        let base = self.baseline_head()?;
        self.arms.entry(strategy.to_string()).or_insert_with(|| ArmState {
            labeled: vec![],
            labels: vec![],
            emb_rows: vec![],
            head: base,
            rounds: 0,
        });
        // snapshot the arm so the select call doesn't hold a borrow
        let (head, exclude, arm_mat, n_prev) = {
            let arm = self.arms.get(strategy).unwrap();
            if self.selectable_pool - arm.labeled.len() < budget {
                return Ok(None);
            }
            let arm_mat = if arm.emb_rows.is_empty() {
                Mat::zeros(0, self.init_emb.cols())
            } else {
                Mat::from_rows(arm.emb_rows.iter().map(|r| r.as_slice()))
            };
            (arm.head.clone(), arm.labeled.clone(), arm_mat, arm.rounds)
        };
        let seed = super::arm_round_seed(self.seed, n_prev);
        if let Some(g) = span.as_mut() {
            g.annotate("round", n_prev);
        }
        let picked = self
            .sel
            .select_arm(strategy, budget, &head, &exclude, &arm_mat, seed)
            .map_err(RuntimeError::Pool)?;
        if picked.len() < budget {
            return Ok(None); // candidate set ran dry mid-merge
        }
        // oracle labels the selection; the arm absorbs it
        let arm = self.arms.get_mut(strategy).unwrap();
        for (g, emb) in picked {
            let label = *self.pool_labels.get(g).ok_or_else(|| {
                RuntimeError::Shape(format!("picked index {g} outside pool labels"))
            })?;
            arm.labeled.push(g);
            arm.labels.push(label);
            arm.emb_rows.push(emb);
        }
        // retrain from scratch on init + the arm's labeled set, evaluate
        let lab_mat = Mat::from_rows(arm.emb_rows.iter().map(|r| r.as_slice()));
        let emb = self.init_emb.vstack(&lab_mat);
        let mut labels = self.init_labels.clone();
        labels.extend_from_slice(&arm.labels);
        let (new_head, _) = trainer::fit(
            self.backend.as_ref(),
            &emb,
            &labels,
            self.num_classes,
            &self.train_cfg,
        )?;
        let acc = trainer::evaluate(
            self.backend.as_ref(),
            &new_head,
            &self.test_emb,
            &self.test_labels,
        )?;
        let arm = self.arms.get_mut(strategy).unwrap();
        arm.head = new_head;
        arm.rounds += 1;
        Ok(Some(acc.top1))
    }
}

/// Lifecycle of a job slot.
#[derive(Debug, Clone, PartialEq)]
pub enum JobStatus {
    Running,
    Done,
    Cancelled,
    Failed(String),
    /// The serving process crashed mid-run and restart recovery could
    /// not resume the job (its session is gone, or bootstrap failed).
    /// Terminal, like `Failed`, but the spend ledger — every round
    /// record and labeled-row spend replayed from the WAL — stays
    /// queryable via `agent_status` (DESIGN.md §Durability).
    Interrupted,
}

impl JobStatus {
    pub fn as_string(&self) -> String {
        match self {
            JobStatus::Running => "running".into(),
            JobStatus::Done => "done".into(),
            JobStatus::Cancelled => "cancelled".into(),
            JobStatus::Failed(e) => format!("failed: {e}"),
            JobStatus::Interrupted => "interrupted".into(),
        }
    }
}

/// Why/when an arm left the field, as `agent_status` reports it.
#[derive(Debug, Clone)]
pub struct EliminatedArm {
    pub strategy: String,
    pub round: usize,
    /// The forecast that killed it.
    pub predicted: f64,
    /// Its last observed accuracy.
    pub observed: f64,
}

/// Queryable mid-run state of one job.
#[derive(Debug)]
pub struct JobState {
    pub status: JobStatus,
    pub strategies: Vec<String>,
    pub live: Vec<String>,
    pub eliminated: Vec<EliminatedArm>,
    pub records: Vec<RoundRecord>,
    pub rounds: usize,
    pub budget_spent: usize,
    pub best_accuracy: f64,
    pub trace: Option<PsheaTrace>,
}

/// Events retained per job for late/slow subscribers. A subscriber whose
/// cursor falls behind the oldest retained event is disconnected with a
/// lag error rather than back-pressuring the job (DESIGN.md §Events).
pub const JOB_EVENT_BUFFER: usize = 1024;

/// One delivery from [`JobEvents::next_after`].
#[derive(Debug)]
pub enum NextEvent {
    /// The event at `cursor + 1`, with its sequence number.
    Event(u64, Value),
    /// `cursor + 1` was evicted; the oldest retained seq is carried so
    /// the lag error can say what remains.
    Lagged(u64),
    /// Every event was delivered and no more will ever be published.
    Closed,
    /// Nothing new within the wait window; the stream is still live.
    Timeout,
}

/// Bounded, sequenced per-job event buffer (DESIGN.md §Events). Events
/// are the *same* `Value` records the coordinator's WAL stores for the
/// job (spend/record/elim/round/resume/done), published at the same
/// points — so a subscriber's stream is bit-identical to the durable
/// log by construction. Sequence numbers start at 1 and never reset;
/// `events[i]` holds seq `first_seq + i`.
pub struct JobEvents {
    inner: Mutex<EventBuf>,
    bell: Condvar,
}

struct EventBuf {
    events: VecDeque<Value>,
    /// Sequence number of `events[0]`; advances on eviction.
    first_seq: u64,
    /// Terminal: set by the `job_done` event (or [`JobEvents::close`]
    /// for jobs restored already-terminal); publishes after are dropped.
    closed: bool,
}

impl Default for JobEvents {
    fn default() -> JobEvents {
        JobEvents {
            inner: Mutex::new(EventBuf {
                events: VecDeque::new(),
                first_seq: 1,
                closed: false,
            }),
            bell: Condvar::new(),
        }
    }
}

impl JobEvents {
    /// Append one event and wake subscribers. Never blocks: the buffer
    /// evicts its oldest entry past [`JOB_EVENT_BUFFER`] — a slow
    /// subscriber observes the eviction as `Lagged` and is disconnected,
    /// the job never waits. A `job_done` event closes the stream.
    pub fn publish(&self, v: Value) {
        let terminal = v.get("t").and_then(Value::as_str) == Some("job_done");
        let mut b = self.inner.lock().unwrap();
        if b.closed {
            return;
        }
        b.events.push_back(v);
        while b.events.len() > JOB_EVENT_BUFFER {
            b.events.pop_front();
            b.first_seq += 1;
        }
        if terminal {
            b.closed = true;
        }
        drop(b);
        self.bell.notify_all();
    }

    /// Close without a terminal event — jobs restored from the WAL in an
    /// already-terminal state, where synthesizing a `job_done` the log
    /// never held would break stream/WAL bit-identity.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.bell.notify_all();
    }

    /// Block up to `wait` for the event after `cursor` (a subscriber
    /// that has consumed seq `cursor` asks for `cursor + 1`; a fresh
    /// subscriber asks with `cursor = 0`).
    pub fn next_after(&self, cursor: u64, wait: Duration) -> NextEvent {
        let deadline = Instant::now() + wait;
        let mut b = self.inner.lock().unwrap();
        loop {
            if cursor + 1 < b.first_seq {
                return NextEvent::Lagged(b.first_seq);
            }
            let idx = (cursor + 1 - b.first_seq) as usize;
            if idx < b.events.len() {
                return NextEvent::Event(cursor + 1, b.events[idx].clone());
            }
            if b.closed {
                return NextEvent::Closed;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return NextEvent::Timeout;
            }
            let (guard, _) = self.bell.wait_timeout(b, left).unwrap();
            b = guard;
        }
    }

    /// Refill from recovery-fold records, bypassing the closed check (a
    /// job restored terminal is closed *before* its history is seeded).
    /// A replayed `job_done` still closes the stream.
    fn seed(&self, raw: &[Value]) {
        let mut b = self.inner.lock().unwrap();
        for v in raw {
            if v.get("t").and_then(Value::as_str) == Some("job_done") {
                b.closed = true;
            }
            b.events.push_back(v.clone());
            while b.events.len() > JOB_EVENT_BUFFER {
                b.events.pop_front();
                b.first_seq += 1;
            }
        }
        drop(b);
        self.bell.notify_all();
    }

    /// `(first_seq, next_seq, closed)` — the subscribe handler's cursor
    /// validation and the diagnostics dump.
    pub fn cursor_info(&self) -> (u64, u64, bool) {
        let b = self.inner.lock().unwrap();
        (b.first_seq, b.first_seq + b.events.len() as u64, b.closed)
    }

    /// Retained events, oldest first (diagnostics).
    pub fn snapshot(&self) -> Vec<Value> {
        self.inner.lock().unwrap().events.iter().cloned().collect()
    }
}

/// One job: state + completion signal + cancel flag + event plane. The
/// flag is an `Arc` so the running [`AgentTask`] shares the very same
/// bool `agent_cancel` flips — no snapshot can desync.
pub struct JobSlot {
    /// The registry id (`job-N`) — carried here so observers deep in the
    /// loop can build WAL-shaped event records without threading the id
    /// through every call.
    pub id: String,
    pub state: Mutex<JobState>,
    pub done: Condvar,
    pub cancel: Arc<AtomicBool>,
    /// Push-stream buffer for `job_subscribe` (DESIGN.md §Events).
    pub events: JobEvents,
    /// Every WAL record appended for this job since `job_start`, in
    /// append order — the raw material a *forced* mid-job snapshot
    /// embeds so compaction under `max_wal_bytes` cannot orphan a
    /// running job (DESIGN.md §Durability).
    pub mirror: Mutex<Vec<Value>>,
}

impl JobSlot {
    /// Record `v` in the WAL mirror (call wherever the record is also
    /// appended to the durable log).
    pub fn wal_mirror(&self, v: &Value) {
        self.mirror.lock().unwrap().push(v.clone());
    }
}

/// Finished jobs kept for late `agent_status`/`agent_result` readers
/// before the registry starts evicting the oldest ones — without a cap a
/// long-running server would accumulate every past job's full round log
/// and trace forever.
const MAX_FINISHED_JOBS: usize = 64;

/// Registry of agent jobs on one serving process.
#[derive(Default)]
pub struct JobRegistry {
    jobs: Mutex<HashMap<String, Arc<JobSlot>>>,
    next: AtomicU64,
}

impl JobRegistry {
    pub fn new() -> JobRegistry {
        JobRegistry::default()
    }

    pub fn create(&self, strategies: &[String]) -> (String, Arc<JobSlot>) {
        let seq = self.next.fetch_add(1, Ordering::Relaxed);
        let id = format!("job-{seq}");
        let slot = Arc::new(JobSlot {
            id: id.clone(),
            state: Mutex::new(JobState {
                status: JobStatus::Running,
                strategies: strategies.to_vec(),
                live: strategies.to_vec(),
                eliminated: vec![],
                records: vec![],
                rounds: 0,
                budget_spent: 0,
                best_accuracy: 0.0,
                trace: None,
            }),
            done: Condvar::new(),
            cancel: Arc::new(AtomicBool::new(false)),
            events: JobEvents::default(),
            mirror: Mutex::new(vec![]),
        });
        let mut jobs = self.jobs.lock().unwrap();
        jobs.insert(id.clone(), slot.clone());
        // evict the oldest *finished* jobs beyond the cap (ids carry the
        // monotonic sequence number; running jobs are never evicted)
        if jobs.len() > MAX_FINISHED_JOBS {
            let mut finished: Vec<(u64, String)> = jobs
                .iter()
                .filter(|(_, s)| s.state.lock().unwrap().status != JobStatus::Running)
                .filter_map(|(k, _)| {
                    k.strip_prefix("job-").and_then(|n| n.parse::<u64>().ok()).map(|n| (n, k.clone()))
                })
                .collect();
            finished.sort_unstable_by_key(|(n, _)| *n);
            let excess = jobs.len().saturating_sub(MAX_FINISHED_JOBS);
            for (_, k) in finished.into_iter().take(excess) {
                jobs.remove(&k);
            }
        }
        drop(jobs);
        (id, slot)
    }

    pub fn get(&self, id: &str) -> Result<Arc<JobSlot>, String> {
        self.jobs
            .lock()
            .unwrap()
            .get(id)
            .cloned()
            .ok_or_else(|| format!("unknown job '{id}'"))
    }

    /// Mark a job failed by id — the spawn-failure path, where the slot
    /// `Arc` was consumed by the never-run thread closure. Without this a
    /// failed spawn would leave a ghost job `running` forever (and
    /// eviction never removes running jobs).
    pub fn fail_orphan(&self, id: &str, metrics: &Registry, err: &str) {
        if let Ok(slot) = self.get(id) {
            fail(&slot, metrics, format!("job thread spawn failed: {err}"));
        }
    }

    /// Re-create a job slot under its original id during crash recovery
    /// (WAL replay). The sequence counter is advanced past the restored
    /// id so jobs started after the restart never collide with pre-crash
    /// ones.
    pub fn restore(&self, id: &str, state: JobState) -> Arc<JobSlot> {
        if let Some(n) = id.strip_prefix("job-").and_then(|n| n.parse::<u64>().ok()) {
            self.next.fetch_max(n + 1, Ordering::Relaxed);
        }
        let terminal = state.status != JobStatus::Running;
        let slot = Arc::new(JobSlot {
            id: id.to_string(),
            state: Mutex::new(state),
            done: Condvar::new(),
            cancel: Arc::new(AtomicBool::new(false)),
            events: JobEvents::default(),
            mirror: Mutex::new(vec![]),
        });
        if terminal {
            // no further events will ever be published; a subscriber
            // gets a clean end instead of a 250ms-poll hang
            slot.events.close();
        }
        self.jobs.lock().unwrap().insert(id.to_string(), slot.clone());
        slot
    }

    /// Re-seed a restored job's event buffer and WAL mirror from the
    /// job-scoped records the recovery fold replayed, in WAL order — so
    /// a subscriber reconnecting across a coordinator crash-restart
    /// resumes from its pre-crash cursor without gaps or duplicates
    /// (the WAL's order *is* the publish order; DESIGN.md §Events).
    pub fn seed_events(slot: &JobSlot, raw: &[Value]) {
        for v in raw {
            slot.wal_mirror(v);
        }
        slot.events.seed(raw);
    }

    /// Is any job still running? The durability layer defers WAL
    /// compaction while one is (round/spend records are not idempotent
    /// across a snapshot rotation — DESIGN.md §Durability).
    pub fn any_running(&self) -> bool {
        self.jobs
            .lock()
            .unwrap()
            .values()
            .any(|s| s.state.lock().unwrap().status == JobStatus::Running)
    }

    /// Slots of currently running jobs, id-sorted — the forced byte-cap
    /// compaction enumerates these to embed their WAL mirrors in the
    /// snapshot (DESIGN.md §Durability).
    pub fn running_slots(&self) -> Vec<Arc<JobSlot>> {
        let mut v: Vec<Arc<JobSlot>> = self
            .jobs
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.state.lock().unwrap().status == JobStatus::Running)
            .cloned()
            .collect();
        v.sort_by(|a, b| a.id.cmp(&b.id));
        v
    }
}

/// Observer publishing loop progress into the slot + `agent.*` metrics.
struct SlotObserver<'a> {
    slot: &'a JobSlot,
    metrics: &'a Registry,
    round_started: Instant,
}

impl PsheaObserver for SlotObserver<'_> {
    fn on_record(&mut self, rec: &RoundRecord) {
        let mut s = self.slot.state.lock().unwrap();
        s.best_accuracy = s.best_accuracy.max(rec.accuracy);
        s.records.push(rec.clone());
        drop(s);
        // the exact record the coordinator's WAL stores (same
        // constructor, same args): streamed events stay bit-identical
        // to the durable log by construction (DESIGN.md §Events)
        self.slot
            .events
            .publish(crate::cluster::recovery::rec_job_record(&self.slot.id, rec));
    }

    fn on_eliminated(&mut self, strategy: &str, round: usize, predicted: f64, observed: f64) {
        let mut s = self.slot.state.lock().unwrap();
        if let Some(r) = s
            .records
            .iter_mut()
            .rev()
            .find(|r| r.round == round && r.strategy == strategy)
        {
            r.eliminated = true;
        }
        s.live.retain(|x| x != strategy);
        s.eliminated.push(EliminatedArm {
            strategy: strategy.to_string(),
            round,
            predicted,
            observed,
        });
        drop(s);
        self.slot.events.publish(crate::cluster::recovery::rec_job_elim(
            &self.slot.id,
            strategy,
            round,
            predicted,
            observed,
        ));
        self.metrics.counter("agent.eliminations").fetch_add(1, Ordering::Relaxed);
    }

    fn on_round(&mut self, round: usize, live: &[String], total_budget: usize, a_max: f64) {
        let mut s = self.slot.state.lock().unwrap();
        let delta = total_budget.saturating_sub(s.budget_spent);
        s.rounds = round + 1;
        s.budget_spent = total_budget;
        s.best_accuracy = s.best_accuracy.max(a_max);
        s.live = live.to_vec();
        drop(s);
        self.slot
            .events
            .publish(crate::cluster::recovery::rec_job_round(&self.slot.id, round));
        self.metrics.meter("agent.labels").add(delta as u64);
        self.metrics.counter("agent.rounds").fetch_add(1, Ordering::Relaxed);
        self.metrics.counter("agent.live_arms").store(live.len() as u64, Ordering::Relaxed);
        self.metrics.time("agent.round", self.round_started.elapsed());
        self.round_started = Instant::now();
    }
}

/// Mark a job failed before its task ever ran (e.g. session scan failed).
pub fn fail(slot: &JobSlot, metrics: &Registry, err: String) {
    let mut s = slot.state.lock().unwrap();
    s.status = JobStatus::Failed(err);
    let status = s.status.as_string();
    drop(s);
    metrics.counter("agent.jobs_failed").fetch_add(1, Ordering::Relaxed);
    slot.events
        .publish(crate::cluster::recovery::rec_job_done(&slot.id, &status, None));
    slot.done.notify_all();
}

/// Fans one PSHEA event stream out to the durability log first (so an
/// event is durable before it becomes observable via `agent_status`),
/// then the job slot.
struct TeeObserver<'a, 'b> {
    wal: &'b mut dyn PsheaObserver,
    slot: SlotObserver<'a>,
}

impl PsheaObserver for TeeObserver<'_, '_> {
    fn on_record(&mut self, rec: &RoundRecord) {
        self.wal.on_record(rec);
        self.slot.on_record(rec);
    }
    fn on_eliminated(&mut self, strategy: &str, round: usize, predicted: f64, observed: f64) {
        self.wal.on_eliminated(strategy, round, predicted, observed);
        self.slot.on_eliminated(strategy, round, predicted, observed);
    }
    fn on_round(&mut self, round: usize, live: &[String], total_budget: usize, a_max: f64) {
        self.wal.on_round(round, live, total_budget, a_max);
        self.slot.on_round(round, live, total_budget, a_max);
    }
}

/// Run Algorithm 1 for `slot` on `task`, publishing progress as it goes.
/// Called on the job's background thread; classifies the outcome via the
/// slot's cancel flag and signals completion.
pub fn drive<S: ArmSelect>(
    slot: &JobSlot,
    task: AgentTask<S>,
    strategies: &[String],
    cfg: &PsheaConfig,
    metrics: &Registry,
) {
    drive_with(slot, task, strategies, cfg, metrics, &[], None)
}

/// [`drive`] with crash-recovery hooks (DESIGN.md §Durability): `prior`
/// holds the completed-round records an interrupted run left in the WAL
/// (empty for a fresh job; the task's arms must already be restored via
/// [`AgentTask::restore_arm`] to match), and `wal`, when present, sees
/// every loop event before the job slot does — the coordinator logs
/// round/elimination/spend records through it.
pub fn drive_with<S: ArmSelect>(
    slot: &JobSlot,
    mut task: AgentTask<S>,
    strategies: &[String],
    cfg: &PsheaConfig,
    metrics: &Registry,
    prior: &[RoundRecord],
    wal: Option<&mut dyn PsheaObserver>,
) {
    metrics.counter("agent.jobs_started").fetch_add(1, Ordering::Relaxed);
    let outcome = {
        let slot_obs = SlotObserver { slot, metrics, round_started: Instant::now() };
        match wal {
            Some(w) => {
                let mut tee = TeeObserver { wal: w, slot: slot_obs };
                run_pshea_resumed(&mut task, strategies, cfg, prior, &mut tee)
            }
            None => {
                let mut obs = slot_obs;
                run_pshea_resumed(&mut task, strategies, cfg, prior, &mut obs)
            }
        }
    };
    let mut s = slot.state.lock().unwrap();
    match outcome {
        Ok(trace) => {
            s.rounds = trace.rounds;
            s.budget_spent = trace.total_budget;
            s.best_accuracy = trace.best_accuracy;
            s.live = trace.survivors.clone();
            s.records = trace.records.clone();
            s.status = JobStatus::Done;
            s.trace = Some(trace);
            metrics.counter("agent.jobs_done").fetch_add(1, Ordering::Relaxed);
        }
        Err(e) => {
            if slot.cancel.load(Ordering::SeqCst) {
                s.status = JobStatus::Cancelled;
                metrics.counter("agent.jobs_cancelled").fetch_add(1, Ordering::Relaxed);
            } else {
                s.status = JobStatus::Failed(e.to_string());
                metrics.counter("agent.jobs_failed").fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    // terminal event: the same `job_done` record the coordinator then
    // appends to the WAL — closes the subscription stream on both
    // topologies (DESIGN.md §Events)
    let done_rec = crate::cluster::recovery::rec_job_done(
        &slot.id,
        &s.status.as_string(),
        s.trace.as_ref(),
    );
    drop(s);
    slot.events.publish(done_rec);
    slot.done.notify_all();
}

/// Block until the job leaves `Running` (or `wait` elapses).
pub fn wait_done(slot: &JobSlot, wait: Duration) -> Result<(), String> {
    let deadline = Instant::now() + wait;
    let mut s = slot.state.lock().unwrap();
    while s.status == JobStatus::Running {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err("agent_result timed out (job still running)".into());
        }
        let (guard, _) = slot.done.wait_timeout(s, left).unwrap();
        s = guard;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Wire forms: config, records, traces, and the shared RPC handlers.
// ---------------------------------------------------------------------------

fn str_field(v: &Value, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string param '{key}'"))
}

pub fn stop_to_str(s: StopReason) -> &'static str {
    match s {
        StopReason::TargetReached => "target_reached",
        StopReason::BudgetExhausted => "budget_exhausted",
        StopReason::Converged => "converged",
        StopReason::RoundLimit => "round_limit",
        StopReason::PoolExhausted => "pool_exhausted",
    }
}

pub fn stop_from_str(s: &str) -> Option<StopReason> {
    match s {
        "target_reached" => Some(StopReason::TargetReached),
        "budget_exhausted" => Some(StopReason::BudgetExhausted),
        "converged" => Some(StopReason::Converged),
        "round_limit" => Some(StopReason::RoundLimit),
        "pool_exhausted" => Some(StopReason::PoolExhausted),
        _ => None,
    }
}

pub fn config_to_value(cfg: &PsheaConfig) -> Value {
    let mut m = Map::new();
    m.insert("target_accuracy", Value::Number(cfg.target_accuracy));
    m.insert("max_budget", Value::from(cfg.max_budget));
    m.insert("round_budget", Value::from(cfg.round_budget));
    m.insert("converge_rounds", Value::from(cfg.converge_rounds));
    m.insert("converge_eps", Value::Number(cfg.converge_eps));
    m.insert("max_rounds", Value::from(cfg.max_rounds));
    m.insert("min_history", Value::from(cfg.min_history));
    if let Some(a0) = cfg.initial_accuracy {
        m.insert("initial_accuracy", Value::Number(a0));
    }
    Value::Object(m)
}

/// Overlay RPC-supplied knobs onto `base` (the server's `[agent]` config
/// defaults). Absent fields keep the defaults; present fields must have
/// the right type.
pub fn config_from_value(mut base: PsheaConfig, v: Option<&Value>) -> Result<PsheaConfig, String> {
    let Some(v) = v else { return Ok(base) };
    if v.is_null() {
        return Ok(base);
    }
    if v.as_object().is_none() {
        return Err("agent config must be an object".into());
    }
    let f64_field = |key: &str| -> Result<Option<f64>, String> {
        match v.get(key) {
            None | Some(Value::Null) => Ok(None),
            Some(x) => x
                .as_f64()
                .map(Some)
                .ok_or_else(|| format!("agent config '{key}' must be a number")),
        }
    };
    let usize_field = |key: &str| -> Result<Option<usize>, String> {
        match v.get(key) {
            None | Some(Value::Null) => Ok(None),
            Some(x) => x
                .as_usize()
                .map(Some)
                .ok_or_else(|| format!("agent config '{key}' must be a non-negative integer")),
        }
    };
    if let Some(x) = f64_field("target_accuracy")? {
        base.target_accuracy = x;
    }
    if let Some(x) = usize_field("max_budget")? {
        base.max_budget = x;
    }
    if let Some(x) = usize_field("round_budget")? {
        base.round_budget = x;
    }
    if let Some(x) = usize_field("converge_rounds")? {
        base.converge_rounds = x;
    }
    if let Some(x) = f64_field("converge_eps")? {
        base.converge_eps = x;
    }
    if let Some(x) = usize_field("max_rounds")? {
        base.max_rounds = x;
    }
    if let Some(x) = usize_field("min_history")? {
        base.min_history = x;
    }
    if let Some(x) = f64_field("initial_accuracy")? {
        base.initial_accuracy = Some(x);
    }
    // same invariant the [active_learning.agent] config section enforces:
    // the RPC entry point must not be able to overspend the cap that the
    // config-file entry point guards (run_pshea stops *before* a round
    // would exceed max_budget, so round 0 would otherwise run unchecked)
    if base.round_budget == 0 || base.round_budget > base.max_budget {
        return Err("agent config 'round_budget' must be in [1, max_budget]".into());
    }
    Ok(base)
}

pub fn record_to_value(r: &RoundRecord) -> Value {
    let mut m = Map::new();
    m.insert("round", Value::from(r.round));
    m.insert("strategy", Value::from(r.strategy.clone()));
    m.insert("budget_spent", Value::from(r.budget_spent));
    m.insert("accuracy", Value::Number(r.accuracy));
    match r.predicted_next {
        Some(p) => m.insert("predicted_next", Value::Number(p)),
        None => m.insert("predicted_next", Value::Null),
    }
    m.insert("eliminated", Value::Bool(r.eliminated));
    Value::Object(m)
}

pub fn record_from_value(v: &Value) -> Result<RoundRecord, String> {
    Ok(RoundRecord {
        round: v.get("round").and_then(Value::as_usize).ok_or("record missing round")?,
        strategy: str_field(v, "strategy")?,
        budget_spent: v
            .get("budget_spent")
            .and_then(Value::as_usize)
            .ok_or("record missing budget_spent")?,
        accuracy: v
            .get("accuracy")
            .and_then(Value::as_f64)
            .ok_or("record missing accuracy")?,
        predicted_next: v.get("predicted_next").and_then(Value::as_f64),
        eliminated: v.get("eliminated").and_then(Value::as_bool).unwrap_or(false),
    })
}

/// The `agent_status` reply shape (also embedded in `agent_result`).
pub fn status_value(job_id: &str, s: &JobState) -> Value {
    let mut m = Map::new();
    m.insert("job", Value::from(job_id));
    m.insert("status", Value::from(s.status.as_string()));
    m.insert("rounds", Value::from(s.rounds));
    m.insert("budget_spent", Value::from(s.budget_spent));
    m.insert("best_accuracy", Value::Number(s.best_accuracy));
    m.insert(
        "live",
        Value::Array(s.live.iter().map(|x| Value::from(x.clone())).collect()),
    );
    m.insert(
        "eliminated",
        Value::Array(
            s.eliminated
                .iter()
                .map(|e| {
                    let mut em = Map::new();
                    em.insert("strategy", Value::from(e.strategy.clone()));
                    em.insert("round", Value::from(e.round));
                    em.insert("predicted", Value::Number(e.predicted));
                    em.insert("observed", Value::Number(e.observed));
                    Value::Object(em)
                })
                .collect(),
        ),
    );
    m.insert(
        "records",
        Value::Array(s.records.iter().map(record_to_value).collect()),
    );
    Value::Object(m)
}

/// The `agent_result` reply: status fields + the completed trace.
fn result_value(job_id: &str, s: &JobState) -> Result<Value, String> {
    let trace = s.trace.as_ref().ok_or("job finished without a trace")?;
    let mut m = match status_value(job_id, s) {
        Value::Object(m) => m,
        _ => Map::new(),
    };
    m.insert(
        "survivors",
        Value::Array(trace.survivors.iter().map(|x| Value::from(x.clone())).collect()),
    );
    m.insert("stop", Value::from(stop_to_str(trace.stop)));
    m.insert("total_budget", Value::from(trace.total_budget));
    m.insert(
        "recommendation",
        trace.recommendation().map(Value::from).unwrap_or(Value::Null),
    );
    Ok(Value::Object(m))
}

/// Parse an `agent_result` reply back into a [`PsheaTrace`] (client side).
pub fn trace_from_value(v: &Value) -> Result<PsheaTrace, String> {
    let records = v
        .get("records")
        .and_then(Value::as_array)
        .ok_or("agent result missing records")?
        .iter()
        .map(record_from_value)
        .collect::<Result<Vec<_>, _>>()?;
    let survivors = v
        .get("survivors")
        .and_then(Value::as_array)
        .ok_or("agent result missing survivors")?
        .iter()
        .map(|x| x.as_str().map(str::to_string).ok_or_else(|| "bad survivor".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    let stop = v
        .get("stop")
        .and_then(Value::as_str)
        .and_then(stop_from_str)
        .ok_or("agent result missing stop reason")?;
    Ok(PsheaTrace {
        records,
        survivors,
        stop,
        total_budget: v
            .get("total_budget")
            .and_then(Value::as_usize)
            .ok_or("agent result missing total_budget")?,
        best_accuracy: v.get("best_accuracy").and_then(Value::as_f64).unwrap_or(0.0),
        rounds: v.get("rounds").and_then(Value::as_usize).unwrap_or(0),
    })
}

/// Shared `agent_status` handler.
pub fn rpc_status(reg: &JobRegistry, params: &Value) -> Result<Value, String> {
    let id = str_field(params, "job")?;
    let slot = reg.get(&id)?;
    let s = slot.state.lock().unwrap();
    Ok(status_value(&id, &s))
}

/// Shared `agent_result` handler: blocks until the job completes (or
/// `wait_ms` elapses), then returns the trace — or an error for a
/// cancelled/failed job.
pub fn rpc_result(reg: &JobRegistry, params: &Value) -> Result<Value, String> {
    let id = str_field(params, "job")?;
    let wait_ms = params.get("wait_ms").and_then(Value::as_usize).unwrap_or(600_000) as u64;
    let slot = reg.get(&id)?;
    wait_done(&slot, Duration::from_millis(wait_ms))?;
    let s = slot.state.lock().unwrap();
    match &s.status {
        JobStatus::Done => result_value(&id, &s),
        other => Err(format!("agent job {id} is {}", other.as_string())),
    }
}

/// Shared `agent_cancel` handler. Returns whether the job was still
/// running when the flag was raised; labeling spend stops at the next
/// round boundary.
pub fn rpc_cancel(reg: &JobRegistry, params: &Value) -> Result<Value, String> {
    let id = str_field(params, "job")?;
    let slot = reg.get(&id)?;
    slot.cancel.store(true, Ordering::SeqCst);
    let was_running = slot.state.lock().unwrap().status == JobStatus::Running;
    if was_running {
        slot.events
            .publish(crate::cluster::recovery::rec_job_cancel(&id));
    }
    let mut m = Map::new();
    m.insert("job", Value::from(id));
    m.insert("cancelled", Value::Bool(was_running));
    Ok(Value::Object(m))
}

/// How often the subscription pump re-checks for a dead sink while the
/// job is quiet.
const SUB_POLL: Duration = Duration::from_millis(250);

/// Shared `job_subscribe` handler (DESIGN.md §Events): validate the
/// cursor against the job's retained buffer, then spawn a pump thread
/// that pushes every event after `from_seq` through the connection's
/// [`PushSink`] as unsolicited frames under this request's id. The reply
/// acknowledges the subscription; events follow on the same connection.
pub fn rpc_subscribe(
    reg: &JobRegistry,
    params: &Value,
    ctx: &crate::server::rpc::RequestCtx,
) -> Result<Value, String> {
    if !ctx.mux {
        return Err(
            "job_subscribe requires the multiplexed wire (negotiate mux at hello)".into(),
        );
    }
    let id = str_field(params, "job")?;
    let slot = reg.get(&id)?;
    let from_seq = params.get("from_seq").and_then(Value::as_usize).unwrap_or(0) as u64;
    let (first_seq, next_seq, _closed) = slot.events.cursor_info();
    if from_seq + 1 < first_seq {
        return Err(format!(
            "cursor {from_seq} lags the event buffer (oldest retained seq is {first_seq}); \
             re-fetch state via agent_status and resubscribe from the current seq"
        ));
    }
    if from_seq >= next_seq {
        return Err(format!(
            "cursor {from_seq} is ahead of the stream (next seq is {next_seq})"
        ));
    }
    let status = slot.state.lock().unwrap().status.as_string();
    let sink = ctx.push_sink();
    let sub_id = ctx.id;
    let thread = format!("alaas-sub-{id}-{sub_id}");
    std::thread::Builder::new()
        .name(thread)
        .spawn(move || pump_subscription(&slot, &sink, sub_id, from_seq))
        .map_err(|e| format!("subscription thread spawn failed: {e}"))?;
    let mut m = Map::new();
    m.insert("job", Value::from(id));
    m.insert("status", Value::from(status));
    m.insert("from_seq", Value::from(from_seq as usize));
    m.insert("next_seq", Value::from(next_seq as usize));
    Ok(Value::Object(m))
}

/// One subscription's pump loop: replay from the cursor, then follow
/// live publishes until the stream ends or the subscriber goes away.
/// Every exit path is subscriber-scoped — the job never blocks on a
/// slow or dead sink, it just stops being watched.
fn pump_subscription(
    slot: &JobSlot,
    sink: &crate::server::rpc::PushSink,
    sub_id: u64,
    mut cursor: u64,
) {
    loop {
        match slot.events.next_after(cursor, SUB_POLL) {
            NextEvent::Event(seq, v) => {
                if !sink.send_event(sub_id, seq, &v) {
                    return; // connection gone
                }
                cursor = seq;
            }
            NextEvent::Lagged(first) => {
                // slow subscriber: the buffer evicted past its cursor —
                // disconnect it rather than back-pressure the job
                sink.send_error(
                    sub_id,
                    &format!(
                        "subscriber lagged: events before seq {first} were evicted; \
                         resubscribe from the current state"
                    ),
                );
                return;
            }
            NextEvent::Closed => {
                sink.send_end(sub_id, "all events delivered");
                return;
            }
            NextEvent::Timeout => {
                if sink.is_closed() {
                    return; // stop polling for a dead connection
                }
            }
        }
    }
}

/// Shared `job_events` diagnostic handler: the retained buffer verbatim
/// plus cursor bounds — what the test harness dumps on failure, and a
/// non-streaming way to inspect exactly what subscribers would see.
pub fn rpc_events(reg: &JobRegistry, params: &Value) -> Result<Value, String> {
    let id = str_field(params, "job")?;
    let slot = reg.get(&id)?;
    let (first_seq, next_seq, closed) = slot.events.cursor_info();
    let mut m = Map::new();
    m.insert("job", Value::from(id));
    m.insert("first_seq", Value::from(first_seq as usize));
    m.insert("next_seq", Value::from(next_seq as usize));
    m.insert("closed", Value::Bool(closed));
    m.insert("events", Value::Array(slot.events.snapshot()));
    Ok(Value::Object(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::HostBackend;
    use crate::util::rng::Rng;

    /// Selector over a fixed in-memory pool: scores under the arm head,
    /// exactly like the served selectors, so AgentTask semantics are
    /// testable without a server.
    struct PoolSelect {
        pool_emb: Mat,
        init_emb: Mat,
        backend: Arc<dyn ComputeBackend>,
    }

    impl ArmSelect for PoolSelect {
        fn select_arm(
            &mut self,
            strategy: &str,
            budget: usize,
            head: &LinearHead,
            exclude: &[usize],
            arm_labeled: &Mat,
            seed: u64,
        ) -> Result<Vec<Picked>, String> {
            let strat = crate::strategies::by_name(strategy)
                .ok_or_else(|| format!("unknown strategy '{strategy}'"))?;
            let excl: std::collections::HashSet<usize> = exclude.iter().copied().collect();
            let ok_rows: Vec<usize> =
                (0..self.pool_emb.rows()).filter(|i| !excl.contains(i)).collect();
            let cand_emb = self.pool_emb.gather_rows(&ok_rows);
            let logits = self
                .backend
                .eval_logits(&cand_emb, &head.w, &head.b)
                .map_err(|e| e.to_string())?;
            let scores = self.backend.scores(&logits).map_err(|e| e.to_string())?;
            let labeled = if arm_labeled.rows() == 0 {
                self.init_emb.clone()
            } else {
                self.init_emb.vstack(arm_labeled)
            };
            let ctx = crate::strategies::SelectCtx {
                scores: &scores,
                embeddings: &cand_emb,
                labeled: &labeled,
                backend: self.backend.as_ref(),
                seed,
            };
            let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
            Ok(picked
                .into_iter()
                .map(|rel| (ok_rows[rel], cand_emb.row(rel).to_vec()))
                .collect())
        }
    }

    fn toy(seed: u64) -> (Mat, Vec<u8>, Mat, Vec<u8>, Mat, Vec<u8>, usize) {
        let mut rng = Rng::new(seed);
        let c = 4;
        let d = 8;
        let gen = |rng: &mut Rng, n: usize| -> (Mat, Vec<u8>) {
            let mut m = Mat::zeros(n, d);
            let mut labels = Vec::with_capacity(n);
            for i in 0..n {
                let class = rng.below(c);
                labels.push(class as u8);
                let row = m.row_mut(i);
                for v in row.iter_mut() {
                    *v = 0.4 * rng.normal_f32();
                }
                row[class] += 2.0;
            }
            (m, labels)
        };
        let (init_emb, init_labels) = gen(&mut rng, 16);
        let (pool_emb, pool_labels) = gen(&mut rng, 120);
        let (test_emb, test_labels) = gen(&mut rng, 80);
        (init_emb, init_labels, pool_emb, pool_labels, test_emb, test_labels, c)
    }

    fn task(seed: u64, cancel: Option<Arc<AtomicBool>>) -> AgentTask<PoolSelect> {
        let (init_emb, init_labels, pool_emb, pool_labels, test_emb, test_labels, c) =
            toy(seed);
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        let n = pool_emb.rows();
        let sel = PoolSelect {
            pool_emb,
            init_emb: init_emb.clone(),
            backend: backend.clone(),
        };
        AgentTask::new(
            sel, backend, n, init_emb, init_labels, pool_labels, test_emb, test_labels,
            c, seed, cancel,
        )
    }

    fn quick_cfg(rounds: usize) -> PsheaConfig {
        PsheaConfig {
            target_accuracy: 1.1,
            max_budget: 1_000_000,
            round_budget: 10,
            converge_rounds: 0,
            converge_eps: 0.0,
            max_rounds: rounds,
            min_history: 2,
            initial_accuracy: None,
        }
    }

    #[test]
    fn agent_task_matches_al_experiment_round_semantics() {
        // Same data through AgentTask and sim::AlExperiment must produce
        // identical accuracy sequences — the parity the remote tests rely
        // on, pinned here without any server in the way.
        let (init_emb, init_labels, pool_emb, pool_labels, test_emb, test_labels, c) =
            toy(11);
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        let oracle = Arc::new(crate::data::Oracle::from_labels(pool_labels.clone()));
        let mut exp = crate::sim::AlExperiment::from_embeddings(
            backend.clone(),
            pool_emb.clone(),
            (0..pool_emb.rows() as u32).collect(),
            init_emb.clone(),
            init_labels.clone(),
            test_emb.clone(),
            test_labels.clone(),
            oracle,
            c,
            TrainConfig::default(),
            11,
        );
        let mut t = task(11, None);
        for strategy in ["least_confidence", "entropy"] {
            for _ in 0..3 {
                let a = t.run_round(strategy, 15).unwrap().unwrap();
                let b = exp.run_round(strategy, 15).unwrap().unwrap();
                assert_eq!(a, b, "{strategy}: AgentTask diverged from AlExperiment");
            }
        }
    }

    #[test]
    fn drive_publishes_progress_and_completion() {
        let reg = JobRegistry::new();
        let strategies = vec!["least_confidence".to_string(), "random".to_string()];
        let (id, slot) = reg.create(&strategies);
        let metrics = Registry::new();
        drive(&slot, task(3, None), &strategies, &quick_cfg(3), &metrics);
        let s = slot.state.lock().unwrap();
        assert_eq!(s.status, JobStatus::Done);
        assert_eq!(s.rounds, 3);
        assert!(s.budget_spent > 0);
        assert!(s.trace.is_some());
        // the wire round trip of the result preserves the trace
        drop(s);
        let v = rpc_result(&reg, &{
            let mut m = Map::new();
            m.insert("job", Value::from(id.clone()));
            Value::Object(m)
        })
        .unwrap();
        let trace = trace_from_value(&v).unwrap();
        let s = slot.state.lock().unwrap();
        let want = s.trace.as_ref().unwrap();
        assert_eq!(trace.survivors, want.survivors);
        assert_eq!(trace.stop, want.stop);
        assert_eq!(trace.total_budget, want.total_budget);
        assert_eq!(trace.records.len(), want.records.len());
        for (a, b) in trace.records.iter().zip(&want.records) {
            assert_eq!(a.strategy, b.strategy);
            assert_eq!(a.eliminated, b.eliminated);
            assert_eq!(a.accuracy, b.accuracy, "f64 must round-trip exactly");
        }
    }

    #[test]
    fn cancel_flag_stops_the_loop_as_cancelled() {
        let reg = JobRegistry::new();
        let strategies = vec!["entropy".to_string()];
        let (_, slot) = reg.create(&strategies);
        slot.cancel.store(true, Ordering::SeqCst);
        let metrics = Registry::new();
        let cancel = Some(slot.cancel.clone());
        drive(&slot, task(5, cancel), &strategies, &quick_cfg(5), &metrics);
        let s = slot.state.lock().unwrap();
        assert_eq!(s.status, JobStatus::Cancelled);
        assert_eq!(s.budget_spent, 0, "no labels after cancel");
    }

    #[test]
    fn config_round_trips_and_validates() {
        let cfg = PsheaConfig {
            max_rounds: 7,
            min_history: 2,
            initial_accuracy: Some(0.5),
            ..Default::default()
        };
        let v = config_to_value(&cfg);
        let back = config_from_value(PsheaConfig::default(), Some(&v)).unwrap();
        assert_eq!(back.max_rounds, 7);
        assert_eq!(back.min_history, 2);
        assert_eq!(back.initial_accuracy, Some(0.5));
        assert_eq!(back.round_budget, cfg.round_budget);
        // absent config keeps the defaults
        let d = config_from_value(PsheaConfig::default(), None).unwrap();
        assert_eq!(d.round_budget, PsheaConfig::default().round_budget);
        // zero round budget rejected
        let mut m = Map::new();
        m.insert("round_budget", Value::from(0usize));
        assert!(config_from_value(PsheaConfig::default(), Some(&Value::Object(m))).is_err());
        // a round budget exceeding the cap would overspend max_budget on
        // round 0 (the loop's guard only fires from round 1) — rejected,
        // matching the [active_learning.agent] config validation
        let mut m = Map::new();
        m.insert("max_budget", Value::from(100usize));
        m.insert("round_budget", Value::from(10_000usize));
        assert!(config_from_value(PsheaConfig::default(), Some(&Value::Object(m))).is_err());
    }

    #[test]
    fn registry_evicts_oldest_finished_jobs_beyond_cap() {
        let reg = JobRegistry::new();
        let strategies = vec!["entropy".to_string()];
        let mut ids = Vec::new();
        for _ in 0..(MAX_FINISHED_JOBS + 10) {
            let (id, slot) = reg.create(&strategies);
            slot.state.lock().unwrap().status = JobStatus::Done;
            ids.push(id);
        }
        // the oldest finished jobs were evicted, the newest survive
        assert!(reg.get(&ids[0]).is_err(), "oldest job should be evicted");
        assert!(reg.get(ids.last().unwrap()).is_ok());
        assert!(reg.jobs.lock().unwrap().len() <= MAX_FINISHED_JOBS);
    }

    /// Crash-resume parity at the job layer, no cluster in the way: run a
    /// job to completion while recording every arm's spend ledger, then
    /// for every possible crash point "restart" — rebuild the arms via
    /// `restore_arm` from the ledger, resume via `drive_with` with the
    /// prior records — and require the final state to match bit for bit.
    #[test]
    fn restored_job_resumes_bit_identical() {
        struct RecordingSelect {
            inner: PoolSelect,
            picks: Arc<Mutex<BTreeMap<String, Vec<usize>>>>,
        }
        impl ArmSelect for RecordingSelect {
            fn select_arm(
                &mut self,
                strategy: &str,
                budget: usize,
                head: &LinearHead,
                exclude: &[usize],
                arm_labeled: &Mat,
                seed: u64,
            ) -> Result<Vec<Picked>, String> {
                let picked =
                    self.inner.select_arm(strategy, budget, head, exclude, arm_labeled, seed)?;
                self.picks
                    .lock()
                    .unwrap()
                    .entry(strategy.to_string())
                    .or_default()
                    .extend(picked.iter().map(|(g, _)| *g));
                Ok(picked)
            }
        }

        let seed = 13;
        let (init_emb, init_labels, pool_emb, pool_labels, test_emb, test_labels, c) =
            toy(seed);
        let backend: Arc<dyn ComputeBackend> = Arc::new(HostBackend::new());
        let picks: Arc<Mutex<BTreeMap<String, Vec<usize>>>> = Default::default();
        let sel = RecordingSelect {
            inner: PoolSelect {
                pool_emb: pool_emb.clone(),
                init_emb: init_emb.clone(),
                backend: backend.clone(),
            },
            picks: picks.clone(),
        };
        let full_task = AgentTask::new(
            sel,
            backend.clone(),
            pool_emb.rows(),
            init_emb.clone(),
            init_labels.clone(),
            pool_labels.clone(),
            test_emb.clone(),
            test_labels.clone(),
            c,
            seed,
            None,
        );
        let strategies = vec!["least_confidence".to_string(), "entropy".to_string()];
        let cfg = quick_cfg(4);
        let reg = JobRegistry::new();
        let (_, slot) = reg.create(&strategies);
        let metrics = Registry::new();
        drive(&slot, full_task, &strategies, &cfg, &metrics);
        let full = {
            let s = slot.state.lock().unwrap();
            assert_eq!(s.status, JobStatus::Done);
            s.trace.clone().unwrap()
        };
        let picks = picks.lock().unwrap().clone();

        for cut in 1..=full.rounds {
            let prior: Vec<RoundRecord> =
                full.records.iter().filter(|r| r.round < cut).cloned().collect();
            let mut task2 = AgentTask::new(
                PoolSelect {
                    pool_emb: pool_emb.clone(),
                    init_emb: init_emb.clone(),
                    backend: backend.clone(),
                },
                backend.clone(),
                pool_emb.rows(),
                init_emb.clone(),
                init_labels.clone(),
                pool_labels.clone(),
                test_emb.clone(),
                test_labels.clone(),
                c,
                seed,
                None,
            );
            for s in &strategies {
                let rounds = prior.iter().filter(|r| r.strategy == *s).count();
                if rounds == 0 {
                    continue;
                }
                let ledger: Vec<usize> =
                    picks[s][..rounds * cfg.round_budget].to_vec();
                let emb_rows: Vec<Vec<f32>> =
                    ledger.iter().map(|&g| pool_emb.row(g).to_vec()).collect();
                task2.restore_arm(s, ledger, emb_rows, rounds as u64).unwrap();
            }
            let eliminated: Vec<EliminatedArm> = prior
                .iter()
                .filter(|r| r.eliminated)
                .map(|r| EliminatedArm {
                    strategy: r.strategy.clone(),
                    round: r.round,
                    predicted: r.predicted_next.unwrap_or(f64::NAN),
                    observed: r.accuracy,
                })
                .collect();
            let live: Vec<String> = strategies
                .iter()
                .filter(|s| !prior.iter().any(|r| r.strategy == **s && r.eliminated))
                .cloned()
                .collect();
            let slot2 = reg.restore(
                "job-77",
                JobState {
                    status: JobStatus::Running,
                    strategies: strategies.clone(),
                    live,
                    eliminated,
                    records: prior.clone(),
                    rounds: cut,
                    budget_spent: prior.len() * cfg.round_budget,
                    best_accuracy: prior.iter().map(|r| r.accuracy).fold(0.0, f64::max),
                    trace: None,
                },
            );
            drive_with(&slot2, task2, &strategies, &cfg, &metrics, &prior, None);
            let s = slot2.state.lock().unwrap();
            assert_eq!(s.status, JobStatus::Done, "cut at round {cut}");
            let got = s.trace.as_ref().unwrap();
            assert_eq!(got.records, full.records, "cut at round {cut}");
            assert_eq!(got.survivors, full.survivors, "cut at round {cut}");
            assert_eq!(got.stop, full.stop, "cut at round {cut}");
            assert_eq!(got.total_budget, full.total_budget, "cut at round {cut}");
            assert_eq!(s.records, full.records, "slot records, cut at round {cut}");
            assert_eq!(s.budget_spent, full.total_budget, "cut at round {cut}");
        }
    }

    #[test]
    fn registry_restore_advances_sequence_and_interrupted_is_terminal() {
        let reg = JobRegistry::new();
        let strategies = vec!["entropy".to_string()];
        let slot = reg.restore(
            "job-41",
            JobState {
                status: JobStatus::Interrupted,
                strategies: strategies.clone(),
                live: strategies.clone(),
                eliminated: vec![],
                records: vec![],
                rounds: 2,
                budget_spent: 40,
                best_accuracy: 0.5,
                trace: None,
            },
        );
        assert_eq!(slot.state.lock().unwrap().status.as_string(), "interrupted");
        assert!(!reg.any_running(), "interrupted is terminal");
        // new ids never collide with restored pre-crash ones
        let (id, slot2) = reg.create(&strategies);
        assert_eq!(id, "job-42");
        assert!(reg.any_running());
        // an interrupted job keeps its ledger queryable but agent_result
        // reports the terminal state as an error, like failed/cancelled
        let mut m = Map::new();
        m.insert("job", Value::from("job-41"));
        let status = rpc_status(&reg, &Value::Object(m.clone())).unwrap();
        assert_eq!(status.get("status").and_then(Value::as_str), Some("interrupted"));
        assert_eq!(status.get("budget_spent").and_then(Value::as_usize), Some(40));
        m.insert("wait_ms", Value::from(1usize));
        let err = rpc_result(&reg, &Value::Object(m)).unwrap_err();
        assert!(err.contains("interrupted"), "{err}");
        slot2.state.lock().unwrap().status = JobStatus::Done;
    }

    #[test]
    fn unknown_job_and_stop_reason_round_trip() {
        let reg = JobRegistry::new();
        let mut m = Map::new();
        m.insert("job", Value::from("nope"));
        let err = rpc_status(&reg, &Value::Object(m)).unwrap_err();
        assert!(err.contains("unknown job"), "{err}");
        for s in [
            StopReason::TargetReached,
            StopReason::BudgetExhausted,
            StopReason::Converged,
            StopReason::RoundLimit,
            StopReason::PoolExhausted,
        ] {
            assert_eq!(stop_from_str(stop_to_str(s)), Some(s));
        }
    }
}

//! The performance predictor: a negative exponential forecasting model
//! (paper §3.3, citing AutoLRS [Jin et al. '21]; evaluated in Fig 5a).
//!
//! Accuracy-vs-budget curves of AL runs saturate, so the agent fits
//!
//!   a(x) = a_inf - (a_inf - a_0) * exp(-k * (x - x_0))
//!
//! to the observed (budget, accuracy) history of each strategy and
//! extrapolates the next round. Fitting: `a_0`/`x_0` are pinned to the
//! first observation; for each candidate asymptote `a_inf` on a grid the
//! optimal rate `k` has a closed-form least-squares solution in log space;
//! the (a_inf, k) pair minimizing SSE in *accuracy* space wins. A golden-
//! section refinement pass tightens a_inf between grid neighbors.

/// Fitted negative-exponential curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NegExpPredictor {
    pub a_inf: f64,
    pub a0: f64,
    pub x0: f64,
    pub k: f64,
    /// Sum of squared residuals on the training points.
    pub sse: f64,
}

impl NegExpPredictor {
    /// Fit to observed budgets `xs` (monotone increasing) and accuracies
    /// `ys` in [0, 1]. Needs >= 2 points; with exactly 2 the fit is exact
    /// through both. Returns None on degenerate input.
    pub fn fit(xs: &[f64], ys: &[f64]) -> Option<NegExpPredictor> {
        if xs.len() != ys.len() || xs.len() < 2 {
            return None;
        }
        if xs.windows(2).any(|w| w[1] <= w[0]) {
            return None;
        }
        let x0 = xs[0];
        let a0 = ys[0];
        let y_max = ys.iter().cloned().fold(f64::MIN, f64::max);

        // Degenerate: flat or decreasing history -> predict flat.
        if y_max <= a0 + 1e-9 {
            return Some(NegExpPredictor { a_inf: a0, a0, x0, k: 0.0, sse: 0.0 });
        }

        let eval_sse = |a_inf: f64, k: f64| -> f64 {
            xs.iter()
                .zip(ys)
                .map(|(&x, &y)| {
                    let p = a_inf - (a_inf - a0) * (-k * (x - x0)).exp();
                    (p - y) * (p - y)
                })
                .sum()
        };

        // Closed-form k for fixed a_inf: z_i = ln((a_inf - y_i)/(a_inf - a0))
        // should equal -k (x_i - x0); least squares k = -Σ z u / Σ u².
        let k_for = |a_inf: f64| -> Option<f64> {
            let denom0 = a_inf - a0;
            if denom0 <= 1e-12 {
                return None;
            }
            let mut num = 0.0;
            let mut den = 0.0;
            for (&x, &y) in xs.iter().zip(ys).skip(1) {
                let r = (a_inf - y) / denom0;
                if r <= 1e-12 {
                    return None; // y touches/exceeds the asymptote
                }
                let z = r.ln();
                let u = x - x0;
                num += z * u;
                den += u * u;
            }
            if den <= 0.0 {
                return None;
            }
            let k = -num / den;
            (k >= 0.0).then_some(k)
        };

        let mut best: Option<(f64, f64, f64)> = None; // (sse, a_inf, k)
        let lo = y_max + 1e-6;
        let hi = 1.0_f64.max(lo + 0.25); // allow overshoot targets > 1 for mid-curve fits
        let grid = 200;
        for g in 0..=grid {
            let a_inf = lo + (hi - lo) * g as f64 / grid as f64;
            if let Some(k) = k_for(a_inf) {
                let sse = eval_sse(a_inf, k);
                if best.map_or(true, |(b, _, _)| sse < b) {
                    best = Some((sse, a_inf, k));
                }
            }
        }
        let (mut sse, mut a_inf, mut k) = best?;

        // golden-section refinement around the winning asymptote
        let step = (hi - lo) / grid as f64;
        let (mut a, mut b) = ((a_inf - step).max(lo), a_inf + step);
        for _ in 0..40 {
            let phi = 0.618_033_988_75;
            let m1 = b - phi * (b - a);
            let m2 = a + phi * (b - a);
            let s1 = k_for(m1).map(|kk| eval_sse(m1, kk)).unwrap_or(f64::INFINITY);
            let s2 = k_for(m2).map(|kk| eval_sse(m2, kk)).unwrap_or(f64::INFINITY);
            if s1 < s2 {
                b = m2;
            } else {
                a = m1;
            }
        }
        let mid = 0.5 * (a + b);
        if let Some(kk) = k_for(mid) {
            let s = eval_sse(mid, kk);
            if s < sse {
                sse = s;
                a_inf = mid;
                k = kk;
            }
        }
        Some(NegExpPredictor { a_inf, a0, x0, k, sse })
    }

    /// Predicted accuracy at budget `x`.
    pub fn predict(&self, x: f64) -> f64 {
        self.a_inf - (self.a_inf - self.a0) * (-self.k * (x - self.x0)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;

    fn curve(a_inf: f64, a0: f64, k: f64, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| a_inf - (a_inf - a0) * (-k * (x - xs[0])).exp()).collect()
    }

    #[test]
    fn recovers_exact_negexp_curve() {
        let xs: Vec<f64> = (0..6).map(|i| 1000.0 * (i + 1) as f64).collect();
        let ys = curve(0.92, 0.55, 0.0007, &xs);
        let p = NegExpPredictor::fit(&xs, &ys).unwrap();
        // next-round prediction is what PSHEA consumes
        let x_next = 7000.0;
        let want = 0.92 - (0.92 - 0.55) * (-0.0007f64 * (x_next - xs[0])).exp();
        assert!(
            (p.predict(x_next) - want).abs() < 0.005,
            "pred {} want {want}",
            p.predict(x_next)
        );
        assert!((p.a_inf - 0.92).abs() < 0.03, "a_inf {}", p.a_inf);
    }

    #[test]
    fn prop_recovers_random_negexp_curves() {
        crate::util::prop::check("negexp-recovery", 60, |rng| {
            let a0 = 0.3 + 0.3 * rng.f64();
            let a_inf = a0 + 0.1 + 0.4 * rng.f64();
            let k = 0.0003 + 0.002 * rng.f64();
            let n = 4 + rng.below(5);
            let xs: Vec<f64> = (0..n).map(|i| 500.0 * (i + 1) as f64).collect();
            let ys = curve(a_inf, a0, k, &xs);
            let p = NegExpPredictor::fit(&xs, &ys)
                .ok_or_else(|| "fit failed".to_string())?;
            let x_next = xs.last().unwrap() + 500.0;
            let want = a_inf - (a_inf - a0) * (-k * (x_next - xs[0])).exp();
            prop_assert!(
                (p.predict(x_next) - want).abs() < 0.01,
                "pred {} want {want} (a_inf {a_inf} k {k} n {n})",
                p.predict(x_next)
            );
            Ok(())
        });
    }

    #[test]
    fn noisy_curve_predicts_within_a_point() {
        let mut rng = crate::util::rng::Rng::new(8);
        let xs: Vec<f64> = (0..8).map(|i| 1000.0 * (i + 1) as f64).collect();
        let clean = curve(0.88, 0.60, 0.0005, &xs);
        let noisy: Vec<f64> =
            clean.iter().map(|y| y + 0.004 * rng.normal()).collect();
        let p = NegExpPredictor::fit(&xs[..6], &noisy[..6]).unwrap();
        let want7 = clean[6];
        assert!((p.predict(xs[6]) - want7).abs() < 0.01, "{} vs {want7}", p.predict(xs[6]));
    }

    #[test]
    fn flat_history_predicts_flat() {
        let xs = [100.0, 200.0, 300.0];
        let ys = [0.7, 0.7, 0.7];
        let p = NegExpPredictor::fit(&xs, &ys).unwrap();
        assert!((p.predict(400.0) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn decreasing_history_does_not_explode() {
        let xs = [100.0, 200.0, 300.0];
        let ys = [0.7, 0.65, 0.6];
        let p = NegExpPredictor::fit(&xs, &ys).unwrap();
        let pred = p.predict(400.0);
        assert!((0.0..=1.0).contains(&pred), "pred {pred}");
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(NegExpPredictor::fit(&[1.0], &[0.5]).is_none());
        assert!(NegExpPredictor::fit(&[1.0, 1.0], &[0.5, 0.6]).is_none());
        assert!(NegExpPredictor::fit(&[2.0, 1.0], &[0.5, 0.6]).is_none());
        assert!(NegExpPredictor::fit(&[], &[]).is_none());
    }

    /// Noiseless curves identify their asymptote: the fitted `a_inf` is
    /// what PSHEA ultimately ranks arms by, so recovery must hold across
    /// the whole (a0, a_inf, k) range the loop sees.
    #[test]
    fn prop_recovers_asymptote_on_noiseless_curves() {
        crate::util::prop::check("negexp-asymptote", 60, |rng| {
            let a0 = 0.3 + 0.3 * rng.f64();
            let a_inf = a0 + 0.15 + 0.35 * rng.f64();
            let k = 0.001 + 0.002 * rng.f64();
            let n = 5 + rng.below(4);
            let xs: Vec<f64> = (0..n).map(|i| 500.0 * (i + 1) as f64).collect();
            let ys = curve(a_inf, a0, k, &xs);
            let p = NegExpPredictor::fit(&xs, &ys)
                .ok_or_else(|| "fit failed".to_string())?;
            prop_assert!(
                (p.a_inf - a_inf).abs() < 0.05,
                "a_inf {} want {a_inf} (a0 {a0} k {k} n {n})",
                p.a_inf
            );
            Ok(())
        });
    }

    /// Any monotone nondecreasing history fits to a nonnegative-rate
    /// curve whose predictions are themselves monotone in `x` and bounded
    /// by the asymptote.
    #[test]
    fn prop_predictions_monotone_for_monotone_histories() {
        crate::util::prop::check("negexp-monotone", 60, |rng| {
            let n = 3 + rng.below(6);
            let mut y = 0.2 + 0.3 * rng.f64();
            let mut ys = Vec::with_capacity(n);
            for _ in 0..n {
                ys.push(y);
                y += rng.f64() * 0.25 * (0.95 - y).max(0.0);
            }
            let xs: Vec<f64> = (0..n).map(|i| 300.0 * (i + 1) as f64).collect();
            let p = NegExpPredictor::fit(&xs, &ys)
                .ok_or_else(|| "fit failed on monotone history".to_string())?;
            prop_assert!(p.k >= 0.0, "negative rate {}", p.k);
            let last = *xs.last().unwrap();
            let mut prev = p.predict(last);
            for step in 1..16 {
                let cur = p.predict(last + 300.0 * step as f64);
                prop_assert!(cur >= prev - 1e-9, "not monotone at step {step}");
                prop_assert!(cur <= p.a_inf + 1e-9, "overshoots asymptote");
                prev = cur;
            }
            Ok(())
        });
    }

    /// Degenerate histories (constant, 2-point, arbitrary/decreasing)
    /// never panic; when a fit comes back its predictions are finite and
    /// sane.
    #[test]
    fn prop_degenerate_histories_never_panic() {
        crate::util::prop::check("negexp-degenerate", 80, |rng| {
            match rng.below(3) {
                0 => {
                    // constant history -> flat forecast at the constant
                    let n = 2 + rng.below(6);
                    let c = rng.f64();
                    let xs: Vec<f64> = (0..n).map(|i| 100.0 * (i + 1) as f64).collect();
                    let ys = vec![c; n];
                    let p = NegExpPredictor::fit(&xs, &ys)
                        .ok_or_else(|| "flat fit failed".to_string())?;
                    prop_assert!(
                        (p.predict(*xs.last().unwrap() + 500.0) - c).abs() < 1e-9,
                        "flat history must predict flat"
                    );
                }
                1 => {
                    // 2 increasing points -> the fit passes through both
                    let y0 = 0.2 + 0.4 * rng.f64();
                    let y1 = y0 + 0.05 + 0.3 * rng.f64();
                    let xs = [200.0, 700.0];
                    let ys = [y0, y1];
                    let p = NegExpPredictor::fit(&xs, &ys)
                        .ok_or_else(|| "2-point fit failed".to_string())?;
                    prop_assert!(
                        (p.predict(xs[1]) - y1).abs() < 1e-6,
                        "2-point fit not exact: {} vs {y1}",
                        p.predict(xs[1])
                    );
                    let next = p.predict(1200.0);
                    prop_assert!(next.is_finite() && (0.0..=2.0).contains(&next));
                }
                _ => {
                    // arbitrary (possibly decreasing) history: fit may
                    // decline, but must not panic, and any prediction it
                    // does produce stays finite and bounded
                    let n = 2 + rng.below(6);
                    let xs: Vec<f64> = (0..n).map(|i| 100.0 * (i + 1) as f64).collect();
                    let ys: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
                    if let Some(p) = NegExpPredictor::fit(&xs, &ys) {
                        let next = p.predict(*xs.last().unwrap() + 300.0);
                        prop_assert!(
                            next.is_finite() && (-1.0..=2.0).contains(&next),
                            "wild prediction {next} from {ys:?}"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_increasing_prediction() {
        let xs = [1000.0, 2000.0, 3000.0, 4000.0];
        let ys = [0.5, 0.62, 0.69, 0.73];
        let p = NegExpPredictor::fit(&xs, &ys).unwrap();
        let mut prev = p.predict(4000.0);
        for i in 1..20 {
            let cur = p.predict(4000.0 + 500.0 * i as f64);
            assert!(cur >= prev - 1e-12, "not monotone at {i}");
            prev = cur;
        }
        assert!(prev <= p.a_inf + 1e-9, "saturates at a_inf");
    }
}

//! The AL agent (paper §3.3): performance predictor + PSHEA controller.

mod predictor;
mod pshea;

pub use predictor::NegExpPredictor;
pub use pshea::{AlTask, PsheaConfig, PsheaTrace, RoundRecord, StopReason, run_pshea};

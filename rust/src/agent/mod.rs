//! The AL agent (paper §3.3): performance predictor + PSHEA controller,
//! plus the server-side job machinery that runs the loop as a service
//! (DESIGN.md §Agent).

pub mod job;
mod predictor;
mod pshea;

pub use predictor::NegExpPredictor;
pub use pshea::{
    run_pshea, run_pshea_observed, run_pshea_resumed, AlTask, PsheaConfig, PsheaObserver,
    PsheaTrace, RoundRecord, StopReason,
};

/// Per-round strategy seed derivation. `sim::AlExperiment` (in-process)
/// and the served agent job both derive their `SelectCtx` seed from the
/// experiment seed and the arm's completed-round count through this one
/// function — remote-vs-local PSHEA parity depends on it.
pub fn arm_round_seed(base: u64, n_prev_rounds: u64) -> u64 {
    base ^ n_prev_rounds.wrapping_mul(0x9E37_79B9)
}

//! Minimal CLI argument parser (clap is not in the offline registry).
//!
//! Supports the subcommand + `--flag value` / `--flag` grammar the `alaas`
//! binary uses. Unknown flags are errors (typos should not silently pick
//! defaults).

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positionals: Vec<String>,
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CliError {
    #[error("missing subcommand (try `alaas help`)")]
    NoSubcommand,
    #[error("unknown flag '--{0}'")]
    UnknownFlag(String),
    #[error("flag '--{0}' expects a value")]
    MissingValue(String),
    #[error("flag '--{flag}' has invalid value '{value}': {reason}")]
    BadValue { flag: String, value: String, reason: String },
}

/// Flag schema: which flags take values, which are boolean switches.
pub struct Schema {
    pub value_flags: &'static [&'static str],
    pub bool_flags: &'static [&'static str],
}

impl Args {
    /// Parse argv (without the program name) against a schema.
    pub fn parse(argv: &[String], schema: &Schema) -> Result<Args, CliError> {
        let mut it = argv.iter().peekable();
        let subcommand = it.next().cloned().ok_or(CliError::NoSubcommand)?;
        let mut args = Args { subcommand, ..Default::default() };
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --flag=value form
                if let Some((n, v)) = name.split_once('=') {
                    if schema.value_flags.contains(&n) {
                        args.flags.insert(n.to_string(), v.to_string());
                        continue;
                    }
                    return Err(CliError::UnknownFlag(n.to_string()));
                }
                if schema.bool_flags.contains(&name) {
                    args.bools.push(name.to_string());
                } else if schema.value_flags.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
                    args.flags.insert(name.to_string(), v.clone());
                } else {
                    return Err(CliError::UnknownFlag(name.to_string()));
                }
            } else {
                args.positionals.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.bools.iter().any(|b| b == flag)
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize, CliError> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                flag: flag.to_string(),
                value: v.to_string(),
                reason: "expected unsigned integer".into(),
            }),
        }
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64, CliError> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                flag: flag.to_string(),
                value: v.to_string(),
                reason: "expected number".into(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCHEMA: Schema = Schema {
        value_flags: &["config", "budget", "strategy", "seed"],
        bool_flags: &["verbose"],
    };

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(&argv("serve --config x.yml --verbose extra"), &SCHEMA).unwrap();
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.get("config"), Some("x.yml"));
        assert!(a.has("verbose"));
        assert_eq!(a.positionals, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&argv("query --budget=100"), &SCHEMA).unwrap();
        assert_eq!(a.get_usize("budget", 0).unwrap(), 100);
    }

    #[test]
    fn errors() {
        assert_eq!(Args::parse(&[], &SCHEMA), Err(CliError::NoSubcommand));
        assert_eq!(
            Args::parse(&argv("x --nope 1"), &SCHEMA),
            Err(CliError::UnknownFlag("nope".into()))
        );
        assert_eq!(
            Args::parse(&argv("x --budget"), &SCHEMA),
            Err(CliError::MissingValue("budget".into()))
        );
        assert!(matches!(
            Args::parse(&argv("x --budget ten"), &SCHEMA).unwrap().get_usize("budget", 0),
            Err(CliError::BadValue { .. })
        ));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("x"), &SCHEMA).unwrap();
        assert_eq!(a.get_or("strategy", "least_confidence"), "least_confidence");
        assert_eq!(a.get_usize("budget", 42).unwrap(), 42);
        assert_eq!(a.get_f64("seed", 1.5).unwrap(), 1.5);
    }
}

//! Dataset manifest: the index the AL client pushes to the server.
//!
//! A manifest lists sample URIs per split (`init` labeled seed, `pool`
//! unlabeled candidates, `test` evaluation set) plus image geometry.
//! Ground-truth labels are intentionally NOT part of the manifest — they
//! live in a separate `labels.json` object that only the oracle
//! (`data::Oracle`) reads, mirroring the human-annotator boundary in
//! Figure 1.

use crate::json::{self, Map, Value};

/// One sample reference.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SampleRef {
    /// Stable id (index into labels.json).
    pub id: u32,
    /// Where the raw bytes live.
    pub uri: String,
}

/// Dataset manifest (what `push_data` transfers).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub name: String,
    pub num_classes: usize,
    pub img_dim: usize,
    pub init: Vec<SampleRef>,
    pub pool: Vec<SampleRef>,
    pub test: Vec<SampleRef>,
}

#[derive(Debug, thiserror::Error)]
#[error("manifest error: {0}")]
pub struct ManifestError(pub String);

impl Manifest {
    pub fn to_value(&self) -> Value {
        fn split(samples: &[SampleRef]) -> Value {
            Value::Array(
                samples
                    .iter()
                    .map(|s| {
                        let mut m = Map::new();
                        m.insert("id", Value::from(s.id as u64));
                        m.insert("uri", Value::from(s.uri.clone()));
                        Value::Object(m)
                    })
                    .collect(),
            )
        }
        let mut m = Map::new();
        m.insert("name", Value::from(self.name.clone()));
        m.insert("num_classes", Value::from(self.num_classes));
        m.insert("img_dim", Value::from(self.img_dim));
        m.insert("init", split(&self.init));
        m.insert("pool", split(&self.pool));
        m.insert("test", split(&self.test));
        Value::Object(m)
    }

    pub fn from_value(v: &Value) -> Result<Manifest, ManifestError> {
        fn split(v: &Value, name: &str) -> Result<Vec<SampleRef>, ManifestError> {
            let arr = v
                .get(name)
                .and_then(Value::as_array)
                .ok_or_else(|| ManifestError(format!("missing split '{name}'")))?;
            arr.iter()
                .map(|e| {
                    let id = e
                        .get("id")
                        .and_then(Value::as_usize)
                        .ok_or_else(|| ManifestError(format!("{name}: sample missing id")))?;
                    let uri = e
                        .get("uri")
                        .and_then(Value::as_str)
                        .ok_or_else(|| ManifestError(format!("{name}: sample missing uri")))?;
                    Ok(SampleRef { id: id as u32, uri: uri.to_string() })
                })
                .collect()
        }
        Ok(Manifest {
            name: v
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| ManifestError("missing name".into()))?
                .to_string(),
            num_classes: v
                .get("num_classes")
                .and_then(Value::as_usize)
                .ok_or_else(|| ManifestError("missing num_classes".into()))?,
            img_dim: v
                .get("img_dim")
                .and_then(Value::as_usize)
                .ok_or_else(|| ManifestError("missing img_dim".into()))?,
            init: split(v, "init")?,
            pool: split(v, "pool")?,
            test: split(v, "test")?,
        })
    }

    pub fn to_json(&self) -> String {
        json::to_string_pretty(&self.to_value())
    }

    pub fn from_json(s: &str) -> Result<Manifest, ManifestError> {
        let v = json::parse(s).map_err(|e| ManifestError(e.to_string()))?;
        Self::from_value(&v)
    }

    pub fn total_samples(&self) -> usize {
        self.init.len() + self.pool.len() + self.test.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Manifest {
        Manifest {
            name: "cifarsim".into(),
            num_classes: 10,
            img_dim: 3072,
            init: vec![SampleRef { id: 0, uri: "mem://d/init/0.bin".into() }],
            pool: vec![
                SampleRef { id: 1, uri: "mem://d/pool/1.bin".into() },
                SampleRef { id: 2, uri: "mem://d/pool/2.bin".into() },
            ],
            test: vec![SampleRef { id: 3, uri: "mem://d/test/3.bin".into() }],
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = sample_manifest();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.total_samples(), 4);
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::from_json("{}").is_err());
        assert!(Manifest::from_json("{\"name\":\"x\"}").is_err());
        let no_uri = r#"{"name":"x","num_classes":2,"img_dim":4,
            "init":[{"id":0}],"pool":[],"test":[]}"#;
        assert!(Manifest::from_json(no_uri).is_err());
    }

    #[test]
    fn labels_not_in_manifest() {
        // The oracle boundary: a manifest must never carry labels.
        let m = sample_manifest();
        let s = m.to_json();
        assert!(!s.contains("label"), "manifest leaked labels: {s}");
    }
}

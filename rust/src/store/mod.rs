//! Object-store substrate: where pushed datasets live (Figure 1's "local
//! disk or AWS S3").
//!
//! * `MemStore` — in-process, for tests and `mem://` URIs.
//! * `LocalFsStore` — directory-backed, for `file://` URIs.
//! * `S3SimStore` — the S3 substitution (DESIGN.md): wraps another store
//!   and injects a deterministic per-GET latency + bandwidth model, which
//!   is what makes the Fig 4c batch-size phenomenon reproducible without
//!   AWS.
//!
//! `resolve()` maps a parsed `Uri` onto the right backend, and `Manifest`
//! is the dataset index (sample URIs + split sizes) the client pushes.

mod latency;
mod manifest;

pub use latency::LatencyModel;
pub use manifest::{Manifest, SampleRef};

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use crate::config::StoreConfig;
use crate::uri::{Scheme, Uri};

/// Store operation failure.
#[derive(Debug, thiserror::Error)]
pub enum StoreError {
    #[error("object not found: {0}")]
    NotFound(String),
    #[error("io error on {key}: {source}")]
    Io {
        key: String,
        #[source]
        source: std::io::Error,
    },
    #[error("injected fault: {0}")]
    Injected(String),
}

pub type StoreResult<T> = Result<T, StoreError>;

/// Blob storage interface. Implementations must be thread-safe: the fetch
/// stage hits them from many threads at once.
pub trait ObjectStore: Send + Sync {
    /// Fetch a whole object.
    fn get(&self, key: &str) -> StoreResult<Vec<u8>>;
    /// Store a whole object (replaces).
    fn put(&self, key: &str, data: &[u8]) -> StoreResult<()>;
    /// True if the object exists.
    fn exists(&self, key: &str) -> bool;
    /// Keys under a prefix, sorted.
    fn list(&self, prefix: &str) -> StoreResult<Vec<String>>;
    /// Human-readable backend tag (metrics labels).
    fn kind(&self) -> &'static str;
}

/// In-process store (tests, `mem://`).
#[derive(Default)]
pub struct MemStore {
    objects: RwLock<HashMap<String, Arc<Vec<u8>>>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.objects.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl ObjectStore for MemStore {
    fn get(&self, key: &str) -> StoreResult<Vec<u8>> {
        self.objects
            .read()
            .unwrap()
            .get(key)
            .map(|a| a.as_ref().clone())
            .ok_or_else(|| StoreError::NotFound(key.to_string()))
    }

    fn put(&self, key: &str, data: &[u8]) -> StoreResult<()> {
        self.objects.write().unwrap().insert(key.to_string(), Arc::new(data.to_vec()));
        Ok(())
    }

    fn exists(&self, key: &str) -> bool {
        self.objects.read().unwrap().contains_key(key)
    }

    fn list(&self, prefix: &str) -> StoreResult<Vec<String>> {
        let mut keys: Vec<String> = self
            .objects
            .read()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.sort();
        Ok(keys)
    }

    fn kind(&self) -> &'static str {
        "mem"
    }
}

/// Directory-backed store (`file://`). Keys are relative paths under the
/// root; `..` segments are rejected.
pub struct LocalFsStore {
    root: PathBuf,
}

impl LocalFsStore {
    pub fn new(root: impl Into<PathBuf>) -> std::io::Result<Self> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        Ok(LocalFsStore { root })
    }

    fn path_for(&self, key: &str) -> StoreResult<PathBuf> {
        if key.split('/').any(|seg| seg == "..") {
            return Err(StoreError::Io {
                key: key.to_string(),
                source: std::io::Error::new(std::io::ErrorKind::InvalidInput, "path escape"),
            });
        }
        Ok(self.root.join(key.trim_start_matches('/')))
    }
}

impl ObjectStore for LocalFsStore {
    fn get(&self, key: &str) -> StoreResult<Vec<u8>> {
        let path = self.path_for(key)?;
        let mut f = std::fs::File::open(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                StoreError::NotFound(key.to_string())
            } else {
                StoreError::Io { key: key.to_string(), source: e }
            }
        })?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)
            .map_err(|e| StoreError::Io { key: key.to_string(), source: e })?;
        Ok(buf)
    }

    fn put(&self, key: &str, data: &[u8]) -> StoreResult<()> {
        let path = self.path_for(key)?;
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| StoreError::Io { key: key.to_string(), source: e })?;
        }
        let mut f = std::fs::File::create(&path)
            .map_err(|e| StoreError::Io { key: key.to_string(), source: e })?;
        f.write_all(data).map_err(|e| StoreError::Io { key: key.to_string(), source: e })
    }

    fn exists(&self, key: &str) -> bool {
        self.path_for(key).map(|p| p.exists()).unwrap_or(false)
    }

    fn list(&self, prefix: &str) -> StoreResult<Vec<String>> {
        let mut out = Vec::new();
        let mut stack = vec![self.root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match std::fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue,
            };
            for e in entries.flatten() {
                let p = e.path();
                if p.is_dir() {
                    stack.push(p);
                } else if let Ok(rel) = p.strip_prefix(&self.root) {
                    let key = rel.to_string_lossy().replace('\\', "/");
                    if key.starts_with(prefix) {
                        out.push(key);
                    }
                }
            }
        }
        out.sort();
        Ok(out)
    }

    fn kind(&self) -> &'static str {
        "localfs"
    }
}

/// The S3 substitution: inner store + injected network model + optional
/// fault injection (failure-rate per key pattern, for resilience tests).
pub struct S3SimStore {
    inner: Arc<dyn ObjectStore>,
    latency: LatencyModel,
    /// Keys matching this substring fail with `Injected` (tests).
    fault_substring: RwLock<Option<String>>,
}

impl S3SimStore {
    pub fn new(inner: Arc<dyn ObjectStore>, cfg: &StoreConfig) -> Self {
        S3SimStore {
            inner,
            latency: LatencyModel::from_config(cfg),
            fault_substring: RwLock::new(None),
        }
    }

    /// Make every key containing `pat` fail (failure-injection tests);
    /// `None` clears.
    pub fn inject_fault(&self, pat: Option<String>) {
        *self.fault_substring.write().unwrap() = pat;
    }

    pub fn latency_model(&self) -> &LatencyModel {
        &self.latency
    }
}

impl ObjectStore for S3SimStore {
    fn get(&self, key: &str) -> StoreResult<Vec<u8>> {
        if let Some(pat) = self.fault_substring.read().unwrap().as_deref() {
            if key.contains(pat) {
                return Err(StoreError::Injected(format!("GET {key}")));
            }
        }
        let data = self.inner.get(key)?;
        self.latency.sleep_for_get(key, data.len());
        Ok(data)
    }

    fn put(&self, key: &str, data: &[u8]) -> StoreResult<()> {
        self.inner.put(key, data)?;
        self.latency.sleep_for_put(key, data.len());
        Ok(())
    }

    fn exists(&self, key: &str) -> bool {
        self.inner.exists(key)
    }

    fn list(&self, prefix: &str) -> StoreResult<Vec<String>> {
        let keys = self.inner.list(prefix)?;
        self.latency.sleep_for_get(prefix, 64 * keys.len().max(1));
        Ok(keys)
    }

    fn kind(&self) -> &'static str {
        "s3sim"
    }
}

/// Multi-backend router: resolves a `Uri` to (store, key).
pub struct StoreRouter {
    mem: Arc<MemStore>,
    s3sim_backing: Arc<MemStore>,
    s3sim: Arc<S3SimStore>,
    fs_root: PathBuf,
}

impl StoreRouter {
    /// `fs_root` anchors `file://` keys; s3sim rides on an in-process
    /// backing store configured by `cfg`.
    pub fn new(fs_root: impl Into<PathBuf>, cfg: &StoreConfig) -> Self {
        let s3sim_backing = Arc::new(MemStore::new());
        let s3sim = Arc::new(S3SimStore::new(s3sim_backing.clone() as Arc<dyn ObjectStore>, cfg));
        StoreRouter {
            mem: Arc::new(MemStore::new()),
            s3sim_backing,
            s3sim,
            fs_root: fs_root.into(),
        }
    }

    /// The store serving a scheme. `file://` URIs carry absolute paths, so
    /// the LocalFsStore is rooted at `/` for them.
    pub fn store_for(&self, scheme: Scheme) -> Arc<dyn ObjectStore> {
        match scheme {
            Scheme::Mem => self.mem.clone(),
            Scheme::S3Sim => self.s3sim.clone(),
            Scheme::File => Arc::new(
                LocalFsStore::new(self.fs_root.clone()).expect("fs root must be creatable"),
            ),
        }
    }

    /// Backend key for a URI (bucket folded into the key for bucketed
    /// schemes so one backing store serves many buckets).
    pub fn key_for(&self, uri: &Uri) -> String {
        match uri.scheme {
            Scheme::File => uri.key.clone(),
            _ => format!("{}/{}", uri.bucket, uri.key),
        }
    }

    pub fn get(&self, uri: &Uri) -> StoreResult<Vec<u8>> {
        self.store_for(uri.scheme).get(&self.key_for(uri))
    }

    pub fn put(&self, uri: &Uri, data: &[u8]) -> StoreResult<()> {
        self.store_for(uri.scheme).put(&self.key_for(uri), data)
    }

    /// Direct access to the s3sim layer (fault injection, latency stats).
    pub fn s3sim(&self) -> &S3SimStore {
        &self.s3sim
    }

    /// Bypass the latency model (dataset generation writes fast).
    pub fn s3sim_backing(&self) -> &MemStore {
        &self.s3sim_backing
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn cfg_fast() -> StoreConfig {
        StoreConfig { get_latency_us: 0, bandwidth_mib_s: 0.0, jitter: 0.0 }
    }

    #[test]
    fn mem_store_crud() {
        let s = MemStore::new();
        assert!(matches!(s.get("a"), Err(StoreError::NotFound(_))));
        s.put("a/b", b"hello").unwrap();
        assert_eq!(s.get("a/b").unwrap(), b"hello");
        assert!(s.exists("a/b"));
        s.put("a/b", b"replaced").unwrap();
        assert_eq!(s.get("a/b").unwrap(), b"replaced");
        s.put("a/c", b"x").unwrap();
        s.put("z", b"y").unwrap();
        assert_eq!(s.list("a/").unwrap(), vec!["a/b".to_string(), "a/c".to_string()]);
    }

    #[test]
    fn localfs_store_crud() {
        let dir = std::env::temp_dir().join(format!("alaas-test-{}", std::process::id()));
        let s = LocalFsStore::new(&dir).unwrap();
        s.put("pool/img1.bin", &[1, 2, 3]).unwrap();
        assert_eq!(s.get("pool/img1.bin").unwrap(), vec![1, 2, 3]);
        assert!(s.exists("pool/img1.bin"));
        assert!(!s.exists("pool/none.bin"));
        assert!(matches!(s.get("missing"), Err(StoreError::NotFound(_))));
        s.put("pool/img2.bin", &[4]).unwrap();
        assert_eq!(s.list("pool/").unwrap().len(), 2);
        assert!(s.get("../escape").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn s3sim_latency_is_injected() {
        let inner = Arc::new(MemStore::new());
        inner.put("k", &vec![0u8; 1024]).unwrap();
        let cfg = StoreConfig { get_latency_us: 2000, bandwidth_mib_s: 0.0, jitter: 0.0 };
        let s = S3SimStore::new(inner, &cfg);
        let t0 = Instant::now();
        s.get("k").unwrap();
        assert!(t0.elapsed().as_micros() >= 1800, "latency not applied: {:?}", t0.elapsed());
    }

    #[test]
    fn s3sim_bandwidth_scales_with_size() {
        let inner = Arc::new(MemStore::new());
        inner.put("small", &vec![0u8; 1_000]).unwrap();
        inner.put("big", &vec![0u8; 1_000_000]).unwrap();
        // 10 MiB/s -> 1MB ~ 95ms, 1KB ~ 0.1ms
        let cfg = StoreConfig { get_latency_us: 0, bandwidth_mib_s: 10.0, jitter: 0.0 };
        let s = S3SimStore::new(inner, &cfg);
        let t0 = Instant::now();
        s.get("small").unwrap();
        let t_small = t0.elapsed();
        let t0 = Instant::now();
        s.get("big").unwrap();
        let t_big = t0.elapsed();
        assert!(t_big > t_small * 20, "big={t_big:?} small={t_small:?}");
    }

    #[test]
    fn s3sim_fault_injection() {
        let inner = Arc::new(MemStore::new());
        inner.put("x/poison", b"p").unwrap();
        inner.put("x/fine", b"f").unwrap();
        let s = S3SimStore::new(inner, &cfg_fast());
        s.inject_fault(Some("poison".into()));
        assert!(matches!(s.get("x/poison"), Err(StoreError::Injected(_))));
        assert_eq!(s.get("x/fine").unwrap(), b"f");
        s.inject_fault(None);
        assert_eq!(s.get("x/poison").unwrap(), b"p");
    }

    #[test]
    fn router_dispatches_by_scheme() {
        let router = StoreRouter::new("/tmp", &cfg_fast());
        let uri = Uri::parse("mem://bkt/sample.bin").unwrap();
        router.put(&uri, b"data").unwrap();
        assert_eq!(router.get(&uri).unwrap(), b"data");
        // same key through s3sim is a different namespace
        let uri2 = Uri::parse("s3sim://bkt/sample.bin").unwrap();
        assert!(router.get(&uri2).is_err());
        router.put(&uri2, b"s3data").unwrap();
        assert_eq!(router.get(&uri2).unwrap(), b"s3data");
    }

    #[test]
    fn concurrent_mem_access() {
        let s = Arc::new(MemStore::new());
        std::thread::scope(|sc| {
            for t in 0..8 {
                let s = s.clone();
                sc.spawn(move || {
                    for i in 0..100 {
                        let key = format!("t{t}/k{i}");
                        s.put(&key, &[t as u8, i as u8]).unwrap();
                        assert_eq!(s.get(&key).unwrap(), vec![t as u8, i as u8]);
                    }
                });
            }
        });
        assert_eq!(s.len(), 800);
    }
}

//! Deterministic network model for the simulated object store.
//!
//! Fig 4c's observation — "transmission time accounts for a large
//! proportion of the total processing time when the batch size is small" —
//! only reproduces if GETs pay a per-request cost plus a size-proportional
//! cost. This model injects exactly that: `latency + size/bandwidth`,
//! with jitter derived from a hash of the key so a run is bit-identical
//! across repeats (no wall-clock entropy in experiments).

use std::time::Duration;

use crate::config::StoreConfig;

/// Per-request latency + bandwidth + deterministic jitter.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    get_latency: Duration,
    /// Seconds per byte (0 = infinite bandwidth).
    secs_per_byte: f64,
    jitter: f64,
}

impl LatencyModel {
    pub fn from_config(cfg: &StoreConfig) -> Self {
        let secs_per_byte = if cfg.bandwidth_mib_s > 0.0 {
            1.0 / (cfg.bandwidth_mib_s * 1024.0 * 1024.0)
        } else {
            0.0
        };
        LatencyModel {
            get_latency: Duration::from_micros(cfg.get_latency_us),
            secs_per_byte,
            jitter: cfg.jitter,
        }
    }

    /// No delays at all (unit tests).
    pub fn zero() -> Self {
        LatencyModel { get_latency: Duration::ZERO, secs_per_byte: 0.0, jitter: 0.0 }
    }

    /// Jitter factor in [1-j, 1+j], a pure function of the key.
    fn jitter_factor(&self, key: &str) -> f64 {
        if self.jitter == 0.0 {
            return 1.0;
        }
        // FNV-1a -> uniform in [0,1)
        let h = crate::util::fnv1a(key.as_bytes());
        let u = (h >> 11) as f64 / (1u64 << 53) as f64;
        1.0 + self.jitter * (2.0 * u - 1.0)
    }

    /// Total simulated duration of a GET of `size` bytes.
    pub fn get_duration(&self, key: &str, size: usize) -> Duration {
        let base = self.get_latency.as_secs_f64() + self.secs_per_byte * size as f64;
        Duration::from_secs_f64(base * self.jitter_factor(key))
    }

    /// Block the calling thread for the simulated GET time.
    pub fn sleep_for_get(&self, key: &str, size: usize) {
        let d = self.get_duration(key, size);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    /// PUTs pay the same model (uploads during dataset generation bypass
    /// this via the backing store).
    pub fn sleep_for_put(&self, key: &str, size: usize) {
        self.sleep_for_get(key, size);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(lat_us: u64, bw: f64, jitter: f64) -> LatencyModel {
        LatencyModel::from_config(&StoreConfig {
            get_latency_us: lat_us,
            bandwidth_mib_s: bw,
            jitter,
        })
    }

    #[test]
    fn duration_composition() {
        let m = model(1000, 1.0, 0.0); // 1ms + 1 MiB/s
        let d = m.get_duration("k", 1024 * 1024);
        assert!((d.as_secs_f64() - 1.001).abs() < 0.01, "{d:?}");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let m = model(1000, 0.0, 0.2);
        let d1 = m.get_duration("key-a", 0);
        let d2 = m.get_duration("key-a", 0);
        assert_eq!(d1, d2, "same key same delay");
        let base = 0.001;
        for key in ["a", "b", "c", "dd", "eee"] {
            let d = m.get_duration(key, 0).as_secs_f64();
            assert!(d >= base * 0.8 - 1e-9 && d <= base * 1.2 + 1e-9, "{key}: {d}");
        }
        // different keys should not all collapse to the same factor
        let da = m.get_duration("a", 0);
        let db = m.get_duration("b", 0);
        assert_ne!(da, db);
    }

    #[test]
    fn zero_model_never_sleeps() {
        let m = LatencyModel::zero();
        assert_eq!(m.get_duration("k", 1 << 30), Duration::ZERO);
    }
}

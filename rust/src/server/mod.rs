//! Server–client architecture (paper §3.2, Figure 1) — "users can use AL
//! as a web service".
//!
//! * [`rpc`] — wire protocol: 4-byte-LE length-prefixed JSON frames over
//!   TCP (the gRPC substitution; DESIGN.md §Substitutions).
//! * [`server`] — `AlServer`: sessions, background dataset processing
//!   through the pipeline, query serving, the agent endpoint, metrics.
//!   Also speaks the worker-facing cluster methods (`scan_shard`,
//!   `select_shard`) so any server can join a coordinator's pool
//!   (DESIGN.md §Cluster).
//! * [`client`] — `AlClient`: the few-LoC user-facing API of Figure 2
//!   (`push_data`, `query(budget)`).

pub mod client;
pub mod rpc;
#[allow(clippy::module_inception)]
pub mod server;

pub use client::AlClient;
pub use server::{AlServer, ServerDeps, SELECT_SEED};

//! Server–client architecture (paper §3.2, Figure 1) — "users can use AL
//! as a web service".
//!
//! * [`rpc`] — wire protocol: 4-byte-LE length-prefixed frames over TCP
//!   (the gRPC substitution; DESIGN.md §Substitutions), JSON (v1) or
//!   binary-tensor (v2) payloads.
//! * [`wire`] — the v2 binary tensor data plane: JSON control header +
//!   raw little-endian f32 tensor sections, per-connection negotiation,
//!   `[server] wire` forcing knob, zero-copy decode views (DESIGN.md
//!   §Wire).
//! * [`pool`] — per-peer persistent connection pool: dial + negotiate
//!   once, reuse across calls, detect/evict stale sockets, `[server.pool]`
//!   knobs and `pool.*` metrics (DESIGN.md §Wire).
//! * [`server`] — `AlServer`: sessions, background dataset processing
//!   through the pipeline, query serving, the agent endpoint, metrics.
//!   Also speaks the worker-facing cluster methods (`scan_shard`,
//!   `select_shard`) so any server can join a coordinator's pool
//!   (DESIGN.md §Cluster).
//! * [`client`] — `AlClient`: the few-LoC user-facing API of Figure 2
//!   (`push_data`, `query(budget)`).

pub mod client;
pub mod pool;
pub mod rpc;
#[allow(clippy::module_inception)]
pub mod server;
pub mod wire;

pub use client::{AlClient, JobEvent, JobEventStream, SessionHandle, SessionOpts};
pub use pool::{ConnPool, PoolConfig};
pub use server::{AlServer, ServerDeps, SELECT_SEED};
pub use wire::{Body, MatRef, MatView, Payload, WireMode};

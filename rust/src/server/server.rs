//! `AlServer` — the AL service of Figure 1.
//!
//! Lifecycle: `AlServer::start(config, deps)` binds the TCP listener and
//! returns immediately; an accept thread hands each connection to a
//! handler pool. `push_data` registers a session and kicks off background
//! processing (optional head fine-tune on the init split, then the
//! pipelined pool scan); `query` blocks until the scan is ready and runs
//! the requested strategy over the scan outputs. All stages record into
//! the shared metrics registry served by the `metrics` method.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::agent::job::{self, AgentTask, ArmSelect, JobRegistry, Picked};
use crate::cache::DataCache;
use crate::cluster::recovery;
use crate::cluster::tenancy::{AdmissionGate, AdmitPermit, TenantRegistry};
use crate::config::{AlaasConfig, StrategyChoice};
use crate::json::{Map, Value};
use crate::metrics::Registry;
use crate::pipeline::{run_pipeline, BatchPolicy, DataflowMode, PipelineParams};
use crate::runtime::backend::ComputeBackend;
use crate::server::rpc::{self, ServiceError};
use crate::server::wire::{self, Body, Payload, WireMode};
use crate::store::{Manifest, SampleRef, StoreRouter};
use crate::strategies::{self, SelectCtx};
use crate::trainer::{self, LinearHead, TrainConfig};
use crate::util::mat::Mat;
use crate::util::pool::ThreadPool;

/// Seed for strategy-internal randomness at query time. One constant for
/// the single server and the cluster coordinator so distributed selection
/// reproduces the single-server path exactly (DESIGN.md §Cluster).
pub const SELECT_SEED: u64 = 0x5e1ec7;

/// Shared server dependencies (built once per process).
pub struct ServerDeps {
    pub store: Arc<StoreRouter>,
    pub cache: Arc<DataCache>,
    pub backend: Arc<dyn ComputeBackend>,
    pub metrics: Arc<Registry>,
}

#[derive(Debug, Clone, PartialEq)]
enum SessionStatus {
    Processing,
    Ready,
    Failed(String),
}

struct Session {
    manifest: Manifest,
    status: SessionStatus,
    head: LinearHead,
    /// Pool-scan outputs (embeddings/scores in manifest pool order).
    pool_emb: Option<Mat>,
    pool_scores: Option<Mat>,
    /// Indices of pool samples that failed processing (excluded from
    /// selection).
    failed: Vec<usize>,
    /// Init-split embeddings (labeled context for diversity strategies).
    init_emb: Option<Mat>,
    /// Init-split labels as pushed (the agent job retrains with them).
    init_labels: Option<Vec<u8>>,
    /// Test-split embeddings (agent-job accuracy evaluation; scanned when
    /// the manifest carries a test split).
    test_emb: Option<Mat>,
    scan_elapsed: Duration,
}

struct SessionSlot {
    s: Mutex<Session>,
    ready: Condvar,
}

struct ServerState {
    config: AlaasConfig,
    deps: ServerDeps,
    /// Distributed-tracing plane (DESIGN.md §Observability): request
    /// spans, slow-query log, and the `trace_recent`/`trace_get` RPCs.
    tracer: Arc<crate::trace::Tracer>,
    sessions: Mutex<HashMap<String, Arc<SessionSlot>>>,
    /// Multi-tenant session registry (DESIGN.md §Tenancy): the same
    /// token/quota surface the cluster coordinator serves.
    tenants: TenantRegistry,
    /// Weighted-fair admission gate over scatter-shaped work — a full
    /// strategy select or one agent arm round. The same gate the
    /// coordinator arbitrates its scatter path with, so one overloaded
    /// server sheds with the identical structured `overloaded` error
    /// (and `retry_after_ms` hint) instead of queueing without bound.
    gate: Arc<AdmissionGate>,
    /// Background PSHEA jobs (DESIGN.md §Agent).
    jobs: JobRegistry,
    /// Live-membership heartbeat loop when this server runs as a
    /// discovered worker (`--discover`; DESIGN.md §Cluster). Stopped —
    /// with a graceful `deregister` — on shutdown.
    heartbeater: Mutex<Option<crate::cluster::worker::Heartbeater>>,
    shutdown: AtomicBool,
}

/// A running AL server.
pub struct AlServer {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl AlServer {
    /// Bind and start serving. `config.al_worker.port = 0` binds an
    /// ephemeral port (tests); read the real one from `addr()`.
    pub fn start(config: AlaasConfig, deps: ServerDeps) -> std::io::Result<AlServer> {
        let listener =
            TcpListener::bind((config.al_worker.host.as_str(), config.al_worker.port))?;
        let addr = listener.local_addr()?;
        crate::util::logger::set_format_from_config(&config.observability.log_format);
        let tracer = Arc::new(crate::trace::Tracer::new(
            config.observability.trace,
            config.observability.slow_query_ms,
        ));
        let tenants = TenantRegistry::new(config.coordinator.tenancy.clone());
        let gate = Arc::new(AdmissionGate::new(
            &config.coordinator.tenancy,
            Some(deps.metrics.clone()),
        ));
        let state = Arc::new(ServerState {
            config,
            deps,
            tracer,
            sessions: Mutex::new(HashMap::new()),
            tenants,
            gate,
            jobs: JobRegistry::new(),
            heartbeater: Mutex::new(None),
            shutdown: AtomicBool::new(false),
        });
        let accept_state = state.clone();
        let accept_thread = std::thread::Builder::new()
            .name("alaas-accept".into())
            .spawn(move || accept_loop(listener, accept_state))?;
        // Pre-compile the serving artifacts in the background so the first
        // push_data doesn't pay XLA compile time (§Perf: cold-start cut
        // from ~10s to sub-second on the quickstart workload).
        let warm_state = state.clone();
        std::thread::Builder::new()
            .name("alaas-warmup".into())
            .spawn(move || {
                let bs = warm_state.config.active_learning.model.batch_size;
                if let Err(e) = warm_state.deps.backend.warmup_serving(bs) {
                    crate::log_warn!("server", "warmup failed: {e}");
                }
            })
            .ok();
        crate::log_info!("server", "AL server listening on {addr}");
        Ok(AlServer { addr, state, accept_thread: Some(accept_thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Announce this server to a cluster coordinator and keep its
    /// membership lease alive (the `serve --role worker --discover`
    /// path; DESIGN.md §Cluster). `advertised` is the address the
    /// *coordinator* should dial — pass it when binding a wildcard
    /// interface. Heartbeat cadence and lease come from this server's
    /// `[cluster.membership]` config; the loop re-registers on reconnect
    /// after a coordinator restart and self-deregisters (flagging
    /// `membership.self_deregistered`) when its lease lapses. Calling
    /// again replaces the previous loop.
    pub fn discover(&self, coordinator: &str, advertised: Option<&str>) {
        let advertised =
            advertised.map(str::to_string).unwrap_or_else(|| self.addr.to_string());
        let mcfg = &self.state.config.cluster.membership;
        let hb = crate::cluster::worker::Heartbeater::start(
            &advertised,
            coordinator,
            mcfg.heartbeat_ms,
            mcfg.lease_ms,
            Some(self.state.deps.metrics.clone()),
        );
        if let Some(prev) = self.state.heartbeater.lock().unwrap().replace(hb) {
            prev.stop_quiet();
        }
    }

    /// Detach (and return) the heartbeat loop without deregistering —
    /// the fault-injection harness uses this to simulate a crashed or
    /// wedged worker whose departure the coordinator must detect via
    /// lease expiry or keepalive probes.
    pub fn take_heartbeater(&self) -> Option<crate::cluster::worker::Heartbeater> {
        self.state.heartbeater.lock().unwrap().take()
    }

    /// Stop accepting and join the accept thread. In-flight handler
    /// threads finish their current request.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.state.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // graceful leave: the coordinator rebalances this worker's rows
        // immediately instead of waiting out the lease
        if let Some(hb) = self.state.heartbeater.lock().unwrap().take() {
            hb.stop();
        }
        // poke the listener awake, through the same dialing path real
        // RPCs use (pool::dial) so liveness behavior cannot diverge
        let _ = crate::server::pool::dial(&self.addr.to_string(), Duration::from_millis(500));
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AlServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    // Handler pool: bounded concurrency, queued accepts beyond it.
    let pool = ThreadPool::new("alaas-conn", 16, 64);
    for conn in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let state = state.clone();
                pool.execute(move || handle_conn(stream, state));
            }
            Err(e) => {
                crate::log_warn!("server", "accept error: {e}");
            }
        }
    }
    pool.shutdown();
}

fn handle_conn(mut stream: TcpStream, state: Arc<ServerState>) {
    rpc::serve_conn_ext(
        &mut stream,
        "server",
        &state.shutdown,
        &state.deps.metrics,
        Some(&state.tracer),
        state.config.server.wire,
        |method, params, mode, ctx| dispatch(&state, method, params, mode, ctx),
    );
}

fn dispatch(
    state: &Arc<ServerState>,
    method: &str,
    params: &Body,
    mode: WireMode,
    ctx: &rpc::RequestCtx,
) -> Result<Payload, String> {
    match method {
        "hello" => Ok(Payload::json(wire::hello_reply(
            &params.value,
            state.config.server.wire,
            state.config.server.mux,
        ))),
        "ping" => Ok(Payload::json(Value::from("pong"))),
        "push_data" => push_data(state, params).map(Payload::json),
        "status" => status(state, &params.value).map(Payload::json),
        "query" => query(state, &params.value).map(Payload::json),
        "metrics" => Ok(Payload::json(state.deps.metrics.snapshot())),
        "metrics_text" => Ok(Payload::json(Value::from(
            crate::metrics::render_prometheus(&state.deps.metrics.snapshot()),
        ))),
        // trace plane (DESIGN.md §Observability)
        "trace_recent" => {
            Ok(Payload::json(crate::trace::rpc_recent(&state.tracer, &params.value)))
        }
        "trace_get" => {
            crate::trace::rpc_get(&state.tracer, &params.value).map(Payload::json)
        }
        "strategies" => Ok(Payload::json(Value::Array(
            strategies::zoo_names().into_iter().map(Value::from).collect(),
        ))),
        "cache_stats" => {
            let (sessions, session_bytes) = session_footprint(state);
            let mut m = Map::new();
            m.insert("hits", Value::from(state.deps.cache.hits()));
            m.insert("misses", Value::from(state.deps.cache.misses()));
            m.insert("bytes", Value::from(state.deps.cache.bytes()));
            m.insert("entries", Value::from(state.deps.cache.len()));
            // resident session footprint: scan outputs held in memory —
            // lets a caller verify `session_close`/`drop_session`
            // actually freed this server
            m.insert("sessions", Value::from(sessions));
            m.insert("session_bytes", Value::from(session_bytes));
            Ok(Payload::json(Value::Object(m)))
        }
        // multi-tenant session lifecycle (DESIGN.md §Tenancy)
        "session_create" => session_create(state, &params.value).map(Payload::json),
        "session_close" => session_close(state, &params.value).map(Payload::json),
        "service_stats" => Ok(Payload::json(service_stats(state))),
        // agent-as-a-service job family (DESIGN.md §Agent)
        "agent_start" => agent_start(state, params).map(Payload::json),
        "agent_status" => job::rpc_status(&state.jobs, &params.value).map(Payload::json),
        "agent_result" => job::rpc_result(&state.jobs, &params.value).map(Payload::json),
        "agent_cancel" => job::rpc_cancel(&state.jobs, &params.value).map(Payload::json),
        // push event stream + pull-based catch-up (DESIGN.md §Events)
        "job_subscribe" => {
            job::rpc_subscribe(&state.jobs, &params.value, ctx).map(Payload::json)
        }
        "job_events" => job::rpc_events(&state.jobs, &params.value).map(Payload::json),
        // worker-facing cluster methods (DESIGN.md §Cluster)
        "scan_shard" => scan_shard(state, params).map(Payload::json),
        "select_shard" => select_shard(state, params, mode),
        "fetch_rows" => fetch_rows(state, &params.value),
        "drop_session" => {
            let session_id = str_param(&params.value, "session")?;
            let dropped =
                state.sessions.lock().unwrap().remove(&session_id).is_some();
            let mut m = Map::new();
            m.insert("dropped", Value::Bool(dropped));
            Ok(Payload::json(Value::Object(m)))
        }
        other => Err(format!("unknown method '{other}'")),
    }
}

/// Take one permit from the weighted-fair admission gate before
/// scatter-shaped work — a full strategy select or one agent arm round
/// (a no-op pass-through when tenancy is disabled). A shed verdict
/// becomes the structured `overloaded` error with its `retry_after_ms`
/// hint, matching the coordinator's `admit_scatter` exactly.
fn admit_select(state: &ServerState, session: &str) -> Result<AdmitPermit, String> {
    state
        .gate
        .admit(session, state.tenants.weight_of(session))
        .map_err(|shed| shed.to_service_error().encode())
}

pub(crate) fn str_param(params: &Value, key: &str) -> Result<String, String> {
    params
        .get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string param '{key}'"))
}

/// Decode + validate an optional u8 label-array field (`init_labels`,
/// `pool_labels`, `test_labels`) against the length of its split. Shared
/// with the cluster coordinator so the endpoints cannot drift. Accepts
/// the v1 integer-array form and the v2 tensor form (placeholder or
/// inline matrix), so a binary push that falls back to JSON
/// mid-negotiation still parses.
pub(crate) fn parse_label_array(
    params: &Body,
    key: &str,
    split_len: usize,
) -> Result<Option<Vec<u8>>, String> {
    let labels: Option<Vec<u8>> = match params.value.get(key) {
        None | Some(Value::Null) => None,
        Some(v) => {
            if let Some(m) = params.maybe_mat(v)? {
                Some(
                    m.as_slice()
                        .iter()
                        .map(|&x| {
                            if x.fract() == 0.0 && (0.0..=255.0).contains(&x) {
                                Ok(x as u8)
                            } else {
                                Err(format!("bad {key} label"))
                            }
                        })
                        .collect::<Result<Vec<u8>, _>>()?,
                )
            } else if let Value::Array(a) = v {
                Some(
                    a.iter()
                        .map(|v| {
                            v.as_usize()
                                .and_then(|u| u8::try_from(u).ok())
                                .ok_or_else(|| format!("bad {key} label"))
                        })
                        .collect::<Result<Vec<u8>, _>>()?,
                )
            } else {
                return Err(format!("{key} must be an array or tensor"));
            }
        }
    };
    if let Some(l) = &labels {
        if l.len() != split_len {
            return Err(format!("{key} len {} != split len {split_len}", l.len()));
        }
    }
    Ok(labels)
}

/// The original `init_labels` entry point (see [`parse_label_array`]).
pub(crate) fn parse_init_labels(
    params: &Body,
    init_len: usize,
) -> Result<Option<Vec<u8>>, String> {
    parse_label_array(params, "init_labels", init_len)
}

/// Strict `seed` field parse: JSON numbers are f64, so a seed at or
/// beyond 2^53 cannot travel losslessly — reject it instead of silently
/// substituting a default and breaking the remote-vs-local parity
/// contract. (Per-round derived seeds XOR small constants into the base,
/// which cannot set bits >= 53, so a valid base keeps every derived seed
/// exact too.)
pub(crate) fn parse_seed(params: &Value) -> Result<Option<u64>, String> {
    match params.get("seed") {
        None | Some(Value::Null) => Ok(None),
        Some(v) => v.as_usize().map(|s| Some(s as u64)).ok_or_else(|| {
            "seed must be a non-negative integer below 2^53 (JSON numbers are f64)"
                .to_string()
        }),
    }
}

fn get_session(state: &ServerState, id: &str) -> Result<Arc<SessionSlot>, String> {
    state
        .sessions
        .lock()
        .unwrap()
        .get(id)
        .cloned()
        .ok_or_else(|| ServiceError::unknown_session(id).encode())
}

/// Pull the `session` param and translate an opaque `tok-*` handle back
/// to its session name; plain names (including the coordinator's shard
/// session ids) pass through unchanged.
fn resolve_session_param(state: &ServerState, params: &Value) -> Result<String, String> {
    let raw = str_param(params, "session")?;
    state.tenants.resolve(&raw).map_err(|e| e.encode())
}

/// Resident scan-output footprint: `(sessions, bytes)` across every
/// registered session's cached matrices.
fn session_footprint(state: &ServerState) -> (u64, u64) {
    let map = state.sessions.lock().unwrap();
    let mut bytes = 0u64;
    for slot in map.values() {
        let s = slot.s.lock().unwrap();
        let sz = |m: &Option<Mat>| {
            m.as_ref().map(|m| (m.rows() * m.cols() * 4) as u64).unwrap_or(0)
        };
        bytes += sz(&s.pool_emb) + sz(&s.pool_scores) + sz(&s.init_emb) + sz(&s.test_emb);
    }
    (map.len() as u64, bytes)
}

/// `session_create {session, weight?, max_workers?}` — register a
/// tenant under the `max_sessions` quota and mint its opaque `tok-*`
/// handle. Same reply shape as the cluster coordinator; `weight` and
/// `max_workers` are recorded but only arbitrate anything there.
fn session_create(state: &Arc<ServerState>, params: &Value) -> Result<Value, String> {
    let name = str_param(params, "session")?;
    let weight = params.get("weight").and_then(Value::as_usize).unwrap_or(1) as u64;
    let max_workers = params.get("max_workers").and_then(Value::as_usize).unwrap_or(0);
    let info =
        state.tenants.create(&name, weight, max_workers).map_err(|e| e.encode())?;
    let mut m = Map::new();
    m.insert("session", Value::from(info.name));
    m.insert("token", Value::from(info.token));
    m.insert("weight", Value::from(info.weight));
    m.insert("max_workers", Value::from(info.max_workers));
    Ok(Value::Object(m))
}

/// `session_close {session}` (name or token) — release the quota slot
/// and drop the session's scan outputs. Idempotent, like the
/// coordinator's close.
fn session_close(state: &Arc<ServerState>, params: &Value) -> Result<Value, String> {
    let raw = str_param(params, "session")?;
    let name = state.tenants.resolve(&raw).unwrap_or(raw);
    let closed = state.tenants.close(&name).is_some();
    let dropped = state.sessions.lock().unwrap().remove(&name).is_some();
    let mut m = Map::new();
    m.insert("closed", Value::Bool(closed || dropped));
    m.insert("dropped_shards", Value::from(usize::from(dropped)));
    Ok(Value::Object(m))
}

/// `service_stats` — the single-server rendering of the coordinator's
/// tenancy snapshot: same shape, with the gate counters fed by this
/// server's own admission gate (queries and agent arm rounds).
fn service_stats(state: &Arc<ServerState>) -> Value {
    let gs = state.gate.stats();
    let tenants = state.tenants.list();
    let rows_of: HashMap<String, usize> = {
        let map = state.sessions.lock().unwrap();
        map.iter()
            .map(|(k, slot)| (k.clone(), slot.s.lock().unwrap().manifest.pool.len()))
            .collect()
    };
    let mut names: Vec<String> = rows_of.keys().cloned().collect();
    for t in &tenants {
        if !rows_of.contains_key(&t.name) {
            names.push(t.name.clone());
        }
    }
    names.sort();
    let mut sessions = Vec::new();
    let mut active = 0usize;
    for name in &names {
        let rows = rows_of.get(name).copied().unwrap_or(0);
        let t = tenants.iter().find(|t| &t.name == name);
        let resident = rows_of.contains_key(name);
        if resident {
            active += 1;
        }
        let (admitted, shed, queued) =
            gs.per_session.get(name).copied().unwrap_or((0, 0, 0));
        let mut m = Map::new();
        m.insert("name", Value::from(name.clone()));
        m.insert("weight", Value::from(t.map(|t| t.weight).unwrap_or(1)));
        m.insert("explicit", Value::Bool(t.map(|t| t.explicit).unwrap_or(false)));
        m.insert("rows", Value::from(rows));
        m.insert("shards", Value::from(usize::from(resident)));
        m.insert("admitted", Value::from(admitted));
        m.insert("shed", Value::from(shed));
        m.insert("queued", Value::from(queued));
        sessions.push(Value::Object(m));
    }
    let cfg = state.tenants.config();
    let mut m = Map::new();
    m.insert("tenancy_enabled", Value::Bool(cfg.enabled));
    m.insert("sessions_total", Value::from(names.len()));
    m.insert("sessions_active", Value::from(active));
    m.insert("running", Value::from(gs.running));
    m.insert("queued", Value::from(gs.queued));
    m.insert("admitted_total", Value::from(gs.admitted_total));
    m.insert("shed_total", Value::from(gs.shed_total));
    m.insert("max_sessions", Value::from(cfg.max_sessions));
    m.insert("sessions", Value::Array(sessions));
    Value::Object(m)
}

/// `push_data {session, manifest, init_labels?}` — register and process.
fn push_data(state: &Arc<ServerState>, params: &Body) -> Result<Value, String> {
    let session_id = resolve_session_param(state, &params.value)?;
    // auto-register pushes from the pre-tenancy stringly API under the
    // same quota explicit creates consume
    state.tenants.ensure(&session_id).map_err(|e| e.encode())?;
    push_session(state, params, session_id)
}

/// The push body shared with [`scan_shard`], whose coordinator-minted
/// shard sessions must NOT count against this server's tenant quota.
fn push_session(
    state: &Arc<ServerState>,
    params: &Body,
    session_id: String,
) -> Result<Value, String> {
    let manifest_v = params.value.get("manifest").ok_or("missing param 'manifest'")?;
    let manifest = Manifest::from_value(manifest_v).map_err(|e| e.to_string())?;
    let init_labels = parse_init_labels(params, manifest.init.len())?;

    let nc = manifest.num_classes;
    let d_embed = 64; // trunk output width (manifest.model geometry)
    let manifest_bg = manifest.clone();
    let slot = Arc::new(SessionSlot {
        s: Mutex::new(Session {
            manifest: manifest.clone(),
            status: SessionStatus::Processing,
            head: LinearHead::zeros(d_embed, nc),
            pool_emb: None,
            pool_scores: None,
            failed: vec![],
            init_emb: None,
            init_labels: init_labels.clone(),
            test_emb: None,
            scan_elapsed: Duration::ZERO,
        }),
        ready: Condvar::new(),
    });
    let replaced = state
        .sessions
        .lock()
        .unwrap()
        .insert(session_id.clone(), slot.clone())
        .is_some();

    // Background processing (the paper's dataflow: the client returns
    // immediately and later queries).
    let bg_state = state.clone();
    std::thread::Builder::new()
        .name(format!("alaas-proc-{session_id}"))
        .spawn(move || {
            let outcome = process_session(&bg_state, &slot, &manifest_bg, init_labels);
            let mut s = slot.s.lock().unwrap();
            s.status = match outcome {
                Ok(()) => SessionStatus::Ready,
                Err(e) => SessionStatus::Failed(e),
            };
            slot.ready.notify_all();
        })
        .map_err(|e| e.to_string())?;

    let mut m = Map::new();
    m.insert("session", Value::from(session_id));
    m.insert("pool_samples", Value::from(manifest.pool.len()));
    m.insert("replaced", Value::Bool(replaced));
    Ok(Value::Object(m))
}

fn pipeline_params(cfg: &AlaasConfig) -> PipelineParams {
    PipelineParams {
        mode: DataflowMode::Pipelined,
        fetch_threads: cfg.al_worker.fetch_threads,
        preprocess_threads: cfg.al_worker.preprocess_threads,
        infer_threads: cfg.al_worker.replicas,
        queue_depth: cfg.al_worker.queue_depth,
        batch: BatchPolicy {
            max_batch: cfg.active_learning.model.batch_size,
            max_wait: Duration::from_millis(cfg.al_worker.batch_timeout_ms),
        },
        per_item_overhead: Duration::ZERO,
        per_round_overhead: Duration::ZERO,
    }
}

fn process_session(
    state: &Arc<ServerState>,
    slot: &Arc<SessionSlot>,
    manifest: &Manifest,
    init_labels: Option<Vec<u8>>,
) -> Result<(), String> {
    let deps = &state.deps;
    let params = pipeline_params(&state.config);
    // 1. optional head fine-tune on the init split
    let mut head = LinearHead::zeros(64, manifest.num_classes);
    let mut init_emb = None;
    if !manifest.init.is_empty() {
        let out = run_pipeline(
            &manifest.init,
            &deps.store,
            &deps.cache,
            &deps.backend,
            &head,
            &params,
            Some(&deps.metrics),
        )
        .map_err(|e| e.to_string())?;
        if let Some(labels) = init_labels {
            let ok_rows: Vec<usize> = (0..manifest.init.len())
                .filter(|i| !out.errors.iter().any(|(j, _)| j == i))
                .collect();
            let emb = out.embeddings.gather_rows(&ok_rows);
            let lab: Vec<u8> = ok_rows.iter().map(|&i| labels[i]).collect();
            let (h, _) = trainer::fit(
                deps.backend.as_ref(),
                &emb,
                &lab,
                manifest.num_classes,
                &TrainConfig::default(),
            )
            .map_err(|e| e.to_string())?;
            head = h;
        }
        init_emb = Some(out.embeddings);
    }
    // 2. pipelined pool scan under the (possibly fine-tuned) head
    let out = run_pipeline(
        &manifest.pool,
        &deps.store,
        &deps.cache,
        &deps.backend,
        &head,
        &params,
        Some(&deps.metrics),
    )
    .map_err(|e| e.to_string())?;

    // 3. test-split scan when the manifest carries one (embeddings only;
    // the agent job evaluates arm accuracy on it — DESIGN.md §Agent)
    let mut test_emb = None;
    if !manifest.test.is_empty() {
        let t = run_pipeline(
            &manifest.test,
            &deps.store,
            &deps.cache,
            &deps.backend,
            &head,
            &params,
            Some(&deps.metrics),
        )
        .map_err(|e| e.to_string())?;
        test_emb = Some(t.embeddings);
    }

    let mut s = slot.s.lock().unwrap();
    s.head = head;
    s.failed = out.errors.iter().map(|(i, _)| *i).collect();
    s.scan_elapsed = out.elapsed;
    s.pool_emb = Some(out.embeddings);
    s.pool_scores = Some(out.scores);
    s.init_emb = init_emb;
    s.test_emb = test_emb;
    Ok(())
}

/// `status {session}`.
fn status(state: &Arc<ServerState>, params: &Value) -> Result<Value, String> {
    let session_id = resolve_session_param(state, params)?;
    let slot = get_session(state, &session_id)?;
    let s = slot.s.lock().unwrap();
    let mut m = Map::new();
    m.insert(
        "status",
        Value::from(match &s.status {
            SessionStatus::Processing => "processing".to_string(),
            SessionStatus::Ready => "ready".to_string(),
            SessionStatus::Failed(e) => format!("failed: {e}"),
        }),
    );
    m.insert("pool_samples", Value::from(s.manifest.pool.len()));
    m.insert("failed_samples", Value::from(s.failed.len()));
    m.insert("scan_ms", Value::Number(s.scan_elapsed.as_secs_f64() * 1e3));
    Ok(Value::Object(m))
}

/// Block until a session leaves `Processing` (or `wait_ms` elapses);
/// returns the guard on the ready session, or the failure message.
fn wait_ready<'a>(
    slot: &'a SessionSlot,
    wait_ms: u64,
) -> Result<MutexGuard<'a, Session>, String> {
    let deadline = Instant::now() + Duration::from_millis(wait_ms);
    let mut s = slot.s.lock().unwrap();
    while s.status == SessionStatus::Processing {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            return Err("query timed out waiting for processing".into());
        }
        let (guard, _) = slot.ready.wait_timeout(s, left).unwrap();
        s = guard;
    }
    if let SessionStatus::Failed(e) = &s.status {
        return Err(format!("session processing failed: {e}"));
    }
    Ok(s)
}

/// The selectable view of a ready session: non-failed pool rows (minus
/// `exclude` — an agent arm's already-labeled positions) and their
/// gathered embeddings/scores. `ok_rows[rel]` maps a strategy-relative
/// index back to the absolute pool position.
fn candidate_view(s: &Session, exclude: &[usize]) -> (Vec<usize>, Mat, Mat) {
    let pool_emb = s.pool_emb.as_ref().expect("ready session has embeddings");
    let pool_scores = s.pool_scores.as_ref().expect("ready session has scores");
    let excl: std::collections::HashSet<usize> = exclude.iter().copied().collect();
    let ok_rows: Vec<usize> = (0..pool_emb.rows())
        .filter(|i| !s.failed.contains(i) && !excl.contains(i))
        .collect();
    let cand_emb = pool_emb.gather_rows(&ok_rows);
    let cand_scores = pool_scores.gather_rows(&ok_rows);
    (ok_rows, cand_emb, cand_scores)
}

/// `query {session, budget, strategy?, wait_ms?}`.
fn query(state: &Arc<ServerState>, params: &Value) -> Result<Value, String> {
    let session_id = resolve_session_param(state, params)?;
    let budget =
        params.get("budget").and_then(Value::as_usize).ok_or("missing usize param 'budget'")?;
    let strategy_name = match params.get("strategy").and_then(Value::as_str) {
        Some(s) => s.to_string(),
        None => state.config.active_learning.strategy.as_str().to_string(),
    };
    if strategy_name == "auto" || matches!(
        (&state.config.active_learning.strategy, strategy_name.as_str()),
        (StrategyChoice::Auto, "auto")
    ) {
        return Err(
            "strategy 'auto' requires the agent workflow (CLI `alaas agent`): the PSHEA \
             loop needs per-round oracle labels, which the one-shot query protocol does \
             not carry"
                .into(),
        );
    }
    let wait_ms =
        params.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;

    // held across wait + select: the query is this server's scatter-shaped
    // unit of work, exactly like one coordinator scatter
    let _permit = admit_select(state, &session_id)?;
    let slot = get_session(state, &session_id)?;
    let s = {
        let mut g = state.tracer.child("wait_ready");
        g.annotate("session", &session_id);
        wait_ready(&slot, wait_ms)?
    };

    let strat = strategies::by_name(&strategy_name)
        .ok_or_else(|| format!("unknown strategy '{strategy_name}'"))?;
    // exclude failed rows from the candidate set
    let (ok_rows, cand_emb, cand_scores) = candidate_view(&s, &[]);
    let empty = Mat::zeros(0, cand_emb.cols());
    let labeled = s.init_emb.as_ref().unwrap_or(&empty);
    let t0 = Instant::now();
    let ctx = SelectCtx {
        scores: &cand_scores,
        embeddings: &cand_emb,
        labeled,
        backend: state.deps.backend.as_ref(),
        seed: SELECT_SEED,
    };
    let mut g = state.tracer.child("select");
    g.annotate("strategy", &strategy_name);
    g.annotate("budget", budget);
    let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
    g.annotate("selected", picked.len());
    drop(g);
    let select_elapsed = t0.elapsed();
    state.deps.metrics.time("al.select", select_elapsed);
    state.deps.metrics.meter("al.selected").add(picked.len() as u64);

    let selected: Vec<Value> = picked
        .iter()
        .map(|&rel| {
            let abs = ok_rows[rel];
            let sr: &SampleRef = &s.manifest.pool[abs];
            let mut m = Map::new();
            m.insert("id", Value::from(sr.id as u64));
            m.insert("uri", Value::from(sr.uri.clone()));
            Value::Object(m)
        })
        .collect();
    let mut m = Map::new();
    m.insert("strategy", Value::from(strategy_name));
    m.insert("selected", Value::Array(selected));
    m.insert("select_ms", Value::Number(select_elapsed.as_secs_f64() * 1e3));
    m.insert("scan_ms", Value::Number(s.scan_elapsed.as_secs_f64() * 1e3));
    Ok(Value::Object(m))
}

/// `scan_shard {session, shard, manifest, init_labels?}` — worker-facing
/// push: identical to `push_data` except the manifest's pool is one shard
/// of a cluster session (the coordinator owns the global index space).
fn scan_shard(state: &Arc<ServerState>, params: &Body) -> Result<Value, String> {
    let shard = params.value.get("shard").and_then(Value::as_usize).unwrap_or(0);
    let session_id = str_param(&params.value, "session")?;
    let v = push_session(state, params, session_id)?;
    state.deps.metrics.counter("cluster.shards_accepted").fetch_add(1, Ordering::Relaxed);
    let mut m = match v {
        Value::Object(m) => m,
        _ => Map::new(),
    };
    m.insert("shard", Value::from(shard));
    Ok(Value::Object(m))
}

/// `select_shard {session, budget, strategy?, with_embeddings?,
/// with_init_emb?, with_test_emb?, wait_ms?, seed?, exclude?, head_w?,
/// head_b?, labeled_emb?}` — worker-facing select (DESIGN.md §Cluster).
///
/// Always waits for the scan and reports the shard's failed local indices
/// plus scan timing; with `budget > 0` it additionally returns the local
/// candidate list for the coordinator's merge (top-k scalars for the
/// uncertainty strategies, embeddings for the refine protocol). `budget =
/// 0` is the coordinator's probe for coordinator-side strategies (random)
/// and for the agent job's bootstrap fetch of init/test embeddings.
///
/// The optional agent-path fields (DESIGN.md §Agent) let one PSHEA arm
/// select through the same code path the plain query uses: `exclude`
/// drops the arm's already-labeled local pool indices from the candidate
/// view, `head_w`/`head_b` recompute the uncertainty scores under the
/// arm's current head (tensor sections on the v2 wire), `labeled_emb`
/// extends the labeled context with the arm's labeled embeddings, and
/// `seed` overrides the query-path `SELECT_SEED`.
///
/// Matrix results travel per the request's encoding (DESIGN.md §Wire):
/// on the v2 binary wire, `init_emb`/`test_emb` and the packed
/// `cand_scores`/`cand_emb` rows (parallel to the slim `candidates`
/// list) ride as tensor sections; on the v1 JSON wire the candidates
/// keep the PR1 fat per-candidate schema, so pre-v2 coordinators decode
/// the refine protocol unchanged.
fn select_shard(
    state: &Arc<ServerState>,
    params: &Body,
    mode: WireMode,
) -> Result<Payload, String> {
    let session_id = str_param(&params.value, "session")?;
    let budget = params.value.get("budget").and_then(Value::as_usize).unwrap_or(0);
    let with_embeddings =
        params.value.get("with_embeddings").and_then(Value::as_bool).unwrap_or(false);
    let with_init_emb =
        params.value.get("with_init_emb").and_then(Value::as_bool).unwrap_or(false);
    let with_test_emb =
        params.value.get("with_test_emb").and_then(Value::as_bool).unwrap_or(false);
    let wait_ms =
        params.value.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;
    let seed = parse_seed(&params.value)?.unwrap_or(SELECT_SEED);
    let exclude: Vec<usize> = match params.value.get("exclude") {
        None | Some(Value::Null) => vec![],
        Some(v) => v
            .as_array()
            .ok_or("exclude must be an index array")?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| "bad exclude index".to_string()))
            .collect::<Result<Vec<_>, _>>()?,
    };
    // materialized straight from the frame buffer, one copy each (the
    // zero-copy decode path — DESIGN.md §Wire)
    let head_w = params.mat("head_w")?;
    let head_b = params.mat("head_b")?;
    let labeled_extra = params.mat("labeled_emb")?;

    let slot = get_session(state, &session_id)?;
    let s = {
        let mut g = state.tracer.child("scan.wait");
        g.annotate("session", &session_id);
        let s = wait_ready(&slot, wait_ms)?;
        g.annotate("scan_ms", format!("{:.1}", s.scan_elapsed.as_secs_f64() * 1e3));
        s
    };

    let mut out = Payload::default();
    let mut m = Map::new();
    m.insert(
        "failed",
        Value::Array(s.failed.iter().map(|&i| Value::from(i)).collect()),
    );
    m.insert("scan_ms", Value::Number(s.scan_elapsed.as_secs_f64() * 1e3));
    m.insert("pool_samples", Value::from(s.manifest.pool.len()));
    if with_init_emb {
        let empty = Mat::zeros(0, 0);
        let init = s.init_emb.as_ref().unwrap_or(&empty).clone();
        m.insert("init_emb", out.stash_mat(init));
    }
    if with_test_emb {
        // only answer when the session actually scanned a test split, so
        // the coordinator can't cache an empty matrix as "the" test set
        if let Some(t) = s.test_emb.as_ref() {
            m.insert("test_emb", out.stash_mat(t.clone()));
        }
    }
    if budget > 0 {
        let strategy = params
            .value
            .get("strategy")
            .and_then(Value::as_str)
            .ok_or("missing strategy for budget > 0")?;
        let (ok_rows, cand_emb, cand_scores) = candidate_view(&s, &exclude);
        // agent arms carry their own head: rescore the candidates under it
        let cand_scores = match (&head_w, &head_b) {
            (Some(w), Some(b)) => {
                let logits = state
                    .deps
                    .backend
                    .eval_logits(&cand_emb, w, b.as_slice())
                    .map_err(|e| e.to_string())?;
                state.deps.backend.scores(&logits).map_err(|e| e.to_string())?
            }
            (None, None) => cand_scores,
            _ => return Err("head_w and head_b must be sent together".into()),
        };
        let empty = Mat::zeros(0, cand_emb.cols());
        let base_labeled = s.init_emb.as_ref().unwrap_or(&empty);
        let labeled = match &labeled_extra {
            Some(extra) if extra.rows() > 0 => base_labeled.vstack(extra),
            _ => base_labeled.clone(),
        };
        let t0 = Instant::now();
        let mut g = state.tracer.child("select.candidates");
        g.annotate("strategy", strategy);
        g.annotate("budget", budget);
        let cands = crate::cluster::worker::build_candidates(
            strategy,
            budget,
            with_embeddings,
            &ok_rows,
            &cand_emb,
            &cand_scores,
            &labeled,
            state.deps.backend.as_ref(),
            seed,
        )?;
        g.annotate("returned", cands.len());
        drop(g);
        state.deps.metrics.time("al.select_shard", t0.elapsed());
        if with_embeddings && mode == WireMode::Json {
            // v1 peers expect the fat per-candidate schema; the packed
            // tensor form is a v2-only optimization
            m.insert(
                "candidates",
                Value::Array(cands.iter().map(|c| c.to_value(true)).collect()),
            );
        } else {
            m.insert(
                "candidates",
                Value::Array(cands.iter().map(|c| c.to_value(false)).collect()),
            );
            if with_embeddings {
                let scores = Mat::from_rows(cands.iter().map(|c| c.scores.as_slice()));
                let emb = Mat::from_rows(cands.iter().map(|c| c.emb.as_slice()));
                m.insert("cand_scores", out.stash_mat(scores));
                m.insert("cand_emb", out.stash_mat(emb));
            }
        }
    }
    out.value = Value::Object(m);
    Ok(out)
}

/// `fetch_rows {session, rows, wait_ms?}` — pool-embedding rows by local
/// index, as one tensor in row-request order. The coordinator uses this
/// to materialize the embeddings of a coordinator-side selection (the
/// agent path of the `random` merge — DESIGN.md §Agent).
fn fetch_rows(state: &Arc<ServerState>, params: &Value) -> Result<Payload, String> {
    let session_id = str_param(params, "session")?;
    let rows: Vec<usize> = params
        .get("rows")
        .and_then(Value::as_array)
        .ok_or("missing index array 'rows'")?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| "bad row index".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    let wait_ms = params.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;
    let slot = get_session(state, &session_id)?;
    let s = wait_ready(&slot, wait_ms)?;
    let pool_emb = s.pool_emb.as_ref().expect("ready session has embeddings");
    for &r in &rows {
        if r >= pool_emb.rows() {
            return Err(format!("row {r} out of range ({} pool rows)", pool_emb.rows()));
        }
    }
    let mut out = Payload::default();
    let mut g = state.tracer.child("gather_rows");
    g.annotate("rows", rows.len());
    let ph = out.stash_mat(pool_emb.gather_rows(&rows));
    drop(g);
    let mut m = Map::new();
    m.insert("emb", ph);
    m.insert("rows", Value::from(rows.len()));
    out.value = Value::Object(m);
    Ok(out)
}

/// Single-server [`ArmSelect`]: one agent arm's selection over the
/// session's candidate view — the same `candidate_view` + strategy-select
/// path `query` uses, with the arm's head, exclusions, and seed. Each
/// round takes one admission-gate permit (the arm round is this server's
/// scatter-shaped unit of work, like the coordinator's) and publishes
/// its spend record to the job's push-event stream.
struct LocalArmSelect {
    state: Arc<ServerState>,
    session_id: String,
    job: Arc<job::JobSlot>,
    slot: Arc<SessionSlot>,
    backend: Arc<dyn ComputeBackend>,
}

impl ArmSelect for LocalArmSelect {
    fn select_arm(
        &mut self,
        strategy: &str,
        budget: usize,
        head: &LinearHead,
        exclude: &[usize],
        arm_labeled: &Mat,
        seed: u64,
    ) -> Result<Vec<Picked>, String> {
        let strat = strategies::by_name(strategy)
            .ok_or_else(|| format!("unknown strategy '{strategy}'"))?;
        // one permit per arm round, held for the duration of the select —
        // a shed surfaces as the same structured `overloaded` error the
        // coordinator's scatter path returns
        let _permit = admit_select(&self.state, &self.session_id)?;
        let s = self.slot.s.lock().unwrap();
        if s.status != SessionStatus::Ready {
            return Err("session left ready state mid-job".into());
        }
        let (ok_rows, cand_emb, _scan_scores) = candidate_view(&s, exclude);
        let logits = self
            .backend
            .eval_logits(&cand_emb, &head.w, &head.b)
            .map_err(|e| e.to_string())?;
        let scores = self.backend.scores(&logits).map_err(|e| e.to_string())?;
        let empty = Mat::zeros(0, cand_emb.cols());
        let base = s.init_emb.as_ref().unwrap_or(&empty);
        let labeled = if arm_labeled.rows() == 0 {
            base.clone()
        } else {
            base.vstack(arm_labeled)
        };
        let ctx = SelectCtx {
            scores: &scores,
            embeddings: &cand_emb,
            labeled: &labeled,
            backend: self.backend.as_ref(),
            seed,
        };
        let picked = strat.select(&ctx, budget).map_err(|e| e.to_string())?;
        let out: Vec<Picked> = picked
            .into_iter()
            .map(|rel| (ok_rows[rel], cand_emb.row(rel).to_vec()))
            .collect();
        // one spend event per round, empty rounds included — the same
        // record shape the coordinator's durable path appends, so a
        // follower sees identical traces on either topology (no WAL on
        // a single server, hence publish-only)
        let idxs: Vec<usize> = out.iter().map(|p| p.0).collect();
        self.job.events.publish(recovery::rec_job_spend(&self.job.id, strategy, &idxs));
        Ok(out)
    }
}

/// Validate the shared `agent_start` request surface: strategy names,
/// config overlay, seed, and the oracle label arrays. Used by both the
/// single server and the cluster coordinator.
pub(crate) struct AgentStartParams {
    pub strategies: Vec<String>,
    pub cfg: crate::agent::PsheaConfig,
    pub seed: u64,
    pub pool_labels: Vec<u8>,
    pub test_labels: Vec<u8>,
    pub wait_ms: u64,
}

pub(crate) fn parse_agent_start(
    params: &Body,
    defaults: crate::agent::PsheaConfig,
    manifest: &Manifest,
    init_labels_present: bool,
) -> Result<AgentStartParams, String> {
    if manifest.init.is_empty() || !init_labels_present {
        return Err(
            "agent_start needs a session pushed with a labeled init split (the \
             baseline model of Algorithm 1 trains on it)"
                .into(),
        );
    }
    if manifest.test.is_empty() {
        return Err(
            "agent_start needs a session whose manifest carries a test split \
             (arm accuracy is evaluated on it)"
                .into(),
        );
    }
    let strategies: Vec<String> = params
        .value
        .get("strategies")
        .and_then(Value::as_array)
        .ok_or("missing param 'strategies'")?
        .iter()
        .map(|v| {
            v.as_str().map(str::to_string).ok_or_else(|| "bad strategy name".to_string())
        })
        .collect::<Result<Vec<_>, _>>()?;
    if strategies.is_empty() {
        return Err("strategies must be non-empty".into());
    }
    for s in &strategies {
        if strategies::by_name(s).is_none() {
            return Err(format!("unknown strategy '{s}'"));
        }
    }
    let cfg = job::config_from_value(defaults, params.value.get("config"))?;
    let seed = parse_seed(&params.value)?.unwrap_or(SELECT_SEED);
    let pool_labels = parse_label_array(params, "pool_labels", manifest.pool.len())?
        .ok_or("missing param 'pool_labels' (the oracle for the pool split)")?;
    let test_labels = parse_label_array(params, "test_labels", manifest.test.len())?
        .ok_or("missing param 'test_labels' (ground truth for evaluation)")?;
    let wait_ms =
        params.value.get("wait_ms").and_then(Value::as_usize).unwrap_or(120_000) as u64;
    Ok(AgentStartParams { strategies, cfg, seed, pool_labels, test_labels, wait_ms })
}

/// `agent_start {session, strategies, config?, seed?, pool_labels,
/// test_labels, wait_ms?}` — spawn a background PSHEA job over a pushed
/// session and return its job id (DESIGN.md §Agent).
fn agent_start(state: &Arc<ServerState>, params: &Body) -> Result<Value, String> {
    let session_id = resolve_session_param(state, &params.value)?;
    let slot = get_session(state, &session_id)?;
    let (manifest, have_init_labels) = {
        let s = slot.s.lock().unwrap();
        (s.manifest.clone(), s.init_labels.is_some())
    };
    let p = parse_agent_start(
        params,
        state.config.active_learning.agent.to_pshea(),
        &manifest,
        have_init_labels,
    )?;
    let n_arms = p.strategies.len();
    let (job_id, job_slot) = state.jobs.create(&p.strategies);
    let bg = state.clone();
    let thread_job = job_id.clone();
    std::thread::Builder::new()
        .name(format!("alaas-agent-{job_id}"))
        .spawn(move || {
            // wait out the scan on the job thread so agent_start returns
            // immediately even while the session is still processing
            let data = match wait_ready(&slot, p.wait_ms) {
                Ok(s) => {
                    let init_emb = s.init_emb.clone();
                    let init_labels = s.init_labels.clone();
                    let test_emb = s.test_emb.clone();
                    let selectable = s
                        .pool_emb
                        .as_ref()
                        .map(|m| m.rows())
                        .unwrap_or(0)
                        .saturating_sub(s.failed.len());
                    let nc = s.manifest.num_classes;
                    drop(s);
                    match (init_emb, init_labels, test_emb) {
                        (Some(ie), Some(il), Some(te)) => Ok((ie, il, te, selectable, nc)),
                        _ => Err("session is missing init/test scan outputs".to_string()),
                    }
                }
                Err(e) => Err(e),
            };
            let (init_emb, init_labels, test_emb, selectable, nc) = match data {
                Ok(d) => d,
                Err(e) => {
                    job::fail(&job_slot, &bg.deps.metrics, e);
                    return;
                }
            };
            let sel = LocalArmSelect {
                state: bg.clone(),
                session_id: session_id.clone(),
                job: job_slot.clone(),
                slot: slot.clone(),
                backend: bg.deps.backend.clone(),
            };
            let task = AgentTask::new(
                sel,
                bg.deps.backend.clone(),
                selectable,
                init_emb,
                init_labels,
                p.pool_labels,
                test_emb,
                p.test_labels,
                nc,
                p.seed,
                Some(job_slot.cancel.clone()),
            )
            .with_tracer(bg.tracer.clone());
            crate::log_info!(
                "server",
                "agent job {thread_job} started on session '{session_id}' ({} arms)",
                p.strategies.len()
            );
            job::drive(&job_slot, task, &p.strategies, &p.cfg, &bg.deps.metrics);
        })
        .map_err(|e| {
            // no thread will ever finish this slot: mark it failed so it
            // doesn't sit in the registry as a ghost "running" job
            state.jobs.fail_orphan(&job_id, &state.deps.metrics, &e.to_string());
            e.to_string()
        })?;

    let mut m = Map::new();
    m.insert("job", Value::from(job_id));
    m.insert("strategies", Value::from(n_arms));
    Ok(Value::Object(m))
}

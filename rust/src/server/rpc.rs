//! Wire protocol: length-prefixed JSON frames.
//!
//! Frame = `u32 little-endian payload length` + `payload` (UTF-8 JSON).
//! Requests: `{"id": n, "method": "...", "params": {...}}`.
//! Responses: `{"id": n, "result": ...}` or `{"id": n, "error": "..."}`.
//! Max frame size 64 MiB (a pushed manifest for a million-sample dataset
//! is ~60 MB; beyond that, shard the push).

use std::io::{Read, Write};

use crate::json::{self, Map, Value};

/// Hard cap on frame payloads.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// Protocol-level failure.
#[derive(Debug, thiserror::Error)]
pub enum RpcError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame too large: {0} bytes (max {MAX_FRAME})")]
    FrameTooLarge(usize),
    #[error("malformed frame: {0}")]
    Malformed(String),
    #[error("remote error: {0}")]
    Remote(String),
    #[error("connection closed")]
    Closed,
}

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub params: Value,
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), RpcError> {
    if payload.len() > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(payload.len()));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Closed` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, RpcError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(RpcError::Closed),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(len));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Serialize + send a request.
pub fn send_request(
    w: &mut impl Write,
    id: u64,
    method: &str,
    params: Value,
) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("method", Value::from(method));
    m.insert("params", params);
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Receive + parse a request frame.
pub fn recv_request(r: &mut impl Read) -> Result<Request, RpcError> {
    let buf = read_frame(r)?;
    let text = std::str::from_utf8(&buf)
        .map_err(|e| RpcError::Malformed(format!("non-utf8 frame: {e}")))?;
    let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
    let id = v
        .get("id")
        .and_then(Value::as_i64)
        .ok_or_else(|| RpcError::Malformed("missing id".into()))? as u64;
    let method = v
        .get("method")
        .and_then(Value::as_str)
        .ok_or_else(|| RpcError::Malformed("missing method".into()))?
        .to_string();
    let params = v.get("params").cloned().unwrap_or(Value::Null);
    Ok(Request { id, method, params })
}

/// Serialize + send a success response.
pub fn send_result(w: &mut impl Write, id: u64, result: Value) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("result", result);
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Serialize + send an error response.
pub fn send_error(w: &mut impl Write, id: u64, error: &str) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("error", Value::from(error));
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Receive a response for `expect_id`; remote errors surface as `Remote`.
pub fn recv_response(r: &mut impl Read, expect_id: u64) -> Result<Value, RpcError> {
    let buf = read_frame(r)?;
    let text = std::str::from_utf8(&buf)
        .map_err(|e| RpcError::Malformed(format!("non-utf8 frame: {e}")))?;
    let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
    let id = v
        .get("id")
        .and_then(Value::as_i64)
        .ok_or_else(|| RpcError::Malformed("missing id".into()))? as u64;
    if id != expect_id {
        return Err(RpcError::Malformed(format!(
            "response id {id} != request id {expect_id}"
        )));
    }
    if let Some(e) = v.get("error").and_then(Value::as_str) {
        return Err(RpcError::Remote(e.to_string()));
    }
    v.get("result")
        .cloned()
        .ok_or_else(|| RpcError::Malformed("missing result".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert!(matches!(read_frame(&mut r), Err(RpcError::Closed)));
    }

    #[test]
    fn request_response_roundtrip() {
        let mut buf = Vec::new();
        send_request(&mut buf, 7, "query", obj([("budget", Value::from(10))])).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.method, "query");
        assert_eq!(req.params.get("budget").unwrap().as_i64(), Some(10));

        let mut buf = Vec::new();
        send_result(&mut buf, 7, Value::from("ok")).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(recv_response(&mut r, 7).unwrap().as_str(), Some("ok"));
    }

    #[test]
    fn remote_error_surfaces() {
        let mut buf = Vec::new();
        send_error(&mut buf, 3, "boom").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_response(&mut r, 3), Err(RpcError::Remote(e)) if e == "boom"));
    }

    #[test]
    fn mismatched_id_rejected() {
        let mut buf = Vec::new();
        send_result(&mut buf, 1, Value::Null).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_response(&mut r, 2), Err(RpcError::Malformed(_))));
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(b"xx");
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(RpcError::FrameTooLarge(_))));
    }

    #[test]
    fn malformed_json_and_missing_fields() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"not json").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_request(&mut r), Err(RpcError::Malformed(_))));

        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"id\": 1}").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_request(&mut r), Err(RpcError::Malformed(_))));
    }

    #[test]
    fn truncated_frame_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"short");
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(RpcError::Io(_))));
    }
}

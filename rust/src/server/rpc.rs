//! Wire protocol: length-prefixed JSON frames.
//!
//! Frame = `u32 little-endian payload length` + `payload` (UTF-8 JSON).
//! Requests: `{"id": n, "method": "...", "params": {...}}`.
//! Responses: `{"id": n, "result": ...}` or `{"id": n, "error": "..."}`.
//! Max frame size 64 MiB (a pushed manifest for a million-sample dataset
//! is ~60 MB; beyond that, shard the push).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::json::{self, Map, Value};
use crate::metrics::Registry;

/// Hard cap on frame payloads.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// Protocol-level failure.
#[derive(Debug, thiserror::Error)]
pub enum RpcError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame too large: {0} bytes (max {MAX_FRAME})")]
    FrameTooLarge(usize),
    #[error("malformed frame: {0}")]
    Malformed(String),
    #[error("remote error: {0}")]
    Remote(String),
    #[error("connection closed")]
    Closed,
}

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub method: String,
    pub params: Value,
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), RpcError> {
    if payload.len() > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(payload.len()));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Closed` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, RpcError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(RpcError::Closed),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(len));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Serialize + send a request.
pub fn send_request(
    w: &mut impl Write,
    id: u64,
    method: &str,
    params: Value,
) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("method", Value::from(method));
    m.insert("params", params);
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Receive + parse a request frame.
pub fn recv_request(r: &mut impl Read) -> Result<Request, RpcError> {
    let buf = read_frame(r)?;
    let text = std::str::from_utf8(&buf)
        .map_err(|e| RpcError::Malformed(format!("non-utf8 frame: {e}")))?;
    let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
    let id = v
        .get("id")
        .and_then(Value::as_i64)
        .ok_or_else(|| RpcError::Malformed("missing id".into()))? as u64;
    let method = v
        .get("method")
        .and_then(Value::as_str)
        .ok_or_else(|| RpcError::Malformed("missing method".into()))?
        .to_string();
    let params = v.get("params").cloned().unwrap_or(Value::Null);
    Ok(Request { id, method, params })
}

/// Serialize + send a success response.
pub fn send_result(w: &mut impl Write, id: u64, result: Value) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("result", result);
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Serialize + send an error response.
pub fn send_error(w: &mut impl Write, id: u64, error: &str) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("error", Value::from(error));
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Serve framed request/response RPC on one connection until clean EOF,
/// a broken frame, an I/O failure, or `shutdown` flips. Shared by the
/// single server and the cluster coordinator so the idle-probe/shutdown
/// behavior cannot diverge. Per-request latency is recorded under
/// `rpc.{method}` in `metrics`.
///
/// The idle wait uses a bounded 250ms peek so the handler re-checks the
/// shutdown flag instead of pinning its thread forever; once bytes are
/// available the frame is read under a generous timeout (a frame, once
/// started, arrives promptly).
pub fn serve_conn(
    stream: &mut TcpStream,
    tag: &'static str,
    shutdown: &AtomicBool,
    metrics: &Registry,
    mut handle: impl FnMut(&str, &Value) -> Result<Value, String>,
) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    stream.set_nodelay(true).ok();
    loop {
        stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
        let mut probe = [0u8; 1];
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return;
            }
            match stream.peek(&mut probe) {
                Ok(0) => return, // clean EOF
                Ok(_) => break,  // a frame is waiting
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    continue
                }
                Err(_) => return,
            }
        }
        stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
        let req = match recv_request(stream) {
            Ok(r) => r,
            Err(RpcError::Closed) => return,
            Err(e) => {
                crate::log_debug!(tag, "bad frame from {peer}: {e}");
                // protocol is broken on this conn; drop it
                return;
            }
        };
        let t0 = Instant::now();
        let result = handle(&req.method, &req.params);
        metrics.time(&format!("rpc.{}", req.method), t0.elapsed());
        let io = match result {
            Ok(v) => send_result(stream, req.id, v),
            Err(e) => send_error(stream, req.id, &e),
        };
        if io.is_err() {
            return;
        }
    }
}

/// Receive a response for `expect_id`; remote errors surface as `Remote`.
pub fn recv_response(r: &mut impl Read, expect_id: u64) -> Result<Value, RpcError> {
    let buf = read_frame(r)?;
    let text = std::str::from_utf8(&buf)
        .map_err(|e| RpcError::Malformed(format!("non-utf8 frame: {e}")))?;
    let v = json::parse(text).map_err(|e| RpcError::Malformed(e.to_string()))?;
    let id = v
        .get("id")
        .and_then(Value::as_i64)
        .ok_or_else(|| RpcError::Malformed("missing id".into()))? as u64;
    if id != expect_id {
        return Err(RpcError::Malformed(format!(
            "response id {id} != request id {expect_id}"
        )));
    }
    if let Some(e) = v.get("error").and_then(Value::as_str) {
        return Err(RpcError::Remote(e.to_string()));
    }
    v.get("result")
        .cloned()
        .ok_or_else(|| RpcError::Malformed("missing result".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert!(matches!(read_frame(&mut r), Err(RpcError::Closed)));
    }

    #[test]
    fn request_response_roundtrip() {
        let mut buf = Vec::new();
        send_request(&mut buf, 7, "query", obj([("budget", Value::from(10))])).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.method, "query");
        assert_eq!(req.params.get("budget").unwrap().as_i64(), Some(10));

        let mut buf = Vec::new();
        send_result(&mut buf, 7, Value::from("ok")).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(recv_response(&mut r, 7).unwrap().as_str(), Some("ok"));
    }

    #[test]
    fn remote_error_surfaces() {
        let mut buf = Vec::new();
        send_error(&mut buf, 3, "boom").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_response(&mut r, 3), Err(RpcError::Remote(e)) if e == "boom"));
    }

    #[test]
    fn mismatched_id_rejected() {
        let mut buf = Vec::new();
        send_result(&mut buf, 1, Value::Null).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_response(&mut r, 2), Err(RpcError::Malformed(_))));
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(b"xx");
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(RpcError::FrameTooLarge(_))));
    }

    #[test]
    fn malformed_json_and_missing_fields() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"not json").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_request(&mut r), Err(RpcError::Malformed(_))));

        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"id\": 1}").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_request(&mut r), Err(RpcError::Malformed(_))));
    }

    #[test]
    fn truncated_frame_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"short");
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(RpcError::Io(_))));
    }

    #[test]
    fn partial_length_prefix_is_closed_not_panic() {
        // a peer dying mid-header (1..3 of the 4 length bytes) must
        // surface as Closed on every prefix length, never panic
        for n in 0..4usize {
            let buf = vec![0x10u8; n];
            let mut r = std::io::Cursor::new(buf);
            assert!(
                matches!(read_frame(&mut r), Err(RpcError::Closed)),
                "prefix of {n} bytes"
            );
        }
    }

    #[test]
    fn oversized_write_is_rejected() {
        // the write side enforces the cap too, so a bad caller cannot emit
        // a frame every reader would then reject
        let payload = vec![0u8; MAX_FRAME + 1];
        let mut buf = Vec::new();
        assert!(matches!(
            write_frame(&mut buf, &payload),
            Err(RpcError::FrameTooLarge(_))
        ));
        assert!(buf.is_empty(), "nothing may reach the wire");
    }

    #[test]
    fn barely_oversized_length_rejected_before_allocation() {
        // MAX_FRAME itself is fine; MAX_FRAME + 1 must fail from the
        // 4-byte header alone (the cursor holds no payload to allocate)
        let mut buf = Vec::new();
        buf.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut r),
            Err(RpcError::FrameTooLarge(n)) if n == MAX_FRAME + 1
        ));
    }

    /// Random JSON payload generator for the round-trip property
    /// (integers within the exact-f64 range, so serialization is
    /// lossless by construction).
    fn random_value(rng: &mut crate::util::rng::Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::from(rng.below(1_000_000) as i64 - 500_000),
            3 => {
                let n = rng.below(12);
                Value::from(
                    (0..n)
                        .map(|_| b"ab\"\\\n\t {}[]:,\x7f"[rng.below(14)] as char)
                        .collect::<String>(),
                )
            }
            4 => Value::Array(
                (0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut m = Map::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_value(rng, depth - 1));
                }
                Value::Object(m)
            }
        }
    }

    #[test]
    fn prop_request_roundtrip_over_random_payloads() {
        crate::util::prop::check("rpc-roundtrip", 80, |rng| {
            let params = random_value(rng, 3);
            let id = rng.next_u64() >> 12; // keep within exact-f64 range
            let mut buf = Vec::new();
            send_request(&mut buf, id, "query", params.clone())
                .map_err(|e| format!("send: {e}"))?;
            let mut r = std::io::Cursor::new(buf);
            let req = recv_request(&mut r).map_err(|e| format!("recv: {e}"))?;
            crate::prop_assert!(req.id == id, "id {} != {id}", req.id);
            crate::prop_assert!(req.method == "query", "method {}", req.method);
            crate::prop_assert!(
                req.params == params,
                "params mismatch:\n got {:?}\nwant {:?}",
                req.params,
                params
            );
            // and the response direction
            let mut buf = Vec::new();
            send_result(&mut buf, id, params.clone()).map_err(|e| format!("send: {e}"))?;
            let mut r = std::io::Cursor::new(buf);
            let back = recv_response(&mut r, id).map_err(|e| format!("recv: {e}"))?;
            crate::prop_assert!(back == params, "response payload mismatch");
            Ok(())
        });
    }
}

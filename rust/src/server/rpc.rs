//! Wire protocol: length-prefixed frames, JSON (v1) or binary-tensor (v2).
//!
//! Frame = `u32 little-endian payload length` + `payload`. A v1 payload is
//! UTF-8 JSON; a v2 payload (first byte `wire::BIN_MAGIC`) is a JSON
//! control header plus raw f32 tensor sections (see `wire` module docs).
//! Requests: `{"id": n, "method": "...", "params": {...}}`.
//! Responses: `{"id": n, "result": ...}` or `{"id": n, "error": "..."}`.
//! Max frame size 64 MiB (a pushed manifest for a million-sample dataset
//! is ~60 MB; beyond that, shard the push).
//!
//! Receivers always accept both encodings (the tag byte disambiguates);
//! only senders pick a [`WireMode`]. A server configured `wire = "json"`
//! additionally refuses v2 *requests* with the stable
//! [`wire::ERR_BINARY_DISABLED`] error so binary-preferring peers can fall
//! back per connection.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::json::{self, Map, Value};
use crate::metrics::Registry;

use super::wire::{self, Body, Payload, WireMode};

/// Hard cap on frame payloads.
pub const MAX_FRAME: usize = 64 * 1024 * 1024;

/// Protocol-level failure.
///
/// The last four variants are *application* errors: the peer is alive,
/// decoded the request, and answered with an error reply — the
/// connection (and the worker behind it) is healthy. `Overloaded`,
/// `QuotaExceeded`, and `UnknownSession` are decoded from the
/// structured `{code, message, retry_after_ms?}` payload a tenancy-aware
/// server embeds in the error string (see [`ServiceError`]); anything
/// else a peer sends stays `Remote`.
#[derive(Debug, thiserror::Error)]
pub enum RpcError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame too large: {0} bytes (max {MAX_FRAME})")]
    FrameTooLarge(usize),
    #[error("malformed frame: {0}")]
    Malformed(String),
    #[error("remote error: {0}")]
    Remote(String),
    #[error("overloaded: {message} (retry after {retry_after_ms} ms)")]
    Overloaded { message: String, retry_after_ms: u64 },
    #[error("quota exceeded: {0}")]
    QuotaExceeded(String),
    #[error("{0}")]
    UnknownSession(String),
    #[error("connection closed")]
    Closed,
}

impl RpcError {
    /// Classify a wire error string: structured service errors become
    /// their typed variant, everything else (old peers, ad-hoc handler
    /// strings) stays [`RpcError::Remote`].
    pub fn from_remote(s: &str) -> RpcError {
        match ServiceError::decode(s) {
            Some(se) => match se.code {
                ErrorCode::Overloaded => RpcError::Overloaded {
                    message: se.message,
                    retry_after_ms: se.retry_after_ms.unwrap_or(0),
                },
                ErrorCode::QuotaExceeded => RpcError::QuotaExceeded(se.message),
                ErrorCode::UnknownSession => RpcError::UnknownSession(se.message),
                ErrorCode::Internal => RpcError::Remote(se.message),
            },
            None => RpcError::Remote(s.to_string()),
        }
    }

    /// True when the peer answered "that session id is not registered
    /// here" — the coordinator's lazy re-push trigger. Matches the typed
    /// variant a structured peer sends and, for old peers, the plain
    /// `unknown session '...'` string.
    pub fn is_unknown_session(&self) -> bool {
        match self {
            RpcError::UnknownSession(_) => true,
            RpcError::Remote(m) => m.contains("unknown session"),
            _ => false,
        }
    }

    /// True for application-level error replies (the peer is alive and
    /// answered) as opposed to transport failures — the distinction
    /// retry/eviction logic keys on: an application error must never
    /// mark a connection stale or a worker dead.
    pub fn is_application(&self) -> bool {
        matches!(
            self,
            RpcError::Remote(_)
                | RpcError::Overloaded { .. }
                | RpcError::QuotaExceeded(_)
                | RpcError::UnknownSession(_)
        )
    }

    /// The bare application-level message of an error reply — what the
    /// peer's handler returned, without the `remote error:` Display
    /// prefix. Falls back to the Display form for transport errors.
    pub fn remote_text(&self) -> String {
        match self {
            RpcError::Remote(m)
            | RpcError::QuotaExceeded(m)
            | RpcError::UnknownSession(m) => m.clone(),
            other => other.to_string(),
        }
    }
}

/// Stable machine-readable codes for structured service errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Admission queue full; retry after `retry_after_ms`.
    Overloaded,
    /// A tenancy quota (`max_sessions`, ...) would be exceeded.
    QuotaExceeded,
    /// The session id/token is not registered on this peer.
    UnknownSession,
    /// Any other server-side failure.
    Internal,
}

impl ErrorCode {
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::QuotaExceeded => "quota_exceeded",
            ErrorCode::UnknownSession => "unknown_session",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        Some(match s {
            "overloaded" => ErrorCode::Overloaded,
            "quota_exceeded" => ErrorCode::QuotaExceeded,
            "unknown_session" => ErrorCode::UnknownSession,
            "internal" => ErrorCode::Internal,
            _ => return None,
        })
    }
}

/// A structured service error: `{code, message, retry_after_ms?}`
/// encoded as JSON *inside* the v1 error-string channel, so old peers
/// see readable JSON text and structured peers decode typed variants.
/// Handlers return `Err(ServiceError::...(...).encode())`; the client's
/// response path runs every wire error string through
/// [`ServiceError::decode`] via [`RpcError::from_remote`].
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceError {
    pub code: ErrorCode,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl ServiceError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ServiceError {
        ServiceError { code, message: message.into(), retry_after_ms: None }
    }

    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> ServiceError {
        ServiceError {
            code: ErrorCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn quota(message: impl Into<String>) -> ServiceError {
        ServiceError::new(ErrorCode::QuotaExceeded, message)
    }

    /// The canonical unknown-session error. The message keeps the exact
    /// `unknown session '{id}'` phrasing old peers substring-match on.
    pub fn unknown_session(id: &str) -> ServiceError {
        ServiceError::new(ErrorCode::UnknownSession, format!("unknown session '{id}'"))
    }

    /// Serialize into the string handlers return as `Err(String)`.
    pub fn encode(&self) -> String {
        let mut m = Map::new();
        m.insert("code", Value::from(self.code.as_str()));
        m.insert("message", Value::from(self.message.as_str()));
        if let Some(ms) = self.retry_after_ms {
            m.insert("retry_after_ms", Value::from(ms));
        }
        json::to_string(&Value::Object(m))
    }

    /// Parse a wire error string; `None` for anything that is not a
    /// structured service error (legacy plain strings, foreign JSON).
    pub fn decode(s: &str) -> Option<ServiceError> {
        let t = s.trim_start();
        if !t.starts_with('{') {
            return None;
        }
        let v = json::parse(s).ok()?;
        let code = ErrorCode::parse(v.get("code")?.as_str()?)?;
        let message = v.get("message")?.as_str()?.to_string();
        let retry_after_ms = v.get("retry_after_ms").and_then(Value::as_i64).map(|n| n as u64);
        Some(ServiceError { code, message, retry_after_ms })
    }
}

/// A parsed request: params (as a zero-copy [`Body`] whose tensor
/// sections stay in the received frame buffer until a handler consumes
/// them — DESIGN.md §Wire) plus which encoding the peer used (replies
/// mirror it).
#[derive(Debug)]
pub struct RequestFrame {
    pub id: u64,
    pub method: String,
    pub params: Body,
    pub mode: WireMode,
    /// Trace context from the envelope's optional `trace` field
    /// (`SpanCtx::default()` when the peer sent none — old peers and
    /// untraced callers look identical).
    pub trace: crate::trace::SpanCtx,
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<(), RpcError> {
    if payload.len() > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(payload.len()));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Closed` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, RpcError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(RpcError::Closed),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(RpcError::FrameTooLarge(len));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn note_tx(metrics: Option<&Registry>, bytes: usize, encode: Duration) {
    if let Some(m) = metrics {
        m.counter("wire.tx_bytes").fetch_add(bytes as u64, Ordering::Relaxed);
        m.time("wire.encode", encode);
    }
}

pub(crate) fn note_rx(metrics: Option<&Registry>, bytes: usize, decode: Duration, mode: WireMode) {
    if let Some(m) = metrics {
        m.counter("wire.rx_bytes").fetch_add(bytes as u64, Ordering::Relaxed);
        m.time("wire.decode", decode);
        m.counter(match mode {
            WireMode::Json => "wire.frames.json",
            WireMode::Binary => "wire.frames.binary",
        })
        .fetch_add(1, Ordering::Relaxed);
    }
}

/// Serialize + send a request in `mode`; tensor payloads inline into the
/// JSON text when `mode` is `Json`. When the calling thread has an
/// active span (installed by a `trace::SpanGuard`), its context rides
/// the envelope as `"trace":{"id","parent"}` — old peers ignore the
/// unknown key, so propagation needs no negotiation.
pub fn send_request_wire(
    w: &mut impl Write,
    id: u64,
    method: &str,
    params: &Payload,
    mode: WireMode,
    metrics: Option<&Registry>,
) -> Result<(), RpcError> {
    let t0 = Instant::now();
    let ctx = crate::trace::current();
    let extra = if ctx.is_active() {
        Some(format!("\"trace\":{{\"id\":{},\"parent\":{}}}", ctx.trace_id, ctx.span_id))
    } else {
        None
    };
    let bytes = wire::encode_message_ext(id, Some(method), params, mode, extra.as_deref())?;
    note_tx(metrics, bytes.len(), t0.elapsed());
    write_frame(w, &bytes)
}

/// Serialize + send a request (v1 JSON convenience form).
pub fn send_request(
    w: &mut impl Write,
    id: u64,
    method: &str,
    params: Value,
) -> Result<(), RpcError> {
    send_request_wire(w, id, method, &Payload::json(params), WireMode::Json, None)
}

/// Decode one frame's bytes (taking ownership of them) into a
/// [`RequestFrame`] whose tensor sections are borrowed from the buffer.
pub fn decode_request_frame(buf: Vec<u8>) -> Result<RequestFrame, RpcError> {
    let (v, tensors, mode) = wire::decode_frame(buf)?;
    let id = v
        .get("id")
        .and_then(Value::as_i64)
        .ok_or_else(|| RpcError::Malformed("missing id".into()))? as u64;
    let method = v
        .get("method")
        .and_then(Value::as_str)
        .ok_or_else(|| RpcError::Malformed("missing method".into()))?
        .to_string();
    let trace = v
        .get("trace")
        .map(|t| crate::trace::SpanCtx {
            trace_id: t.get("id").and_then(Value::as_i64).unwrap_or(0) as u64,
            span_id: t.get("parent").and_then(Value::as_i64).unwrap_or(0) as u64,
        })
        .unwrap_or_default();
    // move the params subtree out of the envelope (a push_data manifest
    // is most of the frame) instead of cloning it
    let params = match v {
        Value::Object(mut m) => m.remove("params").unwrap_or(Value::Null),
        _ => Value::Null,
    };
    Ok(RequestFrame { id, method, params: Body { value: params, tensors }, mode, trace })
}

/// Receive + parse a request frame (either encoding), zero-copy.
pub fn recv_request(r: &mut impl Read) -> Result<RequestFrame, RpcError> {
    decode_request_frame(read_frame(r)?)
}

/// Serialize + send a success response in `mode`.
pub fn send_result_wire(
    w: &mut impl Write,
    id: u64,
    result: &Payload,
    mode: WireMode,
    metrics: Option<&Registry>,
) -> Result<(), RpcError> {
    send_result_ext(w, id, result, mode, metrics, None)
}

/// [`send_result_wire`] with an optional extra envelope fragment — how a
/// traced server piggybacks its span subtree (`"trace_spans":[...]`) on
/// the reply for the caller to adopt. Old callers ignore the field.
pub fn send_result_ext(
    w: &mut impl Write,
    id: u64,
    result: &Payload,
    mode: WireMode,
    metrics: Option<&Registry>,
    extra: Option<&str>,
) -> Result<(), RpcError> {
    let t0 = Instant::now();
    let bytes = wire::encode_message_ext(id, None, result, mode, extra)?;
    note_tx(metrics, bytes.len(), t0.elapsed());
    write_frame(w, &bytes)
}

/// Serialize + send a success response (v1 JSON convenience form).
pub fn send_result(w: &mut impl Write, id: u64, result: Value) -> Result<(), RpcError> {
    send_result_wire(w, id, &Payload::json(result), WireMode::Json, None)
}

/// Serialize + send an error response. Errors always go as v1 JSON so
/// every peer — including one that never spoke v2 — can read them.
pub fn send_error(w: &mut impl Write, id: u64, error: &str) -> Result<(), RpcError> {
    let mut m = Map::new();
    m.insert("id", Value::from(id));
    m.insert("error", Value::from(error));
    write_frame(w, json::to_string(&Value::Object(m)).as_bytes())
}

/// Handler threads spawned per multiplexed connection are capped here;
/// beyond it the read loop processes requests inline, which stops
/// reading further frames until the handler finishes — natural
/// backpressure instead of unbounded thread growth.
const MUX_SERVE_MAX_INFLIGHT: usize = 64;

/// Per-request context a push-capable handler sees (DESIGN.md §Events):
/// the envelope id (a `job_subscribe` request's id doubles as its
/// subscription id for every pushed frame), whether this connection has
/// negotiated multiplexing, and — via [`RequestCtx::push_sink`] — a
/// detachable handle to the serialized write half so a subscription
/// thread can keep pushing frames long after the reply went out.
pub struct RequestCtx {
    pub id: u64,
    pub mux: bool,
    writer: Arc<Mutex<TcpStream>>,
    broken: Arc<AtomicBool>,
}

impl RequestCtx {
    /// A detachable sink for server-push frames on this connection.
    pub fn push_sink(&self) -> PushSink {
        PushSink { writer: self.writer.clone(), broken: self.broken.clone() }
    }
}

/// Detached write handle for unsolicited (server-push) frames. Push
/// frames always go as v1 JSON so every subscriber can read them:
/// events as `{"id":<sub>,"seq":N,"event":{...}}`, a clean stream end
/// as `{"id":<sub>,"end":"<reason>"}`, and stream failure as the plain
/// v1 error reply addressed to the subscription id. A failed write
/// flips the connection's broken flag — the serve loop stops reading,
/// exactly as for a failed reply — and the sink reports closed so
/// publishers stop instead of spinning on a dead socket.
#[derive(Clone)]
pub struct PushSink {
    writer: Arc<Mutex<TcpStream>>,
    broken: Arc<AtomicBool>,
}

impl PushSink {
    /// Has the connection died under this sink?
    pub fn is_closed(&self) -> bool {
        self.broken.load(Ordering::SeqCst)
    }

    fn write_value(&self, v: Value) -> bool {
        if self.is_closed() {
            return false;
        }
        let io = {
            let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
            write_frame(&mut *w, json::to_string(&v).as_bytes())
        };
        if io.is_err() {
            self.broken.store(true, Ordering::SeqCst);
        }
        io.is_ok()
    }

    /// Push one sequenced event frame. `false` means the connection is
    /// gone and the subscription should be torn down.
    pub fn send_event(&self, sub_id: u64, seq: u64, event: &Value) -> bool {
        let mut m = Map::new();
        m.insert("id", Value::from(sub_id));
        m.insert("seq", Value::from(seq));
        m.insert("event", event.clone());
        self.write_value(Value::Object(m))
    }

    /// Cleanly terminate the subscription stream.
    pub fn send_end(&self, sub_id: u64, reason: &str) -> bool {
        let mut m = Map::new();
        m.insert("id", Value::from(sub_id));
        m.insert("end", Value::from(reason));
        self.write_value(Value::Object(m))
    }

    /// Terminate the stream with an error (e.g. the subscriber lagged
    /// past the event buffer).
    pub fn send_error(&self, sub_id: u64, error: &str) -> bool {
        if self.is_closed() {
            return false;
        }
        let io = {
            let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
            send_error(&mut *w, sub_id, error)
        };
        if io.is_err() {
            self.broken.store(true, Ordering::SeqCst);
        }
        io.is_ok()
    }
}

/// Serve framed request/response RPC on one connection until clean EOF,
/// a broken frame, an I/O failure, or `shutdown` flips. Shared by the
/// single server and the cluster coordinator so the idle-probe/shutdown
/// behavior cannot diverge. Per-request latency is recorded under
/// `rpc.{method}` in `metrics`; wire-level byte counts and codec times
/// land under `wire.*`.
///
/// `wire_mode` is this server's configured data plane: replies mirror
/// the request's encoding, and when the config forces `Json` a v2
/// request is answered with the stable `ERR_BINARY_DISABLED` error (the
/// connection stays up so the peer can retry in JSON).
///
/// The idle wait uses a bounded 250ms peek so the handler re-checks the
/// shutdown flag instead of pinning its thread forever; once bytes are
/// available the frame is read under a generous timeout (a frame, once
/// started, arrives promptly).
///
/// With a `tracer`, each request runs under an `rpc.{method}` span:
/// continuing the caller's context when the envelope carried one
/// (traced requests also piggyback this side's span subtree on the
/// reply), or opening a fresh root trace for the entry-point methods in
/// `trace::default_traced`.
///
/// Once the connection negotiates multiplexing (the `handle`-produced
/// `hello` reply carries `"mux": true`), requests are dispatched to
/// scoped handler threads and the loop keeps reading, so many RPCs can
/// be in flight on one socket; replies are serialized through a cloned
/// write half and may interleave out of request order (the envelope
/// `id` is the peer's correlation key). Connections that never
/// negotiate mux are served strictly inline, byte-identical to the
/// pre-mux behavior.
pub fn serve_conn(
    stream: &mut TcpStream,
    tag: &'static str,
    shutdown: &AtomicBool,
    metrics: &Registry,
    tracer: Option<&crate::trace::Tracer>,
    wire_mode: WireMode,
    handle: impl Fn(&str, &Body, WireMode) -> Result<Payload, String> + Sync,
) {
    serve_conn_ext(stream, tag, shutdown, metrics, tracer, wire_mode, |m, p, mode, _ctx| {
        handle(m, p, mode)
    })
}

/// [`serve_conn`] whose handler also receives the per-request
/// [`RequestCtx`] — the push-capable form the coordinator and single
/// server use so `job_subscribe` can detach a [`PushSink`] for the
/// event-stream thread (DESIGN.md §Events). Handlers that ignore the
/// context behave byte-identically to [`serve_conn`].
pub fn serve_conn_ext(
    stream: &mut TcpStream,
    tag: &'static str,
    shutdown: &AtomicBool,
    metrics: &Registry,
    tracer: Option<&crate::trace::Tracer>,
    wire_mode: WireMode,
    handle: impl Fn(&str, &Body, WireMode, &RequestCtx) -> Result<Payload, String> + Sync,
) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    stream.set_nodelay(true).ok();
    // All replies go through one mutex-guarded write half so concurrent
    // mux handler threads cannot interleave frame bytes. The clone
    // shares the fd (and its options) with `stream`; only this loop
    // ever reads, only the mutex holder ever writes. Arc'd (with the
    // broken flag) so a subscription's PushSink can outlive the serve
    // scope: a sink holding the last reference just writes into a
    // socket whose read side is gone, fails, and marks itself closed.
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            // out of fds — refusing the connection beats serving it
            // with no way to ever interleave replies
            crate::log_warn!(tag, "dropping conn from {peer}: clone for write half failed: {e}");
            return;
        }
    };
    let mux = AtomicBool::new(false);
    let in_flight = AtomicUsize::new(0);
    // flipped by a handler thread whose reply write failed: the socket
    // is dead for writing, so reading more requests is pointless
    let broken = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        loop {
            stream.set_read_timeout(Some(Duration::from_millis(250))).ok();
            let mut probe = [0u8; 1];
            loop {
                if shutdown.load(Ordering::SeqCst) || broken.load(Ordering::SeqCst) {
                    return;
                }
                match stream.peek(&mut probe) {
                    Ok(0) => return, // clean EOF
                    Ok(_) => break,  // a frame is waiting
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                    {
                        continue
                    }
                    Err(_) => return,
                }
            }
            stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
            let buf = match read_frame(stream) {
                Ok(b) => b,
                Err(RpcError::Closed) => return,
                Err(e) => {
                    crate::log_debug!(tag, "bad frame from {peer}: {e}");
                    return;
                }
            };
            let t_decode = Instant::now();
            if wire_mode == WireMode::Json && buf.first() == Some(&wire::BIN_MAGIC) {
                // forced-JSON server: refuse the v2 frame from its header
                // alone — never decode tensor sections that will be
                // discarded — and keep the connection so the peer can
                // renegotiate
                let id = match wire::decode_binary_header(&buf) {
                    Ok(v) => v.get("id").and_then(Value::as_i64).unwrap_or(0) as u64,
                    Err(e) => {
                        crate::log_debug!(tag, "bad frame from {peer}: {e}");
                        return;
                    }
                };
                note_rx(Some(metrics), buf.len(), t_decode.elapsed(), WireMode::Binary);
                let io = {
                    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
                    send_error(&mut *w, id, wire::ERR_BINARY_DISABLED)
                };
                if io.is_err() {
                    return;
                }
                continue;
            }
            let buf_len = buf.len();
            // zero-copy decode: tensor sections stay in `buf` (now owned by
            // the request) until the handler materializes the ones it uses
            let req = match decode_request_frame(buf) {
                Ok(r) => r,
                Err(e) => {
                    crate::log_debug!(tag, "bad frame from {peer}: {e}");
                    // protocol is broken on this conn; drop it
                    return;
                }
            };
            note_rx(Some(metrics), buf_len, t_decode.elapsed(), req.mode);
            if mux.load(Ordering::SeqCst)
                && in_flight.load(Ordering::SeqCst) < MUX_SERVE_MAX_INFLIGHT
            {
                in_flight.fetch_add(1, Ordering::SeqCst);
                let (handle, writer, mux, in_flight, broken) =
                    (&handle, &writer, &mux, &in_flight, &broken);
                scope.spawn(move || {
                    // a panicking handler must not poison the whole scope
                    // at join time; treat it like a dead connection
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        process_request(req, metrics, tracer, mux, writer, broken, handle)
                    }));
                    if !matches!(ok, Ok(true)) {
                        broken.store(true, Ordering::SeqCst);
                    }
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                });
            } else if !process_request(req, metrics, tracer, &mux, &writer, &broken, &handle) {
                return;
            }
        }
    })
}

/// Dispatch one decoded request through `handle` and write the reply:
/// the per-request half of [`serve_conn`], shared verbatim by the
/// inline (classic) and spawned (mux) paths so tracing, timing,
/// piggyback, and error-reply behavior cannot diverge between them.
/// Returns `false` when the connection is unusable (reply write
/// failed).
fn process_request(
    req: RequestFrame,
    metrics: &Registry,
    tracer: Option<&crate::trace::Tracer>,
    mux: &AtomicBool,
    writer: &Arc<Mutex<TcpStream>>,
    broken: &Arc<AtomicBool>,
    handle: &(impl Fn(&str, &Body, WireMode, &RequestCtx) -> Result<Payload, String> + Sync),
) -> bool {
    let traced = tracer.is_some_and(|t| t.enabled())
        && (req.trace.is_active() || crate::trace::default_traced(&req.method));
    let t0 = Instant::now();
    // handlers that push (job_subscribe) clone the write half out of
    // this context; everything else ignores it
    let ctx = RequestCtx {
        id: req.id,
        mux: mux.load(Ordering::SeqCst),
        writer: writer.clone(),
        broken: broken.clone(),
    };
    // handlers get the request's encoding so version-sensitive
    // responses (select_shard's candidate schema) can stay
    // v1-compatible on the JSON wire
    let (result, mut spans) = if traced {
        let t = tracer.unwrap();
        crate::trace::begin_collect();
        let r = {
            let mut g = t.request(&format!("rpc.{}", req.method), req.trace);
            let r = handle(&req.method, &req.params, req.mode, &ctx);
            if let Err(e) = &r {
                g.annotate("error", e);
            }
            r
        };
        (r, crate::trace::take_collected())
    } else {
        (handle(&req.method, &req.params, req.mode, &ctx), Vec::new())
    };
    metrics.time(&format!("rpc.{}", req.method), t0.elapsed());
    // the hello handler decides mux per-connection; sniff its reply so
    // the serve loop switches to interleaved dispatch from the next
    // frame on (hello itself always runs inline — mux starts false)
    if req.method == "hello" {
        if let Ok(p) = &result {
            if p.value.get("mux").and_then(Value::as_bool) == Some(true) {
                mux.store(true, Ordering::SeqCst);
            }
        }
    }
    // piggyback this side's spans only when the caller is traced (it
    // sent a context, so it has a tracer to adopt them into)
    let extra = if req.trace.is_active() && !spans.is_empty() {
        spans.truncate(crate::trace::MAX_PIGGYBACK);
        Some(format!(
            "\"trace_spans\":{}",
            json::to_string(&crate::trace::spans_to_value(&spans))
        ))
    } else {
        None
    };
    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
    let io = match result {
        Ok(p) => {
            match send_result_ext(&mut *w, req.id, &p, req.mode, Some(metrics), extra.as_deref()) {
                // encode-side failures (frame cap, bad tensor refs)
                // happen before any bytes hit the stream — e.g. a JSON
                // fallback inflating a tensor reply past MAX_FRAME where
                // the binary form fits. Report them as an error reply
                // instead of silently dropping the connection.
                Err(e) if !matches!(e, RpcError::Io(_)) => {
                    send_error(&mut *w, req.id, &format!("reply encoding failed: {e}"))
                }
                other => other,
            }
        }
        Err(e) => send_error(&mut *w, req.id, &e),
    };
    io.is_ok()
}

/// Receive a response for `expect_id` in either encoding; remote errors
/// surface as `Remote`. Returns the result value plus a [`Body`] whose
/// tensor sections are still borrowed from the frame buffer — the
/// zero-copy path the connection pool and the cluster merge use.
pub fn recv_response_body(
    r: &mut impl Read,
    expect_id: u64,
    metrics: Option<&Registry>,
) -> Result<Body, RpcError> {
    recv_response_traced(r, expect_id, metrics, None)
}

/// [`recv_response_body`] that also folds a `trace_spans` piggyback from
/// the reply envelope into `tracer` (when both are present), so the
/// callee's span subtree lands in the caller's ring. Replies without the
/// field — old peers, untraced requests — behave identically.
pub fn recv_response_traced(
    r: &mut impl Read,
    expect_id: u64,
    metrics: Option<&Registry>,
    tracer: Option<&crate::trace::Tracer>,
) -> Result<Body, RpcError> {
    let buf = read_frame(r)?;
    let buf_len = buf.len();
    let t0 = Instant::now();
    let (v, tensors, mode) = wire::decode_frame(buf)?;
    note_rx(metrics, buf_len, t0.elapsed(), mode);
    let id = v
        .get("id")
        .and_then(Value::as_i64)
        .ok_or_else(|| RpcError::Malformed("missing id".into()))? as u64;
    if id != expect_id {
        return Err(RpcError::Malformed(format!(
            "response id {id} != request id {expect_id}"
        )));
    }
    if let Some(e) = v.get("error").and_then(Value::as_str) {
        return Err(RpcError::from_remote(e));
    }
    // move, don't clone: result can be a multi-MB inline matrix on the
    // JSON wire
    let (result, spans) = match v {
        Value::Object(mut m) => (m.remove("result"), m.remove("trace_spans")),
        _ => (None, None),
    };
    if let (Some(t), Some(sv)) = (tracer, spans) {
        t.adopt(crate::trace::spans_from_value(&sv));
    }
    let result = result.ok_or_else(|| RpcError::Malformed("missing result".into()))?;
    Ok(Body { value: result, tensors })
}

/// Receive a response with every tensor section materialized (the owned
/// view; [`recv_response_body`] is the zero-copy form).
pub fn recv_response_wire(
    r: &mut impl Read,
    expect_id: u64,
    metrics: Option<&Registry>,
) -> Result<Payload, RpcError> {
    recv_response_body(r, expect_id, metrics).map(Body::into_payload)
}

/// Receive a response as a plain `Value` (tensor sections, if any, are
/// inlined) — the v1-compatible view callers without bulk data use.
pub fn recv_response(r: &mut impl Read, expect_id: u64) -> Result<Value, RpcError> {
    recv_response_wire(r, expect_id, None)?.into_inline_value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::value::obj;
    use crate::util::mat::Mat;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert!(matches!(read_frame(&mut r), Err(RpcError::Closed)));
    }

    #[test]
    fn request_response_roundtrip() {
        let mut buf = Vec::new();
        send_request(&mut buf, 7, "query", obj([("budget", Value::from(10))])).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.method, "query");
        assert_eq!(req.mode, WireMode::Json);
        assert_eq!(req.params.value.get("budget").unwrap().as_i64(), Some(10));

        let mut buf = Vec::new();
        send_result(&mut buf, 7, Value::from("ok")).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(recv_response(&mut r, 7).unwrap().as_str(), Some("ok"));
    }

    #[test]
    fn binary_request_roundtrip_carries_tensors() {
        let m = Mat::from_vec(vec![1.0, f32::NAN, -3.5, 0.0], 2, 2);
        let mut p = Payload::default();
        let ph = p.stash_mat(m.clone());
        p.value = obj([("emb", ph)]);
        let mut buf = Vec::new();
        send_request_wire(&mut buf, 9, "scan_shard", &p, WireMode::Binary, None).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert_eq!(req.mode, WireMode::Binary);
        assert_eq!(req.method, "scan_shard");
        let back = req.params.mat("emb").unwrap().unwrap();
        assert_eq!(back.shape(), (2, 2));
        assert!(back.get(0, 1).is_nan());
        assert_eq!(back.get(1, 0), -3.5);
    }

    #[test]
    fn json_mode_inlines_tensor_payloads() {
        let m = Mat::from_vec(vec![0.5, 1.5], 1, 2);
        let mut p = Payload::default();
        let ph = p.stash_mat(m.clone());
        p.value = obj([("emb", ph)]);
        let mut buf = Vec::new();
        send_request_wire(&mut buf, 3, "scan_shard", &p, WireMode::Json, None).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert_eq!(req.mode, WireMode::Json);
        assert!(req.params.tensors.is_empty(), "json frames carry no sections");
        // the field arrives in the inline {rows, cols, data} form
        assert_eq!(req.params.mat("emb").unwrap().unwrap(), m);
    }

    #[test]
    fn binary_response_roundtrip_and_inlined_view() {
        let m = Mat::from_vec(vec![2.0, 4.0, 6.0], 3, 1);
        let mut p = Payload::default();
        let ph = p.stash_mat(m.clone());
        p.value = obj([("init_emb", ph)]);
        let mut buf = Vec::new();
        send_result_wire(&mut buf, 5, &p, WireMode::Binary, None).unwrap();
        // tensor-aware view
        let mut r = std::io::Cursor::new(buf.clone());
        let got = recv_response_wire(&mut r, 5, None).unwrap();
        assert_eq!(got.mat("init_emb").unwrap().unwrap(), m);
        // v1-compatible Value view inlines the section
        let mut r = std::io::Cursor::new(buf);
        let v = recv_response(&mut r, 5).unwrap();
        assert_eq!(
            super::super::wire::mat_from_value(v.get("init_emb").unwrap()).unwrap(),
            m
        );
    }

    #[test]
    fn zero_copy_response_serves_views() {
        let m = Mat::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2);
        let mut p = Payload::default();
        let ph = p.stash_mat(m.clone());
        p.value = obj([("emb", ph)]);
        let mut buf = Vec::new();
        send_result_wire(&mut buf, 11, &p, WireMode::Binary, None).unwrap();
        let mut r = std::io::Cursor::new(buf);
        let body = recv_response_body(&mut r, 11, None).unwrap();
        // one section, still raw bytes; rows copy straight out
        assert_eq!(body.tensors.len(), 1);
        let view = body.mat_ref("emb").unwrap().unwrap();
        assert_eq!(view.row_vec(2), &[5.0, 6.0]);
        assert_eq!(body.mat("emb").unwrap().unwrap(), m);
    }

    #[test]
    fn remote_error_surfaces() {
        let mut buf = Vec::new();
        send_error(&mut buf, 3, "boom").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_response(&mut r, 3), Err(RpcError::Remote(e)) if e == "boom"));
    }

    #[test]
    fn structured_overloaded_error_roundtrips_typed() {
        let enc = ServiceError::overloaded("admit queue full (3 queued)", 120).encode();
        let mut buf = Vec::new();
        send_error(&mut buf, 4, &enc).unwrap();
        let mut r = std::io::Cursor::new(buf);
        match recv_response(&mut r, 4) {
            Err(RpcError::Overloaded { message, retry_after_ms }) => {
                assert_eq!(message, "admit queue full (3 queued)");
                assert_eq!(retry_after_ms, 120);
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }

    #[test]
    fn structured_quota_and_unknown_session_decode() {
        let q = ServiceError::quota("session quota exceeded: 2/2").encode();
        assert!(matches!(
            RpcError::from_remote(&q),
            RpcError::QuotaExceeded(m) if m == "session quota exceeded: 2/2"
        ));
        let u = ServiceError::unknown_session("tok-ff").encode();
        match RpcError::from_remote(&u) {
            RpcError::UnknownSession(m) => assert_eq!(m, "unknown session 'tok-ff'"),
            other => panic!("expected UnknownSession, got {other:?}"),
        }
        // internal code folds back to the plain Remote surface
        let i = ServiceError::new(ErrorCode::Internal, "boom").encode();
        assert!(matches!(RpcError::from_remote(&i), RpcError::Remote(m) if m == "boom"));
    }

    #[test]
    fn legacy_and_foreign_error_strings_stay_remote() {
        for s in [
            "unknown session 'x'",              // old-peer plain string
            "{\"not\":\"service\"}",            // JSON but not a service error
            "{\"code\":\"nope\",\"message\":\"x\"}", // unknown code
            "{broken",                          // not even JSON
        ] {
            assert!(
                matches!(RpcError::from_remote(s), RpcError::Remote(m) if m == s),
                "{s}"
            );
        }
    }

    #[test]
    fn unknown_session_helper_matches_old_and_new_shapes() {
        let typed = RpcError::from_remote(&ServiceError::unknown_session("a").encode());
        assert!(typed.is_unknown_session());
        assert!(RpcError::Remote("unknown session 'a'".into()).is_unknown_session());
        assert!(!RpcError::Remote("boom".into()).is_unknown_session());
        assert!(!RpcError::Closed.is_unknown_session());
        // application-vs-transport classification
        assert!(typed.is_application());
        assert!(RpcError::Remote("boom".into()).is_application());
        assert!(!RpcError::Closed.is_application());
        assert!(!RpcError::Malformed("x".into()).is_application());
    }

    #[test]
    fn service_error_encode_decode_roundtrip() {
        for se in [
            ServiceError::overloaded("busy", 55),
            ServiceError::quota("too many"),
            ServiceError::unknown_session("s1"),
            ServiceError::new(ErrorCode::Internal, "oops"),
        ] {
            assert_eq!(ServiceError::decode(&se.encode()), Some(se.clone()), "{se:?}");
        }
    }

    #[test]
    fn mismatched_id_rejected() {
        let mut buf = Vec::new();
        send_result(&mut buf, 1, Value::Null).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_response(&mut r, 2), Err(RpcError::Malformed(_))));
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(b"xx");
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(RpcError::FrameTooLarge(_))));
    }

    #[test]
    fn malformed_json_and_missing_fields() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"not json").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_request(&mut r), Err(RpcError::Malformed(_))));

        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"id\": 1}").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(recv_request(&mut r), Err(RpcError::Malformed(_))));
    }

    #[test]
    fn truncated_frame_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"short");
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Err(RpcError::Io(_))));
    }

    #[test]
    fn partial_length_prefix_is_closed_not_panic() {
        // a peer dying mid-header (1..3 of the 4 length bytes) must
        // surface as Closed on every prefix length, never panic
        for n in 0..4usize {
            let buf = vec![0x10u8; n];
            let mut r = std::io::Cursor::new(buf);
            assert!(
                matches!(read_frame(&mut r), Err(RpcError::Closed)),
                "prefix of {n} bytes"
            );
        }
    }

    #[test]
    fn oversized_write_is_rejected() {
        // the write side enforces the cap too, so a bad caller cannot emit
        // a frame every reader would then reject
        let payload = vec![0u8; MAX_FRAME + 1];
        let mut buf = Vec::new();
        assert!(matches!(
            write_frame(&mut buf, &payload),
            Err(RpcError::FrameTooLarge(_))
        ));
        assert!(buf.is_empty(), "nothing may reach the wire");
    }

    #[test]
    fn barely_oversized_length_rejected_before_allocation() {
        // MAX_FRAME itself is fine; MAX_FRAME + 1 must fail from the
        // 4-byte header alone (the cursor holds no payload to allocate)
        let mut buf = Vec::new();
        buf.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut r),
            Err(RpcError::FrameTooLarge(n)) if n == MAX_FRAME + 1
        ));
    }

    #[test]
    fn trace_context_rides_the_envelope_in_both_encodings() {
        let tracer = crate::trace::Tracer::with_capacity(true, 0, 16);
        for mode in [WireMode::Json, WireMode::Binary] {
            let root = tracer.root("client.query");
            let ctx = root.ctx();
            let mut buf = Vec::new();
            send_request_wire(&mut buf, 4, "query", &Payload::json(Value::Null), mode, None)
                .unwrap();
            drop(root);
            let mut r = std::io::Cursor::new(buf);
            let req = recv_request(&mut r).unwrap();
            assert_eq!(req.trace.trace_id, ctx.trace_id, "{mode:?}");
            assert_eq!(req.trace.span_id, ctx.span_id, "{mode:?}");
        }
    }

    #[test]
    fn untraced_and_old_peer_requests_decode_with_no_context() {
        // no active span on this thread: the envelope carries no trace key
        let mut buf = Vec::new();
        send_request(&mut buf, 5, "query", Value::Null).unwrap();
        let text = {
            let mut r = std::io::Cursor::new(buf.clone());
            String::from_utf8(read_frame(&mut r).unwrap()).unwrap()
        };
        assert!(!text.contains("trace"), "{text}");
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert!(!req.trace.is_active());
        // a hand-written old-peer frame (pre-trace wire) decodes the same
        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"id\":1,\"method\":\"query\",\"params\":null}").unwrap();
        let mut r = std::io::Cursor::new(buf);
        let req = recv_request(&mut r).unwrap();
        assert_eq!(req.trace, crate::trace::SpanCtx::default());
    }

    #[test]
    fn trace_spans_piggyback_adopted_by_tracer_ignored_by_old_readers() {
        let rec = crate::trace::SpanRecord {
            trace_id: 77,
            span_id: 78,
            parent: 70,
            name: "rpc.select_shard".into(),
            start_ns: 5,
            end_ns: 25,
            notes: vec![],
            root: false,
        };
        let frag = format!(
            "\"trace_spans\":{}",
            json::to_string(&crate::trace::spans_to_value(&[rec]))
        );
        let mut buf = Vec::new();
        send_result_ext(
            &mut buf,
            9,
            &Payload::json(Value::from(1i64)),
            WireMode::Json,
            None,
            Some(&frag),
        )
        .unwrap();
        // an old (trace-unaware) reader sees only the result
        let mut r = std::io::Cursor::new(buf.clone());
        assert_eq!(recv_response(&mut r, 9).unwrap().as_i64(), Some(1));
        // a traced reader folds the subtree into its ring
        let t = crate::trace::Tracer::with_capacity(true, 0, 8);
        let mut r = std::io::Cursor::new(buf);
        let body = recv_response_traced(&mut r, 9, None, Some(&t)).unwrap();
        assert_eq!(body.value.as_i64(), Some(1));
        let adopted = t.get(77);
        assert_eq!(adopted.len(), 1);
        assert_eq!(adopted[0].parent, 70);
    }

    /// Random JSON payload generator for the round-trip property
    /// (integers within the exact-f64 range, so serialization is
    /// lossless by construction).
    fn random_value(rng: &mut crate::util::rng::Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.below(2) == 0),
            2 => Value::from(rng.below(1_000_000) as i64 - 500_000),
            3 => {
                let n = rng.below(12);
                Value::from(
                    (0..n)
                        .map(|_| b"ab\"\\\n\t {}[]:,\x7f"[rng.below(14)] as char)
                        .collect::<String>(),
                )
            }
            4 => Value::Array(
                (0..rng.below(4)).map(|_| random_value(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut m = crate::json::Map::new();
                for i in 0..rng.below(4) {
                    m.insert(format!("k{i}"), random_value(rng, depth - 1));
                }
                Value::Object(m)
            }
        }
    }

    #[test]
    fn prop_request_roundtrip_over_random_payloads() {
        crate::util::prop::check("rpc-roundtrip", 80, |rng| {
            let params = random_value(rng, 3);
            let id = rng.next_u64() >> 12; // keep within exact-f64 range
            // run the same payload through both encodings
            for mode in [WireMode::Json, WireMode::Binary] {
                let p = Payload::json(params.clone());
                let mut buf = Vec::new();
                send_request_wire(&mut buf, id, "query", &p, mode, None)
                    .map_err(|e| format!("send: {e}"))?;
                let mut r = std::io::Cursor::new(buf);
                let req = recv_request(&mut r).map_err(|e| format!("recv: {e}"))?;
                crate::prop_assert!(req.id == id, "id {} != {id}", req.id);
                crate::prop_assert!(req.method == "query", "method {}", req.method);
                crate::prop_assert!(req.mode == mode, "mode {:?}", req.mode);
                crate::prop_assert!(
                    req.params.value == params,
                    "params mismatch ({mode:?}):\n got {:?}\nwant {:?}",
                    req.params.value,
                    params
                );
                // and the response direction
                let mut buf = Vec::new();
                send_result_wire(&mut buf, id, &Payload::json(params.clone()), mode, None)
                    .map_err(|e| format!("send: {e}"))?;
                let mut r = std::io::Cursor::new(buf);
                let back = recv_response(&mut r, id).map_err(|e| format!("recv: {e}"))?;
                crate::prop_assert!(back == params, "response payload mismatch ({mode:?})");
            }
            Ok(())
        });
    }
}
